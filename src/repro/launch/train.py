"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 20 --batch 8 --seq 256 [--smoke] [--fed]

On this CPU host it runs the reduced (smoke) configs by default; on a real
TPU slice drop --smoke and point --mesh at the production topology (the
same step functions the dry-run lowers are used verbatim).

``--trace-out PATH`` dumps the ``repro.obs`` timeline (per-step
``train.step`` spans via ``jax.profiler.StepTraceAnnotation``, loss gauge,
device-memory watermarks) as Chrome trace-event JSON for Perfetto /
chrome://tracing.  ``--scope-costs`` prints the per-``obs.*``-named-scope
FLOP/byte attribution of the compiled step (``repro.obs.devmem``) — which
kernel owns the step's cost, straight from the HLO.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.core.lora import FAMILY_TARGETS, attach_lora
from repro.data.tokens import lm_batches, markov_tokens
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_fed_train_step, make_train_step
from repro.models.registry import get_model, train_batch_shapes
from repro.optim.adamw import adamw_init


def synth_batch(cfg, batch, seq, it):
    shapes = train_batch_shapes(cfg, batch, seq)
    out = {}
    b = next(it)
    for k, (shp, dt) in shapes.items():
        if k == "tokens":
            out[k] = jnp.asarray(b["tokens"][:, :shp[1]])
        elif k == "labels":
            out[k] = jnp.asarray(b["labels"][:, :shp[1]])
        else:
            out[k] = jnp.zeros(shp, dt)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="smollm-360m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full-config", dest="smoke", action="store_false")
    ap.add_argument("--fed", action="store_true",
                    help="LoRA-federated step (the paper's training mode)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--trace-out", default="",
                    help="write the repro.obs span timeline as Chrome "
                         "trace-event JSON (Perfetto / chrome://tracing)")
    ap.add_argument("--scope-costs", action="store_true",
                    help="print per-obs.* named-scope FLOP/byte attribution "
                         "of the compiled train step")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    api = get_model(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    print(f"arch={cfg.name} devices={mesh.size} mesh={dict(mesh.shape)}")

    params = api.init(cfg, jax.random.PRNGKey(0))
    if args.fed:
        params = attach_lora(params, jax.random.PRNGKey(1), rank=4,
                             alpha=8.0, targets=FAMILY_TARGETS[cfg.family])
        step_fn = make_fed_train_step(cfg, lr=args.lr)
    else:
        step_fn = make_train_step(cfg, lr=args.lr)
    opt = adamw_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    toks = markov_tokens(200_000, cfg.vocab_size, seed=0)
    it = lm_batches(toks, args.batch, args.seq + 1, seed=0)

    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    with mesh:
        if args.scope_costs:
            # undonated lower: attribution only, params survive for the loop
            batch = synth_batch(cfg, args.batch, args.seq, it)
            compiled = jax.jit(step_fn).lower(
                params, opt, batch, jnp.asarray(0, jnp.int32)).compile()
            costs = obs.devmem.compiled_scope_costs(compiled)
            if costs:
                total_f = sum(v["flops"] for v in costs.values()) or 1.0
                print("per-scope HLO cost attribution (compiled step):")
                for scope, v in sorted(costs.items(),
                                       key=lambda kv: -kv[1]["flops"]):
                    print(f"  {scope:<28} flops={v['flops']:.3e} "
                          f"({v['flops'] / total_f:5.1%})  "
                          f"bytes={v['bytes']:.3e}")
        t0 = time.time()
        for i in range(args.steps):
            batch = synth_batch(cfg, args.batch, args.seq, it)
            with obs.step_span("train.step", i, batch=args.batch,
                               seq=args.seq):
                params, opt, loss = jitted(params, opt, batch,
                                           jnp.asarray(i, jnp.int32))
                loss = float(loss)      # device sync inside the span
            obs.gauge("train.loss", loss)
            if i < 3 or (i + 1) % 5 == 0:
                dt = time.time() - t0
                tok_s = args.batch * args.seq * (i + 1) / dt
                print(f"step {i + 1}/{args.steps} loss={loss:.4f} "
                      f"({tok_s:.0f} tok/s)", flush=True)
                if obs.enabled():
                    obs.watermark("train.step")   # devmem track, sampled
    print("done")
    if args.trace_out:
        from repro.obs import bench_gate
        path = obs.dump(args.trace_out, provenance=bench_gate.provenance())
        print(f"trace: wrote {path} "
              f"(open at https://ui.perfetto.dev or chrome://tracing)")


if __name__ == "__main__":
    main()
