import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, and extract the roofline raw terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results land in experiments/dryrun/<arch>__<shape>__<mesh>[__fed].json;
benchmarks/roofline.py turns them into EXPERIMENTS.md §Roofline.

NOTE: the XLA_FLAGS line above MUST run before any other import (jax locks
the device count at first init); do not move it.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import dryrun_args
from repro.launch.steps import (decode_force_window, make_fed_train_step,
                                make_prefill_step, make_serve_step,
                                make_train_step)


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            fed: bool = False, outdir: str = "experiments/dryrun") -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    t0 = time.time()

    kind, args, in_sh, out_sh = dryrun_args(cfg, shape_name, mesh, fed=fed)
    # gradient-accumulation factor: large models microbatch train_4k
    # (§Perf memory lever; EXPERIMENTS.md records before/after)
    accum = int(os.environ.get("REPRO_ACCUM", "0")) or         (8 if cfg.d_model >= 4096 else 4 if cfg.d_model >= 1024 else 1)
    if kind == "train":
        fn = make_train_step(cfg, accum=accum)
        donate = (0, 1)
    elif kind == "fed_train":
        fn = make_fed_train_step(cfg)
        donate = (0, 1)
    elif kind == "prefill":
        fn = make_prefill_step(cfg)
        donate = ()
    else:
        fw = decode_force_window(cfg, [s for s in INPUT_SHAPES
                                       if s.name == shape_name][0].seq_len)
        fn = make_serve_step(cfg, force_window=fw)
        donate = (1,)

    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax < 0.5 returns a one-element list of dicts
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # scan-aware accounting (XLA cost_analysis counts while bodies once)
        parsed = hlo_analyze(hlo)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "step_kind": kind, "fed": fed,
        "accum": accum if kind in ("train", "fed_train") else 1,
        "num_devices": mesh.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # per-device numbers (post-SPMD module, trip-count corrected)
        "flops_per_device": parsed["flops_per_device"],
        "bytes_accessed_per_device": parsed["bytes_per_device"],
        "collectives": {"bytes": parsed["collective_bytes"],
                        "counts": parsed["collective_counts"],
                        "total_bytes": parsed["collective_total_bytes"]},
        # raw XLA module-level numbers (uncorrected), for reference
        "xla_flops_per_device": cost.get("flops", 0.0),
        "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
    }
    os.makedirs(outdir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_name}" + ("__fed" if fed else "")
    with open(os.path.join(outdir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=[s.name for s in INPUT_SHAPES])
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--fed", action="store_true",
                    help="lower the paper's LoRA-federated train step")
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x shapes")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    pairs = []
    archs = ASSIGNED_ARCHS if args.all else [args.arch]
    shapes = [s.name for s in INPUT_SHAPES] if args.all else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    ok, fail = 0, 0
    for a, s in pairs:
        for mp in meshes:
            tag = f"{a} x {s} x {'multi' if mp else 'single'}" + \
                (" [fed]" if args.fed else "")
            try:
                r = run_one(a, s, multi_pod=mp, fed=args.fed,
                            outdir=args.outdir)
                print(f"OK   {tag}: compile={r['compile_s']}s "
                      f"flops/dev={r['flops_per_device']:.3e} "
                      f"coll={r['collectives']['total_bytes']:.3e}B "
                      f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB",
                      flush=True)
                ok += 1
            except Exception as e:
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
                fail += 1
    print(f"dryrun: {ok} ok, {fail} failed", flush=True)
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
