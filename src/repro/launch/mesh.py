"""Production mesh construction.

Single pod: (data=16, model=16) — 256 TPU v5e chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the
``pod`` axis carries cross-site aggregation (Caltech/JPL in the paper's ACN
setting — DESIGN.md §3).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""

from __future__ import annotations

import jax

# Canonical production mesh shapes, keyed by the dry-run's mesh name.
# Single source of truth for mesh construction AND the analytic comm
# cross-checks (benchmarks/roofline.py, repro.dist.fed).
PRODUCTION_MESH_SHAPES = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the installed jax supports
    them (>= 0.5); on older jax Auto is the only behavior anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    spec = PRODUCTION_MESH_SHAPES["multi" if multi_pod else "single"]
    return _make_mesh(tuple(spec.values()), tuple(spec))


def make_host_mesh(*, model: int = 1):
    """Whatever this host actually has (CPU smoke / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return _make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants for the roofline (EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
