"""Production mesh construction.

Single pod: (data=16, model=16) — 256 TPU v5e chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips across 2 pods; the
``pod`` axis carries cross-site aggregation (Caltech/JPL in the paper's ACN
setting — DESIGN.md §3).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(*, model: int = 1):
    """Whatever this host actually has (CPU smoke / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# v5e hardware constants for the roofline (EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
