"""Dry-run argument construction: ShapeDtypeStruct stand-ins + shardings for
every (architecture × input shape) pair — no device allocation anywhere.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, SHAPES_BY_NAME
from repro.core.lora import FAMILY_TARGETS, attach_lora, quantize_base
from repro.dist.sharding import (cache_specs, data_specs, opt_state_specs,
                                 param_specs, to_shardings)
from repro.launch.steps import decode_force_window
from repro.models.registry import (decode_batch_shapes, get_model,
                                   train_batch_shapes)
from repro.optim.adamw import adamw_init


def _sds(tree_of_shape_dtype):
    return {k: jax.ShapeDtypeStruct(shp, dt)
            for k, (shp, dt) in tree_of_shape_dtype.items()}


def param_shapes(cfg: ModelConfig, *, fed: bool = False):
    """abstract parameter tree via eval_shape (no allocation)."""
    api = get_model(cfg)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def build(k):
        p = api.init(cfg, k)
        if fed:
            ft = cfg.fedtime
            targets = FAMILY_TARGETS[cfg.family]
            p = attach_lora(p, k, rank=ft.lora_rank, alpha=ft.lora_alpha,
                            targets=targets)
            if ft.qlora:
                p = quantize_base(p, qblock=ft.qlora_block, targets=targets)
        return p

    return jax.eval_shape(build, key)


def dryrun_args(arch_cfg: ModelConfig, shape_name: str, mesh, *,
                fed: bool = False) -> Tuple[str, tuple, tuple, tuple]:
    """Returns (step_kind, arg ShapeDtypeStructs, in_shardings,
    out_shardings)."""
    cfg = arch_cfg
    shape = SHAPES_BY_NAME[shape_name]
    api = get_model(cfg)
    params = param_shapes(cfg, fed=fed)
    p_shard = to_shardings(param_specs(params, mesh), mesh)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        if fed:
            from repro.core.lora import lora_tree
            opt = jax.eval_shape(lambda p: adamw_init(lora_tree(p)), params)
        else:
            opt = jax.eval_shape(adamw_init, params)
        from repro.core.lora import lora_tree
        batch = _sds(train_batch_shapes(cfg, shape.global_batch,
                                        shape.seq_len))
        if fed:
            ad = jax.eval_shape(lora_tree, params)
            o_shard = to_shardings(opt_state_specs(ad, mesh), mesh)
        else:
            # ZeRO-1: m/v additionally sharded over data(+pod)
            o_shard = to_shardings(opt_state_specs(params, mesh), mesh)
        opt_shard = {"mu": o_shard, "nu": o_shard}
        b_shard = to_shardings(data_specs(batch, mesh), mesh)
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return ("fed_train" if fed else "train",
                (params, opt, batch, step),
                (p_shard, opt_shard, b_shard, repl),
                (p_shard, opt_shard, repl))

    if shape.kind == "prefill":
        batch = _sds(train_batch_shapes(cfg, shape.global_batch,
                                        shape.seq_len))
        batch.pop("labels")
        cache = jax.eval_shape(
            lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len,
                                   force_window=0, dtype=jnp.bfloat16))
        c_shard = to_shardings(cache_specs(cache, mesh), mesh)
        b_shard = to_shardings(data_specs(batch, mesh), mesh)
        return ("prefill", (params, batch), (p_shard, b_shard),
                (c_shard, repl))

    # decode
    fw = decode_force_window(cfg, shape.seq_len)
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len,
                               force_window=fw, dtype=jnp.bfloat16))
    c_shard = to_shardings(cache_specs(cache, mesh), mesh)
    batch = _sds(decode_batch_shapes(cfg, shape.global_batch))
    b_shard = to_shardings(data_specs(batch, mesh), mesh)
    tok_shard = b_shard["token"]
    return ("serve", (params, cache, batch),
            (p_shard, c_shard, b_shard),
            (tok_shard, c_shard))
