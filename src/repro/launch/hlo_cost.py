"""Scan-aware HLO cost analysis from compiled text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports FLOPs/bytes by ~num_layers×.  This
parser rebuilds the cost from the post-SPMD HLO text with loop trip counts
(taken from ``backend_config.known_trip_count``) multiplied through the
call graph:

  * FLOPs: every ``dot`` op — 2 · numel(result) · contracted dims.
    (Elementwise FLOPs are ignored: matmul-dominated at these scales.)
  * bytes: operands + result of every op executed at non-fused level
    (fusion bodies contribute at their call boundary — matching
    HloCostAnalysis' "bytes accessed" semantics).
  * collective bytes: result bytes per collective kind, trip-aware.

All shapes in the partitioned module are per-device, so every number this
module emits is per-device (multiply by mesh size for global).
"""

from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8,
    "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(r"^\s+(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w\.\-]+))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "after-all",
                   "opt-barrier"}


def _array_segments(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(d, [int(x) for x in dims.split(",")] if dims else [])
            for d, dims in _ARRAY_RE.findall(type_str)]


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for d, dims in _array_segments(type_str):
        n = 1
        for x in dims:
            n *= x
        total += n * _DTYPE_BYTES.get(d, 4)
    return total


@dataclass
class Op:
    name: str
    kind: str
    result_type: str
    operands: List[str]
    callees: List[Tuple[str, int]]      # (computation, multiplier)
    raw: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)


def _parse_op(line: str) -> Optional[Op]:
    m = _OP_RE.match(line)
    if not m:
        return None
    name, rhs = m.group(2), m.group(3)
    # result type = leading type expression (array or balanced-paren tuple —
    # tuples may contain /*index=N*/ comments, so match parens manually)
    if rhs.startswith("("):
        depth, j = 0, 0
        while j < len(rhs):
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        result_type = rhs[:j + 1]
        rest = rhs[j + 1:]
    else:
        tm2 = re.match(r"([a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)", rhs)
        if not tm2:
            return None
        result_type = tm2.group(1)
        rest = rhs[tm2.end():]
    km = re.match(r"\s+([a-z][\w\-]*)", rest)
    if not km:
        return None
    kind = km.group(1)
    # operands: %names inside the first (...) after the op kind
    pstart = rhs.find("(", len(result_type) + km.end(1))
    operands = []
    if pstart >= 0:
        depth, j = 0, pstart
        while j < len(rhs):
            if rhs[j] == "(":
                depth += 1
            elif rhs[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        operands = re.findall(r"%([\w\.\-]+)", rhs[pstart:j + 1])

    callees: List[Tuple[str, int]] = []
    trip = 1
    tm = _TRIP_RE.search(rhs)
    if tm:
        trip = int(tm.group(1))
    for cm in _CALL_ATTR_RE.finditer(rhs):
        group = cm.group(1) or cm.group(2)
        mult = trip if kind == "while" else 1
        for cname in re.findall(r"%?([\w\.\-]+)", group):
            callees.append((cname, mult))
    return Op(name, kind, result_type, operands, callees, rhs)


def parse_module(hlo_text: str):
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    current: Optional[Computation] = None
    types: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        if not line.strip() or line.strip().startswith("//"):
            continue
        if not line.startswith(" "):
            hm = _COMP_HEADER_RE.match(line)
            if hm:
                current = Computation(hm.group(2))
                comps[current.name] = current
                if hm.group(1):
                    entry = current.name
                # parameter types from header signature
                sig = hm.group(3)
                for pm in re.finditer(r"([\w\.\-]+):\s*"
                                      r"(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\])",
                                      sig):
                    types[pm.group(1)] = pm.group(2)
            continue
        if current is None:
            continue
        op = _parse_op(line)
        if op:
            current.ops.append(op)
            types[op.name] = op.result_type
    return comps, entry, types


def _dot_flops(op: Op, types: Dict[str, str]) -> float:
    segs = _array_segments(op.result_type)
    numel = 1
    for _, dims in segs[:1]:
        for x in dims:
            numel *= x
    cm = _CONTRACT_RE.search(op.raw)
    contract = 1
    if cm and op.operands:
        lhs_t = types.get(op.operands[0], "")
        lhs_segs = _array_segments(lhs_t)
        if lhs_segs:
            lhs_dims = lhs_segs[0][1]
            for idx in (int(i) for i in cm.group(1).split(",") if i):
                if idx < len(lhs_dims):
                    contract *= lhs_dims[idx]
    return 2.0 * numel * contract


def analyze(hlo_text: str) -> dict:
    comps, entry, types = parse_module(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # computations reached via fusion calls contribute no byte traffic
    fusion_bodies = set()
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                for cname, _ in op.callees:
                    fusion_bodies.add(cname)

    # multiplicity of each computation (trip-count aware, memoized DAG walk)
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = _topo_order(comps, entry)
    for cname in order:
        m = mult[cname]
        if m == 0 or cname not in comps:
            continue
        for op in comps[cname].ops:
            for callee, k in op.callees:
                if callee in comps:
                    mult[callee] += m * k

    flops = 0.0
    bytes_acc = 0.0
    coll_bytes = {c: 0.0 for c in _COLLECTIVES}
    coll_counts = {c: 0.0 for c in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        in_fusion = cname in fusion_bodies
        for op in comp.ops:
            if op.kind in ("dot", "dot-general"):
                flops += m * _dot_flops(op, types)
            if not in_fusion and op.kind not in _SKIP_BYTES_OPS:
                b = _type_bytes(op.result_type)
                for o in op.operands:
                    t = types.get(o)
                    if t:
                        b += _type_bytes(t)
                bytes_acc += m * b
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in _COLLECTIVES:
                coll_bytes[base] += m * _type_bytes(op.result_type)
                coll_counts[base] += m

    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total_bytes": sum(coll_bytes.values()),
        "num_computations": len(comps),
    }


def _topo_order(comps, entry) -> List[str]:
    """Callers before callees (call graph is a DAG in HLO)."""
    edges = {c: [cl for op in comp.ops for cl, _ in op.callees
                 if cl in comps]
             for c, comp in comps.items()}
    seen, order = set(), []

    def visit(c):
        if c in seen:
            return
        seen.add(c)
        order.append(c)          # pre-order: caller first
        for nxt in edges.get(c, []):
            visit(nxt)

    visit(entry)
    # pre-order works because multiplicities only flow downward and we
    # process in discovery order; but diamond patterns need full ordering:
    # redo as proper topological sort (Kahn) to be safe.
    indeg = defaultdict(int)
    for c, outs in edges.items():
        for o in set(outs):
            indeg[o] += 1
    frontier = [c for c in comps if indeg[c] == 0]
    topo = []
    indeg2 = dict(indeg)
    while frontier:
        c = frontier.pop()
        topo.append(c)
        for o in set(edges.get(c, [])):
            indeg2[o] -= 1
            if indeg2[o] == 0:
                frontier.append(o)
    return topo if len(topo) == len(comps) else order


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=2))
