"""Jit-able step functions lowered by the dry-run and used by launchers.

  train_step   — full fine-tuning: value_and_grad + AdamW (on a mesh the
                 update runs the ZeRO-1 scatter-update schedule: shard-local
                 moment update + all-gather of the updated param shard only;
                 REPRO_ZERO1_SCATTER=0 restores the gather form)
  fed_train_step — the paper's step: LoRA-only grads, cluster-weighted psum
                 aggregation over the data (+pod) axes folded into the step
                 (DESIGN.md §3: federation mapped onto mesh collectives);
                 the adapter AdamW takes the same scatter-update schedule
  prefill_step — full forward building the KV/SSM cache + last logits
  serve_step   — one-token decode against the cache, through the fused
                 flash-decode kernel path (repro.kernels.ops.flash_decode;
                 seq-sharded caches combine per-shard partials over the
                 ``model`` axis via repro.dist.decode)

All are pure; cfg/api are closed over (static).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.lora import lora_mask
from repro.models.registry import get_model
from repro.optim.adamw import adamw_init, adamw_update, adamw_update_zero1


def _mesh_update(params, grads, opt_state, step, *, lr):
    """AdamW on the ZeRO-1 scatter-update schedule when a mesh is active
    (slice to the moment shard, update, all-gather ONLY the updated param
    shard — `repro.optim.adamw`); plain AdamW otherwise.  Bit-exact either
    way; REPRO_ZERO1_SCATTER=0 restores the gather formulation."""
    from repro.dist.sharding import current_mesh
    return adamw_update_zero1(params, grads, opt_state, step,
                              mesh=current_mesh(), lr=lr)


def make_train_step(cfg: ModelConfig, *, lr: float = 1e-4, accum: int = 1):
    """``accum`` > 1 enables gradient accumulation (microbatching): the
    global batch is split into ``accum`` microbatches scanned sequentially,
    dividing activation memory by ~accum at equal total FLOPs (§Perf
    memory-term lever for the large train_4k configs)."""
    api = get_model(cfg)

    def train_step(params, opt_state, batch, step):
        if accum <= 1:
            loss, grads = jax.value_and_grad(api.loss)(params, cfg, batch)
        else:
            # pin the f32 accumulation carry to the ZeRO layout — otherwise
            # it persists model-sharded-only (6.75 GiB/device at 27B) across
            # all microbatches (§Perf iteration 7)
            from repro.dist.sharding import (current_mesh, opt_state_specs,
                                             to_shardings)
            mesh = current_mesh()

            def pin(tree):
                if mesh is None:
                    return tree
                sh = to_shardings(opt_state_specs(tree, mesh), mesh)
                return jax.tree.map(jax.lax.with_sharding_constraint,
                                    tree, sh)

            # grad accumulation dtype: bf16 halves the dominant train-step
            # temp (transient grad tree + carry) at a documented precision
            # cost (§Perf iteration 8) — f32 default.
            import os
            acc_dt = jnp.bfloat16 if os.environ.get(
                "REPRO_GRAD_DTYPE") == "bf16" else jnp.float32

            def micro(carry, mb):
                l_acc, g_acc = carry
                l, g = jax.value_and_grad(api.loss)(params, cfg, mb)
                g_acc = pin(jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32) +
                                  b.astype(jnp.float32)).astype(acc_dt),
                    g_acc, g))
                return (l_acc + l, g_acc), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) +
                                    x.shape[1:]), batch)
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt),
                                 params))
            (loss, grads), _ = jax.lax.scan(micro, zero, micro_batches)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        params, opt_state = _mesh_update(params, grads, opt_state, step + 1,
                                         lr=lr)
        return params, opt_state, loss

    return train_step


def make_fed_train_step(cfg: ModelConfig, *, lr: float = 1e-3):
    """The paper's local step at mesh scale: every data-axis slice is a
    cluster member training its LoRA adapters on its own shard; the
    weighted adapter-delta aggregation (Algorithm 1, line 12) is a psum
    over ``data`` (+``pod`` cross-site).  Base weights receive no grads and
    no traffic — exactly FedTime's comm profile."""
    api = get_model(cfg)
    from repro.core.lora import lora_tree, merge_lora

    def fed_train_step(params, opt_state, batch, step):
        # differentiate w.r.t. the adapter subtree ONLY: the NF4-quantized
        # base (uint8 codes) is frozen and carries no tangents — exactly
        # the paper's client step
        adapters = lora_tree(params)

        def loss_fn(ad):
            return api.loss(merge_lora(params, ad), cfg, batch)

        loss, grads = jax.value_and_grad(loss_fn)(adapters)
        adapters, opt_state = _mesh_update(adapters, grads, opt_state,
                                           step + 1, lr=lr)
        params = merge_lora(params, adapters)
        return params, opt_state, loss

    return fed_train_step


def make_prefill_step(cfg: ModelConfig, *, force_window: int = 0):
    api = get_model(cfg)

    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch, force_window=force_window)

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, force_window: int = 0,
                    sampling: bool = False, guard: bool = False):
    """One-token decode step.  Attention over the ring cache runs the fused
    flash-decode path (Pallas on TPU, blockwise XLA elsewhere; int8 caches
    dequantized tile-by-tile in the streamed pass); REPRO_FLASH_DECODE=0
    restores the legacy dequantize-then-sdpa step for A/B comparison.

    Two batch layouts share the one compiled step:

      * synchronous: ``{"token": (B,1), "pos": scalar}`` — every row at the
        same position (the fixed-batch launcher / dry-run shape).
      * ragged (continuous batching): ``pos`` is (B,) with per-slot
        positions, ``-1`` marking inactive lanes.  Inactive lanes are fully
        masked in attention, their cache lanes are frozen (SSM states
        included), and their token passes through unchanged — batch
        composition changes step to step without re-jit.  With a paged pool
        (``block_tbl``/``ring_len`` in the batch) the attention cache is one
        shared block pool: inactive-lane writes are already dropped at the
        scatter (out-of-bounds index, mode="drop"), so the freeze select is
        skipped — it has no batch axis to select over.

    ``sampling=True`` additionally reads per-slot ``temperature``/``top_k``/
    ``top_p`` ((B,) arrays), base PRNG keys ``key`` ((B, 2) uint32) and
    per-slot sample counters ``t`` ((B,)), routing logits through
    ``repro.serve.sampling.sample_vec`` (rows with temperature <= 0 stay
    greedy — bit-identical to the argmax path).

    ``guard=True`` (the fault-tolerant engine's step) additionally reads a
    (B,) bool ``poison`` batch row — the chaos harness's in-jit NaN
    injector, which overwrites a poisoned lane's logits row with NaN
    *before* sampling — and returns ``(next_token, ok, new_cache)`` where
    ``ok`` is ``fault.guard.logits_finite`` evaluated per lane on the
    post-injection logits slice (inactive lanes report ok, they produced
    nothing).  The injector and the screen live in the same compiled step
    so arming/disarming chaos never adds a jit signature."""
    api = get_model(cfg)

    def serve_step(params, cache, batch):
        pos = jnp.asarray(batch["pos"], jnp.int32)
        logits, new_cache = api.decode_step(params, cfg, cache, batch,
                                            force_window=force_window)
        lg = logits[:, -1, :]
        if guard:
            from repro.fault.guard import logits_finite
            poison = jnp.asarray(batch["poison"], bool)
            lg = jnp.where(poison[:, None], jnp.asarray(jnp.nan, lg.dtype),
                           lg)
            ok = logits_finite(lg)
        if sampling:
            from repro.serve.sampling import sample_vec
            keys = jax.vmap(jax.random.fold_in)(batch["key"], batch["t"])
            next_token = sample_vec(keys, lg,
                                    temperature=batch["temperature"],
                                    top_k=batch["top_k"],
                                    top_p=batch["top_p"])[:, None]
        else:
            next_token = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
        if pos.ndim == 1:
            active = pos >= 0
            if "block_tbl" not in batch:
                from repro.serve.cache_pool import (cache_batch_axes,
                                                    freeze_inactive)
                new_cache = freeze_inactive(cache, new_cache, active,
                                            cache_batch_axes(api, cfg))
            next_token = jnp.where(active[:, None], next_token,
                                   batch["token"])
            if guard:
                ok = ok | ~active          # inactive lanes produced nothing
        if guard:
            return next_token, ok, new_cache
        return next_token, new_cache

    return serve_step


def decode_force_window(cfg: ModelConfig, seq_len: int) -> int:
    """long_500k policy (DESIGN.md §4): pure full-attention archs decode
    under the sliding-window variant; windowed/recurrent archs run native."""
    if seq_len >= 262_144 and cfg.sliding_window == 0 and \
            cfg.family not in ("ssm", "hybrid"):
        return cfg.decode_sliding_window or 4096
    return 0
