"""Serving launcher: prefill a batch of prompts, then decode N tokens with
the same serve_step the dry-run lowers.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.kernels import ops
from repro.launch.steps import make_serve_step
from repro.models.registry import get_model, train_batch_shapes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full-config", dest="smoke", action="store_false")
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    print(f"decode path: {ops.decode_mode()}")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    total = P + args.gen

    rng = np.random.default_rng(0)
    batch = {}
    shapes = train_batch_shapes(cfg, B, P)
    shapes.pop("labels")
    for k, (shp, dt) in shapes.items():
        if dt == jnp.int32:
            batch[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, shp),
                                   jnp.int32)
        else:
            batch[k] = jnp.zeros(shp, dt)

    t0 = time.time()
    cache, logits = api.prefill(params, cfg, batch, cache_len=total)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{P} in {t_prefill:.2f}s "
          f"({B * P / t_prefill:.0f} tok/s)")

    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.time()
    # prompt positions vary per family (vlm prepends image tokens)
    pos0 = P + (cfg.vlm.num_image_tokens if cfg.family == "vlm" else 0)
    for i in range(args.gen):
        tok, cache = serve(params, cache,
                           {"token": tok, "pos": jnp.asarray(pos0 + i,
                                                             jnp.int32)})
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.time() - t0
    out = np.concatenate(generated, axis=1)
    print(f"decode: {args.gen} steps x {B} seqs in {dt:.2f}s "
          f"({B * args.gen / dt:.1f} tok/s)")
    print(f"sample continuation (seq 0): {out[0][:16].tolist()}")


if __name__ == "__main__":
    main()
