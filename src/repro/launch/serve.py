"""Serving launcher: fixed-batch decode or the continuous-batching engine.

Fixed batch (the dry-run shape — one prefill, synchronous decode):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --batch 4 --prompt-len 64 --gen 32

Engine (request-level continuous batching over the same compiled step):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --engine \
      --slots 4 --trace 8 --arrival-rate 0.5 --gen 32

``--trace N`` synthesizes N requests with Poisson arrivals and mixed prompt
lengths; ``--requests FILE`` replays a JSON trace instead (a list of
objects with ``prompt`` or ``prompt_len``, ``max_new_tokens``, and optional
``arrival_step`` / ``temperature`` / ``top_k`` / ``top_p`` / ``seed``).

Fault tolerance (engine mode): ``--max-queue`` bounds the submit queue
with cost-aware load shedding, ``--deadline-s`` / ``--ttft-slo-s`` attach
default SLOs (cancelled mid-decode on miss), ``--journal PATH`` arms the
write-ahead request journal for crash recovery, and ``--virtual-clock`` /
``--step-time-s`` run the SLO clock deterministically.  Shed / quarantine
verdicts print per request; the summary grows a fault-tolerance line.

``--trace-out PATH`` dumps the run's ``repro.obs`` span timeline (request
lifecycles, engine decode steps, pool-utilization counters) as Chrome
trace-event JSON — open it at https://ui.perfetto.dev or chrome://tracing.
``--flight-out PATH`` arms the post-mortem flight recorder instead: the
last-N-events ring is written there at exit, on unhandled exception, and
on engine distress (park-storm, eviction) — cheap enough to leave on in
runs where the full tracer is off.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.kernels import ops
from repro.launch.steps import make_serve_step
from repro.models.registry import get_model, train_batch_shapes


def make_trace(cfg, n: int, *, gen: int, max_prompt: int, rate: float,
               seed: int = 0):
    """Synthetic Poisson request trace (arrival steps, mixed prompt
    lengths) as plain dicts — shared with benchmarks/serving_bench.py."""
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / max(rate, 1e-6),
                                                  n))).astype(int)
    out = []
    for i in range(n):
        plen = int(rng.integers(max(4, max_prompt // 4), max_prompt + 1))
        out.append({
            "id": f"req{i}",
            "prompt": rng.integers(0, cfg.vocab_size, plen).tolist(),
            "max_new_tokens": gen,
            "arrival_step": int(arrivals[i]),
        })
    return out


def load_trace(path: str, cfg, *, gen: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for i, r in enumerate(json.load(open(path))):
        prompt = r.get("prompt")
        if prompt is None:
            prompt = rng.integers(0, cfg.vocab_size,
                                  int(r["prompt_len"])).tolist()
        out.append({**r, "id": r.get("id", f"req{i}"), "prompt": prompt,
                    "max_new_tokens": int(r.get("max_new_tokens", gen)),
                    "arrival_step": int(r.get("arrival_step", 0))})
    return out


def _to_request(r: dict):
    from repro.serve import Request, SamplingParams
    deadline = r.get("deadline_s")
    ttft_slo = r.get("ttft_slo_s")
    return Request(
        id=r["id"], prompt=np.asarray(r["prompt"], np.int32),
        max_new_tokens=r["max_new_tokens"],
        arrival_step=r.get("arrival_step", 0),
        eos_id=r.get("eos_id"),
        deadline_s=None if deadline is None else float(deadline),
        ttft_slo_s=None if ttft_slo is None else float(ttft_slo),
        sampling=SamplingParams(
            temperature=float(r.get("temperature", 0.0)),
            top_k=int(r.get("top_k", 0)),
            top_p=float(r.get("top_p", 0.0)),
            seed=int(r.get("seed", 0))))


def run_engine(cfg, params, trace, *, slots: int, cache_len: int,
               max_tokens_in_flight: int = 0, prefill_chunk: int = 0,
               prefill_bucket: int = 0, paged=None, block_size: int = 0,
               pool_blocks: int = 0, share_prefixes=None, swap_tier=None,
               max_queue=None, deadline_s=None, ttft_slo_s=None,
               journal=None, clock=None, step_time_s=None,
               quiet: bool = False):
    from repro.serve import ForecastEngine
    engine = ForecastEngine(cfg, params, num_slots=slots,
                            cache_len=cache_len,
                            max_tokens_in_flight=max_tokens_in_flight,
                            prefill_chunk=prefill_chunk,
                            prefill_bucket=prefill_bucket,
                            paged=paged, block_size=block_size,
                            pool_blocks=pool_blocks,
                            share_prefixes=share_prefixes,
                            swap_tier=swap_tier,
                            max_queue=max_queue,
                            default_deadline_s=deadline_s,
                            default_ttft_slo_s=ttft_slo_s,
                            journal=journal, clock=clock,
                            step_time_s=step_time_s)
    for r in trace:
        verdict = engine.submit(_to_request(r))
        if not verdict.ok and not quiet:
            # surface backpressure to the caller: a shed request should be
            # retried after retry_after_s, a quarantined one should not
            print(f"submit {verdict.id}: {verdict.verdict}"
                  + (f" (retry after {verdict.retry_after_s:.2f}s)"
                     if verdict.verdict == "shed" else "")
                  + (f" [{verdict.reason}]" if verdict.reason else ""))
    done = engine.run()
    summ = engine.metrics.summary()
    if not quiet:
        pool_kind = (f"paged ({engine.pool.pool_blocks} blocks x "
                     f"{engine.pool.block_size})" if engine.paged
                     else "contiguous lanes")
        print(f"engine: {summ['requests']} requests, "
              f"{summ['decode_tokens']} tokens in {summ['decode_steps']} "
              f"steps ({summ['tok_per_s']:.1f} tok/s aggregate, "
              f"{summ['steady_tok_per_s']:.1f} tok/s steady decode)")
        print(f"        mean TTFT {summ['mean_ttft_s'] * 1e3:.0f}ms, "
              f"occupancy {summ['mean_occupancy']:.2f}, block util "
              f"{summ['mean_block_utilization']:.2f} [{pool_kind}], "
              f"peak in-flight {summ['peak_in_flight']}, "
              f"parked {summ['parked_events']}, "
              f"evicted {summ['evictions']}, "
              f"fragmentation {summ['mean_fragmentation']:.2f} mean / "
              f"{summ['peak_fragmentation']:.2f} peak, "
              f"compiled serve_step signatures: "
              f"{engine.num_step_signatures()}")
        if (summ["shed"] or summ["deadline_misses"] or summ["quarantined"]
                or engine.journal is not None):
            print(f"        fault tolerance: {summ['shed']} shed, "
                  f"{summ['deadline_misses']} deadline-missed "
                  f"({summ['ttft_slo_misses']} TTFT-SLO), "
                  f"{summ['quarantined']} quarantined, "
                  f"deadline miss rate {summ['deadline_miss_rate']:.3f}"
                  + (f", journal {engine.journal.path}"
                     if engine.journal is not None else ""))
        if engine.paged and (engine.share_prefixes or engine.swap_tier):
            print(f"        prefix sharing: {summ['share_hits']} hits "
                  f"({summ['full_prompt_hits']} full-prompt, "
                  f"{summ['shared_blocks']} blocks shared, "
                  f"{summ['cow_copies']} CoW copies), swap tier: "
                  f"{summ['swap_outs']} out / {summ['swap_ins']} in "
                  f"({summ['swap_out_bytes']} B out)")
    return done, summ, engine


def run_fixed_batch(cfg, params, api, *, batch: int, prompt_len: int,
                    gen: int) -> None:
    B, P = batch, prompt_len
    total = P + gen

    rng = np.random.default_rng(0)
    fb = {}
    shapes = train_batch_shapes(cfg, B, P)
    shapes.pop("labels")
    for k, (shp, dt) in shapes.items():
        if dt == jnp.int32:
            fb[k] = jnp.asarray(rng.integers(0, cfg.vocab_size, shp),
                                jnp.int32)
        else:
            fb[k] = jnp.zeros(shp, dt)

    t0 = time.time()
    cache, logits = api.prefill(params, cfg, fb, cache_len=total)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{P} in {t_prefill:.2f}s "
          f"({B * P / t_prefill:.0f} tok/s)")

    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    # prompt positions vary per family (vlm prepends image tokens)
    pos0 = P + (cfg.vlm.num_image_tokens if cfg.family == "vlm" else 0)

    # warmup: the first step carries jit compile time — time it apart so
    # the reported decode throughput is steady-state
    t0 = time.time()
    tok, cache = serve(params, cache,
                       {"token": tok, "pos": jnp.asarray(pos0, jnp.int32)})
    jax.block_until_ready(tok)
    t_warm = time.time() - t0
    generated.append(np.asarray(tok))

    t0 = time.time()
    for i in range(1, gen):
        tok, cache = serve(params, cache,
                           {"token": tok, "pos": jnp.asarray(pos0 + i,
                                                             jnp.int32)})
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.time() - t0
    out = np.concatenate(generated, axis=1)
    steady = B * (gen - 1) / dt if gen > 1 else 0.0
    print(f"decode warmup (incl. jit): 1 step x {B} seqs in {t_warm:.2f}s")
    print(f"decode steady-state: {gen - 1} steps x {B} seqs in {dt:.2f}s "
          f"({steady:.1f} tok/s)")
    print(f"sample continuation (seq 0): {out[0][:16].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full-config", dest="smoke", action="store_false")
    # engine mode
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine instead of one fixed "
                         "batch")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=0,
                    help="per-slot ring length (default prompt+gen)")
    ap.add_argument("--trace", type=int, default=0,
                    help="synthesize N Poisson-arrival requests")
    ap.add_argument("--requests", default="",
                    help="JSON request trace file (see module docstring)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="mean arrivals per engine step (--trace)")
    ap.add_argument("--max-tokens-in-flight", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--prefill-bucket", type=int, default=0)
    ap.add_argument("--trace-seed", type=int, default=0)
    # paged block-KV pool (default: auto — on for uniform-ring dense/moe)
    ap.add_argument("--paged", dest="paged", action="store_const", const=True,
                    default=None, help="force the paged block-KV pool")
    ap.add_argument("--no-paged", dest="paged", action="store_const",
                    const=False, help="force contiguous per-slot lanes")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged pool block size (0 = divisor of the ring "
                         "nearest REPRO_PAGED_BLOCK, default 16)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="physical blocks in the paged pool (0 = full "
                         "capacity slots*blocks_per_slot; less "
                         "oversubscribes lanes against real footprints)")
    ap.add_argument("--share-prefixes", dest="share_prefixes",
                    action="store_const", const=True, default=None,
                    help="copy-on-write prefix sharing across lanes "
                         "(default on for paged pools; "
                         "REPRO_PREFIX_SHARE=0 disables)")
    ap.add_argument("--no-share-prefixes", dest="share_prefixes",
                    action="store_const", const=False,
                    help="disable prefix sharing (every lane owns private "
                         "blocks)")
    ap.add_argument("--swap-tier", dest="swap_tier", action="store_const",
                    const=True, default=None,
                    help="host-memory swap tier for displaced lanes "
                         "(default on for paged pools; REPRO_SWAP_TIER=0 "
                         "disables)")
    ap.add_argument("--no-swap-tier", dest="swap_tier", action="store_const",
                    const=False,
                    help="disable the swap tier (displaced lanes recompute)")
    # fault tolerance (engine mode; see repro.serve.engine docstring)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded submit queue: admission backpressure "
                         "sheds the cheapest-to-retry queued request when "
                         "full (0 = unbounded; REPRO_SERVE_MAX_QUEUE)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="default whole-request deadline in engine-clock "
                         "seconds (REPRO_SERVE_DEADLINE_S); per-request "
                         "deadline_s in a --requests trace overrides")
    ap.add_argument("--ttft-slo-s", type=float, default=None,
                    help="default first-token SLO in engine-clock seconds "
                         "(REPRO_SERVE_TTFT_SLO_S)")
    ap.add_argument("--journal", default="",
                    help="write-ahead request journal path: submits/tokens/"
                         "finishes are logged so a crashed engine replays "
                         "unfinished requests bit-identically "
                         "(REPRO_SERVE_JOURNAL)")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="run SLO deadlines on fault.clock.VirtualClock "
                         "(each engine step advances --step-time-s) instead "
                         "of wall time — deterministic deadline tests")
    ap.add_argument("--step-time-s", type=float, default=None,
                    help="virtual seconds per engine step under "
                         "--virtual-clock (REPRO_SERVE_STEP_S, default "
                         "0.05)")
    ap.add_argument("--trace-out", default="",
                    help="write the repro.obs span timeline as Chrome "
                         "trace-event JSON (Perfetto / chrome://tracing)")
    ap.add_argument("--flight-out", default="",
                    help="arm the crash-dump flight recorder: write the "
                         "last-N-events ring here at exit / on exception / "
                         "on engine distress (park-storm, evict) — works "
                         "with REPRO_TRACE=0")
    args = ap.parse_args()

    if args.flight_out:
        import os
        os.environ["REPRO_FLIGHT_OUT"] = args.flight_out

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    print(f"decode path: {ops.decode_mode()}")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))

    if args.engine:
        if args.requests:
            trace = load_trace(args.requests, cfg, gen=args.gen,
                               seed=args.trace_seed)
        else:
            trace = make_trace(cfg, args.trace or 8, gen=args.gen,
                               max_prompt=args.prompt_len,
                               rate=args.arrival_rate, seed=args.trace_seed)
        cache_len = args.cache_len or max(
            len(r["prompt"]) + r["max_new_tokens"] for r in trace)
        clock = None
        if args.virtual_clock:
            from repro.fault.clock import VirtualClock
            clock = VirtualClock()
        run_engine(cfg, params, trace, slots=args.slots, cache_len=cache_len,
                   max_tokens_in_flight=args.max_tokens_in_flight,
                   prefill_chunk=args.prefill_chunk,
                   prefill_bucket=args.prefill_bucket,
                   paged=args.paged, block_size=args.block_size,
                   pool_blocks=args.pool_blocks,
                   share_prefixes=args.share_prefixes,
                   swap_tier=args.swap_tier,
                   max_queue=args.max_queue or None,
                   deadline_s=args.deadline_s,
                   ttft_slo_s=args.ttft_slo_s,
                   journal=args.journal or None,
                   clock=clock, step_time_s=args.step_time_s)
    else:
        run_fixed_batch(cfg, params, api, batch=args.batch,
                        prompt_len=args.prompt_len, gen=args.gen)

    if args.trace_out:
        from repro.obs import bench_gate
        path = obs.dump(args.trace_out, provenance=bench_gate.provenance())
        print(f"trace: wrote {path} "
              f"(open at https://ui.perfetto.dev or chrome://tracing)")


if __name__ == "__main__":
    main()
