"""Fused QLoRA matmul Pallas TPU kernel:  y = x · dequant_nf4(Wq) + s·(x·A)·B

This is FedTime's compute hot spot: every frozen linear of the backbone is
NF4-quantized with a trainable LoRA bypass (paper C2).  On GPU this is a
bitsandbytes CUDA kernel; the TPU adaptation (DESIGN.md §3) streams packed
uint8 codes HBM→VMEM, dequantizes tiles in-register via a one-hot·codebook
matmul (MXU-friendly — no gather needed), and accumulates both the base and
the low-rank paths in VMEM scratch across the K grid axis.

Layout contract (matches repro.core.quant when N % qblock == 0):
  w_nf4   uint8 (K, N//2)  — two 4-bit codes per byte along N
  absmax  f32   (K, N//qblock) — per-(row, column-block) scale
  lora_a  f32   (K, r), lora_b f32 (r, N), scale scalar

Tiling: grid (M/bm, N/bn, K/bk), K innermost; bn must be a multiple of
qblock; tiles 128-aligned for the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import NF4_CODE


def _kernel(x_ref, wq_ref, amax_ref, a_ref, b_ref, scale_ref, code_ref,
            o_ref, acc_ref, xa_ref, *, qblock: int, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...].astype(jnp.float32)                # (bm, bk)
    wq = wq_ref[...]                                  # (bk, bn//2) uint8
    amax = amax_ref[...]                              # (bk, bn//qblock)

    # unpack two nibbles per byte -> (bk, bn) int32 codes
    hi = (wq >> 4).astype(jnp.int32)
    lo = (wq & 0xF).astype(jnp.int32)
    bk, half = wq.shape
    bn = half * 2
    codes = jnp.stack([hi, lo], axis=-1).reshape(bk, bn)

    # dequant via one-hot @ codebook (gather-free, feeds the MXU)
    onehot = (codes[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, (bk, bn, 16), 2)
              ).astype(jnp.float32)
    w = onehot @ code_ref[...]                        # (bk, bn)
    scale = jnp.repeat(amax, qblock, axis=1)          # (bk, bn)
    w = w * scale

    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _finish():
        lora = jnp.dot(xa_ref[...], b_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] +
                      scale_ref[0] * lora).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("qblock", "bm", "bn", "bk",
                                             "interpret"))
def qlora_matmul(x, w_nf4, absmax, lora_a, lora_b, lora_scale, *,
                 qblock: int = 64, bm: int = 128, bn: int = 256,
                 bk: int = 128, interpret: bool = False):
    """x: (M, K) -> (M, N). See module docstring for layouts."""
    M, K = x.shape
    Kw, half = w_nf4.shape
    N = half * 2
    r = lora_a.shape[1]
    assert Kw == K and lora_b.shape == (r, N), (w_nf4.shape, lora_b.shape)
    assert N % qblock == 0 and bn % qblock == 0, (N, bn, qblock)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk

    scale_arr = jnp.asarray(lora_scale, jnp.float32).reshape(1)
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, qblock=qblock, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn // qblock), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((16,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w_nf4, absmax, lora_a, lora_b, scale_arr,
      jnp.asarray(NF4_CODE, jnp.float32))
