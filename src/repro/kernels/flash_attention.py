"""Flash attention Pallas TPU kernel (causal, online softmax).

Canonical TPU flash pattern: grid (B·H, S/bq, S/bk) with the KV axis
innermost; running max / denominator / accumulator persist in VMEM scratch
across KV iterations.  Causal blocks above the diagonal are skipped via a
mask (the index map still visits them; a production variant would use a
custom grid — noted in EXPERIMENTS.md §Perf as a known further win).

Used by the long-sequence prefill path on TPU; the XLA fallback is the
blockwise path in repro.models.layers.attention (same algorithm at HLO
level), which is also this kernel's oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = float(jnp.finfo(jnp.float32).min)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (bq, D)
    k = k_ref[0].astype(jnp.float32)                   # (bk, D)
    v = v_ref[0].astype(jnp.float32)                   # (bk, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = False):
    """q,k,v: (B, H, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = D ** -0.5
    n_k = S // bk
    qr = q.reshape(B * H, S, D)
    kr = k.reshape(B * H, S, D)
    vr = v.reshape(B * H, S, D)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
                          scale=scale),
        grid=(B * H, S // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, D)
