"""Fused RMSNorm Pallas kernel — the framework's most-executed pointwise op
(2 per transformer block × every block of every backbone).

One pass: mean-of-squares reduction + rsqrt + scale, tiled (rows × d) in
VMEM; f32 internal math regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (bm, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, bm: int = 256,
            interpret: bool = False):
    """x: (..., d), scale: (d,) -> same shape as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    xr = x.reshape(-1, d)
    M = xr.shape[0]
    bm = min(bm, M)
    # pad rows to a multiple of bm
    pad = (-M) % bm
    if pad:
        xr = jnp.concatenate([xr, jnp.zeros((pad, d), xr.dtype)], 0)
    Mp = xr.shape[0]

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(Mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, d), x.dtype),
        interpret=interpret,
    )(xr, scale)
    return out[:M].reshape(orig_shape)
