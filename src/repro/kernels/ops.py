"""Jit'd wrappers / dispatch layer for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container) they
run in interpret mode or fall back to the jnp oracle.  ``use_kernels()``
reflects the effective mode so model code can branch once.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.qlora_matmul import qlora_matmul as _qlora
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_kernels() -> bool:
    """Kernels are the default on TPU; REPRO_FORCE_KERNELS=1 forces
    interpret-mode execution elsewhere (slow — tests only)."""
    return on_tpu() or os.environ.get("REPRO_FORCE_KERNELS") == "1"


def qlora_matmul(x, w_nf4, absmax, lora_a, lora_b, lora_scale, **kw):
    if use_kernels():
        return _qlora(x, w_nf4, absmax, lora_a, lora_b, lora_scale,
                      interpret=not on_tpu(), **kw)
    return ref.qlora_matmul_ref(x, w_nf4, absmax, lora_a, lora_b, lora_scale)


def flash_attention(q, k, v, *, causal: bool = True, **kw):
    if use_kernels():
        return _flash(q, k, v, causal=causal, interpret=not on_tpu(), **kw)
    return ref.flash_attention_ref(q, k, v, causal=causal)


def rmsnorm(x, scale, *, eps: float = 1e-6, **kw):
    if use_kernels():
        return _rmsnorm(x, scale, eps=eps, interpret=not on_tpu(), **kw)
    return ref.rmsnorm_ref(x, scale, eps)
