"""Jit'd wrappers / dispatch layer for the Pallas kernels.

On TPU the kernels run compiled; everywhere else (this CPU container) they
run in interpret mode or fall back to the jnp oracle.  ``use_kernels()``
reflects the effective mode so model code can branch once.

Decode path: ``flash_decode`` is the serving hot loop — one token against
the ring KV cache.  On TPU it is the fused Pallas split-KV kernel
(int8-aware, GQA-packed, ring/window/prefix masking in-kernel); off-TPU it
dispatches to ``flash_decode_xla``, the same online-softmax algorithm as a
``lax.scan`` over cache blocks with fused blockwise dequant — in neither
mode is the full quantized cache ever dequantized to HBM.  Sequence-sharded
caches (``REPRO_CACHE_SHARD=seq``) go through ``repro.dist.decode``, which
calls this entry point with ``return_partials=True`` per shard and combines
the (m, l, acc) partials with a pmax/psum over the ``model`` axis.

Observability: every dispatch wraps its body in a ``jax.named_scope``
(``obs.flash_decode``, ``obs.qlora_matmul``, ...).  The scopes cost nothing
at runtime (they only name the lowered HLO), but XLA device traces and
``launch/hlo_cost`` dumps then carry the same region names as the host
spans ``repro.obs`` records around the compiled calls, so profiler
timelines line up across the host/device boundary.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.flash_decode import flash_decode_xla as _flash_decode_xla
from repro.kernels.flash_decode import paged_block_copy as _paged_block_copy
from repro.kernels.qlora_matmul import qlora_matmul as _qlora
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def use_kernels() -> bool:
    """Kernels are the default on TPU; REPRO_FORCE_KERNELS=1 forces
    interpret-mode execution elsewhere (slow — tests only)."""
    return on_tpu() or os.environ.get("REPRO_FORCE_KERNELS") == "1"


def flash_decode_enabled() -> bool:
    """Escape hatch: REPRO_FLASH_DECODE=0 restores the legacy
    dequantize-then-sdpa decode step (baselines / A-B benchmarks)."""
    return os.environ.get("REPRO_FLASH_DECODE", "1") != "0"


def decode_mode() -> str:
    """Human-readable decode dispatch (launchers print this)."""
    if not flash_decode_enabled():
        return "naive-sdpa (REPRO_FLASH_DECODE=0)"
    if on_tpu():
        return "flash_decode (pallas, compiled)"
    if use_kernels():
        return "flash_decode (pallas, interpret)"
    return "flash_decode (xla blockwise fallback)"


def qlora_matmul(x, w_nf4, absmax, lora_a, lora_b, lora_scale, **kw):
    with jax.named_scope("obs.qlora_matmul"):
        if use_kernels():
            return _qlora(x, w_nf4, absmax, lora_a, lora_b, lora_scale,
                          interpret=not on_tpu(), **kw)
        return ref.qlora_matmul_ref(x, w_nf4, absmax, lora_a, lora_b,
                                    lora_scale)


def flash_attention(q, k, v, *, causal: bool = True, **kw):
    with jax.named_scope("obs.flash_attention"):
        if use_kernels():
            return _flash(q, k, v, causal=causal, interpret=not on_tpu(),
                          **kw)
        return ref.flash_attention_ref(q, k, v, causal=causal)


def _pallas_min_s() -> int:
    """Profitability floor for the Pallas kernel: below this cache length
    the launch/grid overhead loses to one wide XLA pass, so ops.flash_decode
    dispatches to the fallback instead (read per call like every REPRO_
    flag)."""
    return int(os.environ.get("REPRO_FLASH_DECODE_MIN_S", "1024"))


def flash_decode(q, k, v, kv_pos, q_pos, **kw):
    """One decode step over the ring or paged cache; see
    ``repro.kernels.flash_decode`` for signature and semantics.  On TPU,
    caches shorter than REPRO_FLASH_DECODE_MIN_S take the XLA path (kernel
    launch not profitable); forced-interpret mode keeps the kernel so CI
    exercises it at test sizes."""
    with jax.named_scope("obs.flash_decode"):
        if use_kernels():
            tbl = kw.get("block_tables")
            s_logical = (tbl.shape[1] * k.shape[1] if tbl is not None
                         else k.shape[1])
            if on_tpu() and s_logical < _pallas_min_s():
                return _flash_decode_xla(q, k, v, kv_pos, q_pos, **kw)
            return _flash_decode(q, k, v, kv_pos, q_pos,
                                 interpret=not on_tpu(), **kw)
        return _flash_decode_xla(q, k, v, kv_pos, q_pos, **kw)


def block_copy(pool_leaf, src, dst, **kw):
    """Copy one physical block's tile to another within a layer-stacked
    pool leaf ``(L, n_blocks, ...)`` — the paged pool's copy-on-write data
    move.  Pallas per-layer DMA under ``use_kernels()``; elsewhere an XLA
    dynamic gather+scatter with identical semantics (the copy is exact for
    every dtype, so CoW preserves bit-identical greedy decode)."""
    with jax.named_scope("obs.block_copy"):
        if use_kernels():
            return _paged_block_copy(pool_leaf, src, dst,
                                     interpret=not on_tpu(), **kw)
        return pool_leaf.at[:, dst].set(pool_leaf[:, src])


def rmsnorm(x, scale, *, eps: float = 1e-6, **kw):
    with jax.named_scope("obs.rmsnorm"):
        if use_kernels():
            return _rmsnorm(x, scale, eps=eps, interpret=not on_tpu(), **kw)
        return ref.rmsnorm_ref(x, scale, eps)
