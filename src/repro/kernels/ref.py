"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import nf4_dequant


def qlora_matmul_ref(x, w_nf4, absmax, lora_a, lora_b, lora_scale):
    """y = x · dequant(Wq) + s·(x·A)·B, all in f32."""
    K, half = w_nf4.shape
    N = half * 2
    nb_per_row = absmax.shape[-1]
    # kernel layout: absmax is (K, N//qblock); core.quant dequant expects
    # flat row-major blocks — identical when qblock | N.
    w = nf4_dequant(w_nf4, absmax.reshape(-1))
    base = x.astype(jnp.float32) @ w
    lora = (x.astype(jnp.float32) @ lora_a.astype(jnp.float32)) @ \
        lora_b.astype(jnp.float32)
    return (base + jnp.asarray(lora_scale, jnp.float32) * lora).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (B, H, S, D) -> (B, H, S, D), f32 softmax."""
    S = q.shape[2]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """x: (..., d)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * (var + eps) ** -0.5 *
            scale.astype(jnp.float32)).astype(x.dtype)
