"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import nf4_dequant


def qlora_matmul_ref(x, w_nf4, absmax, lora_a, lora_b, lora_scale):
    """y = x · dequant(Wq) + s·(x·A)·B, all in f32."""
    K, half = w_nf4.shape
    N = half * 2
    nb_per_row = absmax.shape[-1]
    # kernel layout: absmax is (K, N//qblock); core.quant dequant expects
    # flat row-major blocks — identical when qblock | N.
    w = nf4_dequant(w_nf4, absmax.reshape(-1))
    base = x.astype(jnp.float32) @ w
    lora = (x.astype(jnp.float32) @ lora_a.astype(jnp.float32)) @ \
        lora_b.astype(jnp.float32)
    return (base + jnp.asarray(lora_scale, jnp.float32) * lora).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (B, H, S, D) -> (B, H, S, D), f32 softmax."""
    S = q.shape[2]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def flash_decode_ref(q, k, v, kv_pos, q_pos, *, k_scale=None, v_scale=None,
                     kind: str = "causal", window: int = 0, prefix_len=None,
                     softcap: float = 0.0, block_tables=None, **_unused):
    """Naive decode-step oracle: dequantize the whole cache, materialize the
    full (H, S) score matrix, f32 softmax.  q: (B, 1, H, D); k, v:
    (B, S, Hk, D) (+ (B, S, Hk, 1) absmax scales for int8 caches); kv_pos:
    (B, S) absolute slot positions (-1 == empty); q_pos scalar or (B,).
    ``block_tables`` (B, T): k/v are an (n_blocks, block_size, Hk, D) paged
    pool instead — gathered to the logical (B, T*block_size) view first."""
    if block_tables is not None:
        from repro.kernels.flash_decode import paged_gather
        k, v, kv_pos, k_scale, v_scale = paged_gather(
            k, v, kv_pos, k_scale, v_scale, block_tables)
    B, S, Hk, D = k.shape
    H = q.shape[2]
    G = H // Hk
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)
        vf = vf * v_scale.astype(jnp.float32)
    qg = q[:, 0].reshape(B, Hk, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, kf,
                   preferred_element_type=jnp.float32) * D ** -0.5
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    kv_pos = jnp.asarray(kv_pos, jnp.int32)
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (B, S))
    kp = kv_pos[:, None, None, :]
    qp = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32).reshape(-1),
                          (B,)).reshape(B, 1, 1, 1)
    valid = kp >= 0
    if kind == "causal":
        m = kp <= qp
    elif kind == "prefix":
        pl_ = jnp.broadcast_to(jnp.asarray(prefix_len, jnp.int32).reshape(-1),
                               (B,)).reshape(B, 1, 1, 1)
        m = (kp <= qp) | (kp < pl_)
    elif kind == "full":
        m = jnp.ones_like(valid)
    else:
        raise ValueError(kind)
    if window > 0 and kind != "full":
        m = m & (qp - kp < window)
    m = m & valid

    s = jnp.where(m, s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(m.any(-1, keepdims=True), p, 0.0)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, vf)
    return o.reshape(B, 1, H, D).astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    """x: (..., d)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * (var + eps) ** -0.5 *
            scale.astype(jnp.float32)).astype(x.dtype)
