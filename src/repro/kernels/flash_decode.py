"""Fused flash-decode Pallas TPU kernel over the ring KV cache.

One decode step: G grouped queries per KV head attend to every valid slot of
the ring buffer.  Grid is (batch, kv_head, KV blocks); the KV axis is
innermost, so each program streams one ``block_kv`` cache tile through VMEM
while a running (m, l, acc) online-softmax state persists in scratch.  The
KV axis is further carved into ``n_splits`` independent splits: each split
flushes its own partial (m, l, acc) and a final cross-split combine (plain
jnp — the payload is n_splits x G x D per head) produces the output.  This
split-KV shape is what makes single-token decode fill the chip: without it,
one (batch, head) pair maps to one core-sequential stream.

Fused into the streamed pass:
  - int8 -> f32 dequantization from the per-slot absmax scales
    (``REPRO_KV_INT8`` caches), so the quantized cache is never materialized
    in HBM at full precision;
  - ring-buffer validity / causal / prefix / sliding-window masking from the
    absolute slot positions ``kv_pos`` (slot position -1 == empty);
  - GQA query-group packing: the G queries of one KV head are one
    (G, block_kv) MXU matmul instead of G vector products.

Cache layout note: the ring cache lives as (B, S, Hk, dh).  The kernel views
k/v as (B, S, Hk*dh) — a free row-major reshape — so each BlockSpec block is
a well-tiled (block_kv, dh) slab; no transpose of the cache is ever made.

``flash_decode_xla`` is the same algorithm as a ``jax.lax.scan`` over KV
blocks (the non-TPU fallback: fused blockwise dequant, no full-cache
materialization).  Both support ``return_partials`` for the sequence-sharded
path (``repro.dist.decode``): a shard computes local (m, l, acc) over its
slots and the cross-shard combine is a pmax/psum over the ``model`` axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Finite mask fill: -inf poisons the online-softmax recurrences (exp(-inf -
# -inf) = nan) on fully-masked blocks; with a finite floor the masked
# probabilities are zeroed explicitly and every carry stays finite.
_NEG = -1e30


def _slot_mask(kp, qp, plen, *, kind: str, window: int):
    """Boolean keep-mask over KV slots from absolute positions.

    kp: (..., block) int32 slot positions (-1 == empty ring slot);
    qp / plen: scalars (or broadcastable) — the query position and prefix
    length.  Mirrors repro.models.layers.attention._mask for Sq == 1.
    """
    valid = kp >= 0
    if kind == "causal":
        m = kp <= qp
    elif kind == "prefix":
        m = (kp <= qp) | (kp < plen)
    elif kind == "full":
        m = jnp.ones_like(valid)
    else:
        raise ValueError(kind)
    if window > 0 and kind != "full":
        m = m & (qp - kp < window)
    return m & valid


def _pick_splits(n_blocks: int, requested: int) -> int:
    """Largest split count <= requested that divides the block count."""
    n = requested or (8 if n_blocks >= 32 else 4 if n_blocks >= 8 else 1)
    n = max(1, min(n, n_blocks))
    while n_blocks % n:
        n -= 1
    return n


def _combine(m, l, acc, axis: int):
    """Merge independent online-softmax partials along ``axis``:
    out = sum_i exp(m_i - m*) acc_i / sum_i exp(m_i - m*) l_i."""
    m_g = m.max(axis=axis, keepdims=True)
    w = jnp.exp(m - m_g)
    l_tot = (l * w).sum(axis=axis)
    acc_tot = (acc * w).sum(axis=axis)
    return acc_tot / jnp.maximum(l_tot, 1e-30)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _kernel(qpos_ref, plen_ref, q_ref, k_ref, v_ref, kpos_ref, *rest,
            bps: int, kind: str, window: int, softcap: float, scale: float,
            quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_m, o_l, o_acc, m_s, l_s, acc_s = rest
    else:
        o_m, o_l, o_acc, m_s, l_s, acc_s = rest
    j = pl.program_id(2)
    local = jax.lax.rem(j, bps)

    @pl.when(local == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0].astype(jnp.float32)                 # (block_kv, D)
    v = v_ref[0].astype(jnp.float32)
    if quantized:                                    # fused int8 dequant
        k = k * ks_ref[0].astype(jnp.float32)        # scales (block_kv, 1)
        v = v * vs_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kp = kpos_ref[...]                               # (1, block_kv)
    mask = _slot_mask(kp, qpos_ref[0, 0], plen_ref[0, 0],
                      kind=kind, window=window)      # (1, block_kv)
    s = jnp.where(mask, s, _NEG)

    m_prev = m_s[...]                                # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)     # (G, block_kv)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(local == bps - 1)
    def _flush():
        o_m[0, 0, 0] = m_s[...]
        o_l[0, 0, 0] = l_s[...]
        o_acc[0, 0, 0] = acc_s[...]


def _pad_inputs(q, k, v, kv_pos, k_scale, v_scale, block_kv: int):
    """Pad the KV axis to a block multiple (padded slots get position -1 so
    the validity mask drops them) and pack queries per KV head, G padded to
    the f32 sublane count."""
    B, S, Hk, D = k.shape
    H = q.shape[2]
    G = H // Hk
    g_pad = -G % 8
    qg = q.reshape(B, Hk, G, D)
    if g_pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad), (0, 0)))
    s_pad = -S % block_kv
    if s_pad:
        pad4 = ((0, 0), (0, s_pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad4), jnp.pad(v, pad4)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, s_pad)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, pad4)
            v_scale = jnp.pad(v_scale, pad4)
    return qg, k, v, kv_pos, k_scale, v_scale, G, G + g_pad


def _broadcast_pos(x, batch: int):
    x = jnp.zeros((), jnp.int32) if x is None else jnp.asarray(x, jnp.int32)
    return jnp.broadcast_to(x.reshape(-1, 1) if x.ndim else x,
                            (batch, 1)).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("kind", "window", "softcap", "block_kv",
                              "n_splits", "interpret", "return_partials"))
def flash_decode(q, k, v, kv_pos, q_pos, *, k_scale=None, v_scale=None,
                 kind: str = "causal", window: int = 0, prefix_len=None,
                 softcap: float = 0.0, block_kv: int = 512, n_splits: int = 0,
                 interpret: bool = False, return_partials: bool = False):
    """One fused decode step against the ring cache.

    q: (B, 1, H, D); k, v: (B, S, Hk, D) ring buffers (int8 when
    ``k_scale``/``v_scale`` — (B, S, Hk, 1) absmax scales — are given);
    kv_pos: (B, S) absolute slot positions (-1 == empty); q_pos: scalar or
    (B,) query position.  Returns (B, 1, H, D) in q.dtype, or the raw f32
    partials (m, l, acc) of shapes (B, Hk, G, 1)/(B, Hk, G, 1)/(B, Hk, G, D)
    when ``return_partials`` (sequence-sharded combine, repro.dist.decode).
    """
    B, S, Hk, D = k.shape
    kv_pos = jnp.asarray(kv_pos, jnp.int32)
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (B, S))
    block_kv = min(block_kv, -(-S // 128) * 128)
    quantized = k_scale is not None
    qg, k, v, kv_pos, k_scale, v_scale, G, G_pad = _pad_inputs(
        q, k, v, kv_pos, k_scale, v_scale, block_kv)
    S_pad = k.shape[1]
    n_blocks = S_pad // block_kv
    n_splits = _pick_splits(n_blocks, n_splits)
    bps = n_blocks // n_splits

    # (B, S, Hk, D) -> (B, S, Hk*D): free reshape that turns each per-head
    # KV tile into a contiguous, well-tiled (block_kv, D) block.
    kr = k.reshape(B, S_pad, Hk * D)
    vr = v.reshape(B, S_pad, Hk * D)
    qp = _broadcast_pos(q_pos, B)
    plen = _broadcast_pos(prefix_len, B)

    smem = lambda: pl.BlockSpec((1, 1), lambda b, h, j: (b, 0),  # noqa: E731
                                memory_space=pltpu.SMEM)
    in_specs = [
        smem(), smem(),
        pl.BlockSpec((1, 1, G_pad, D), lambda b, h, j: (b, h, 0, 0)),
        pl.BlockSpec((1, block_kv, D), lambda b, h, j: (b, j, h)),
        pl.BlockSpec((1, block_kv, D), lambda b, h, j: (b, j, h)),
        pl.BlockSpec((1, block_kv), lambda b, h, j: (b, j)),
    ]
    args = [qp, plen, qg, kr, vr, kv_pos]
    if quantized:
        in_specs += [pl.BlockSpec((1, block_kv, 1), lambda b, h, j: (b, j, h)),
                     pl.BlockSpec((1, block_kv, 1), lambda b, h, j: (b, j, h))]
        args += [k_scale.reshape(B, S_pad, Hk),
                 v_scale.reshape(B, S_pad, Hk)]

    out_specs = [
        pl.BlockSpec((1, 1, 1, G_pad, 1),
                     lambda b, h, j, _bps=bps: (b, h, j // _bps, 0, 0)),
        pl.BlockSpec((1, 1, 1, G_pad, 1),
                     lambda b, h, j, _bps=bps: (b, h, j // _bps, 0, 0)),
        pl.BlockSpec((1, 1, 1, G_pad, D),
                     lambda b, h, j, _bps=bps: (b, h, j // _bps, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, Hk, n_splits, G_pad, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hk, n_splits, G_pad, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hk, n_splits, G_pad, D), jnp.float32),
    ]

    m, l, acc = pl.pallas_call(
        functools.partial(_kernel, bps=bps, kind=kind, window=window,
                          softcap=softcap, scale=D ** -0.5,
                          quantized=quantized),
        grid=(B, Hk, n_blocks),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((G_pad, 1), jnp.float32),
            pltpu.VMEM((G_pad, 1), jnp.float32),
            pltpu.VMEM((G_pad, D), jnp.float32),
        ],
        interpret=interpret,
    )(*args)

    m, l, acc = m[:, :, :, :G], l[:, :, :, :G], acc[:, :, :, :G]
    if return_partials:
        m_loc = m.max(axis=2)
        w = jnp.exp(m - m.max(axis=2, keepdims=True))
        return m_loc, (l * w).sum(axis=2), (acc * w).sum(axis=2)
    out = _combine(m, l, acc, axis=2)                # (B, Hk, G, D)
    return out.reshape(B, 1, Hk * G, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# XLA fallback: identical algorithm as a scan over KV blocks (fused
# blockwise dequant — the quantized cache is never materialized whole)
# ---------------------------------------------------------------------------

def flash_decode_xla(q, k, v, kv_pos, q_pos, *, k_scale=None, v_scale=None,
                     kind: str = "causal", window: int = 0, prefix_len=None,
                     softcap: float = 0.0, block_kv: int = 512,
                     return_partials: bool = False, **_unused):
    """Same signature/semantics as ``flash_decode`` without Pallas: a
    ``lax.scan`` over block_kv-sized cache tiles with in-block dequant and
    online softmax — O(block) temporaries instead of O(cache_len)."""
    B, S, Hk, D = k.shape
    kv_pos = jnp.asarray(kv_pos, jnp.int32)
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (B, S))
    block_kv = min(block_kv, S)
    quantized = k_scale is not None
    qg, k, v, kv_pos, k_scale, v_scale, G, _ = _pad_inputs(
        q, k, v, kv_pos, k_scale, v_scale, block_kv)
    qg = qg[:, :, :G].astype(jnp.float32)            # no sublane padding here
    S_pad = k.shape[1]
    nb = S_pad // block_kv
    scale = D ** -0.5
    qp = _broadcast_pos(q_pos, B)[:, :, None, None]  # (B, 1, 1, 1)
    plen = _broadcast_pos(prefix_len, B)[:, :, None, None]

    def to_blocks(x):
        return x.reshape((B, nb, block_kv) + x.shape[2:]).swapaxes(0, 1)

    blocks = [to_blocks(k), to_blocks(v), to_blocks(kv_pos)]
    if quantized:
        blocks += [to_blocks(k_scale), to_blocks(v_scale)]

    def kv_step(carry, blk):
        m_run, l_run, acc = carry
        if quantized:
            kb, vb, kpb, ksb, vsb = blk
            kb = kb.astype(jnp.float32) * ksb.astype(jnp.float32)
            vb = vb.astype(jnp.float32) * vsb.astype(jnp.float32)
        else:
            kb, vb, kpb = blk
            kb, vb = kb.astype(jnp.float32), vb.astype(jnp.float32)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = _slot_mask(kpb[:, None, None, :], qp, plen,
                          kind=kind, window=window)  # (B, 1, 1, block_kv)
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m_run, s.max(-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum(
            "bhgk,bkhd->bhgd", p, vb, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, G, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, 1), jnp.float32)
    a0 = jnp.zeros((B, Hk, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), tuple(blocks))
    if return_partials:
        return m, l, acc
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, 1, Hk * G, D).astype(q.dtype)
