"""Fused flash-decode Pallas TPU kernel over ring or paged block KV caches.

One decode step: G grouped queries per KV head attend to every valid slot of
the cache.  Grid is (batch, kv_head, KV blocks); the KV axis is innermost,
so each program streams one cache tile through VMEM while a running
(m, l, acc) online-softmax state persists in scratch.  The KV axis is
further carved into ``n_splits`` independent splits: each split flushes its
own partial (m, l, acc) and a final cross-split combine (plain jnp — the
payload is n_splits x G x D per head) produces the output.  This split-KV
shape is what makes single-token decode fill the chip: without it, one
(batch, head) pair maps to one core-sequential stream.

Fused into the streamed pass:
  - int8 -> f32 dequantization from the per-slot absmax scales
    (``REPRO_KV_INT8`` caches), so the quantized cache is never materialized
    in HBM at full precision;
  - ring-buffer validity / causal / prefix / sliding-window masking from the
    absolute slot positions ``kv_pos`` (slot position -1 == empty);
  - GQA query-group packing: the G queries of one KV head are one
    (G, block_kv) MXU matmul instead of G vector products.

Two cache layouts share the kernel body:

  * contiguous ring (the training / fixed-batch shape): k/v are
    (B, S, Hk, dh) per-request rings, one tile is a ``block_kv`` slice.
  * paged block pool (the serving engine's layout): k/v are
    (n_blocks, block_size, Hk, dh) — ONE pool shared by every request —
    and ``block_tables`` (B, T) maps each request's logical block j to a
    physical pool block (-1 == not granted).  The table is a
    scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``): the BlockSpec
    index_map dereferences it, so each program DMAs exactly the tile the
    table names — the pool is never gathered in HBM.  Ungranted entries
    stream pool block 0 and are masked wholesale in-kernel.  Tables are
    READ-ONLY to the kernel, so one physical block may appear in many
    tables at once (copy-on-write prefix sharing): every sharer streams the
    same tile, and slots a sharer hasn't logically reached are excluded by
    the causal/ring masks, not by table bookkeeping.  On real TPUs
    ``block_size`` should be a multiple of the 128-lane tile; the serving
    smoke configs use smaller blocks under interpret mode.

``paged_block_copy`` is the pool's copy-on-write data move: one physical
block's tile duplicated to another block across all layers of a
layer-stacked pool leaf, with the src/dst pair riding scalar prefetch so
the copy is a pure per-layer DMA (no gather of the pool).

Block policy (``block_kv``/``n_splits`` <= 0 selects it): tile and split
counts are derived from the cache length instead of fixed defaults —
short caches get fewer, wider tiles; long caches cap the tile at 1024 and
let ``_pick_splits`` fill the chip.  ``flash_decode_xla`` is the same
algorithm without Pallas, with a measured two-regime policy: up to
``REPRO_DECODE_WIDE_MAX`` (4096) slots a single-pass "wide" form (int8
codes transposed *before* dequant — half the transpose traffic of
dequant-then-transpose, the reason the old blockwise scan lost to naive
sdpa at 4k; it does materialize one O(S) f32 copy, the accepted trade at
short S), above it a ``jax.lax.scan`` over 2048-slot tiles with in-scan
dequant (O(block) temporaries).  Both support ``return_partials`` for the sequence-sharded
path (``repro.dist.decode``): a shard computes local (m, l, acc) over its
slots and the cross-shard combine is a pmax/psum over the ``model`` axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Finite mask fill: -inf poisons the online-softmax recurrences (exp(-inf -
# -inf) = nan) on fully-masked blocks; with a finite floor the masked
# probabilities are zeroed explicitly and every carry stays finite.
_NEG = -1e30

# XLA-fallback policy boundary: at/below this cache length the single-pass
# wide form beats the blockwise scan (measured on the kernels bench: the
# scan's per-block overhead + full-cache transpose lost to naive sdpa at 4k,
# 0.5x); above it the scan's O(block) temporaries win (1.4x at 32k).  The
# wide form deliberately trades an O(S) f32 temporary for speed, so the
# boundary stays at the measured 4k crossover and is env-tunable
# (REPRO_DECODE_WIDE_MAX=0 restores scan-always for memory-tight hosts).
_SCAN_BLOCK_KV = 2048


def _wide_max_s() -> int:
    import os
    return int(os.environ.get("REPRO_DECODE_WIDE_MAX", "4096"))


def _slot_mask(kp, qp, plen, *, kind: str, window: int):
    """Boolean keep-mask over KV slots from absolute positions.

    kp: (..., block) int32 slot positions (-1 == empty ring slot);
    qp / plen: scalars (or broadcastable) — the query position and prefix
    length.  Mirrors repro.models.layers.attention._mask for Sq == 1.
    """
    valid = kp >= 0
    if kind == "causal":
        m = kp <= qp
    elif kind == "prefix":
        m = (kp <= qp) | (kp < plen)
    elif kind == "full":
        m = jnp.ones_like(valid)
    else:
        raise ValueError(kind)
    if window > 0 and kind != "full":
        m = m & (qp - kp < window)
    return m & valid


def _pick_splits(n_blocks: int, requested: int) -> int:
    """Largest split count <= requested that divides the block count."""
    n = requested or (8 if n_blocks >= 32 else 4 if n_blocks >= 8 else 1)
    n = max(1, min(n, n_blocks))
    while n_blocks % n:
        n -= 1
    return n


def _auto_block_kv(S: int) -> int:
    """Pallas KV tile from the cache length: target ~16 tiles (split-KV
    parallelism) without dropping below the 128-lane tile or ballooning
    VMEM past a 1024-slot slab."""
    per = -(-S // 16)
    per = -(-per // 128) * 128
    return int(max(128, min(1024, per)))


def _combine(m, l, acc, axis: int):
    """Merge independent online-softmax partials along ``axis``:
    out = sum_i exp(m_i - m*) acc_i / sum_i exp(m_i - m*) l_i."""
    m_g = m.max(axis=axis, keepdims=True)
    w = jnp.exp(m - m_g)
    l_tot = (l * w).sum(axis=axis)
    acc_tot = (acc * w).sum(axis=axis)
    return acc_tot / jnp.maximum(l_tot, 1e-30)


def paged_gather(k, v, kv_pos, k_scale, v_scale, block_tables):
    """Materialize the (B, T*block_size) logical cache view of a paged pool.

    k/v: (n_blocks, bs, Hk, dh) pool; block_tables: (B, T) physical block
    ids (-1 == ungranted — its slots come back with position -1, i.e.
    masked).  The gathered view is bit-identical to the contiguous ring it
    replaces when T*bs equals the ring length, which is what keeps paged
    greedy decode exactly equal to the contiguous pool's.  (Off-TPU
    fallback + oracle only — the Pallas kernel indexes the pool in place.)
    """
    tbl = jnp.asarray(block_tables, jnp.int32)
    B, T = tbl.shape
    nb = k.shape[0]
    safe = jnp.clip(tbl, 0, nb - 1)

    def g(x):
        y = x[safe]                              # (B, T, bs, ...)
        return y.reshape((B, T * x.shape[1]) + x.shape[2:])

    kv_pos_g = jnp.where(tbl[:, :, None] >= 0, kv_pos[safe], -1)
    kv_pos_g = kv_pos_g.reshape(B, T * kv_pos.shape[1])
    ks = g(k_scale) if k_scale is not None else None
    vs = g(v_scale) if v_scale is not None else None
    return g(k), g(v), kv_pos_g, ks, vs


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _kernel(*refs, bps: int, kind: str, window: int, softcap: float,
            scale: float, quantized: bool, paged: bool):
    if paged:
        tbl_ref, *refs = refs                    # scalar-prefetch operand
    if quantized:
        (qpos_ref, plen_ref, q_ref, k_ref, v_ref, kpos_ref, ks_ref, vs_ref,
         o_m, o_l, o_acc, m_s, l_s, acc_s) = refs
    else:
        (qpos_ref, plen_ref, q_ref, k_ref, v_ref, kpos_ref,
         o_m, o_l, o_acc, m_s, l_s, acc_s) = refs
    j = pl.program_id(2)
    local = jax.lax.rem(j, bps)

    @pl.when(local == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = k_ref[0].astype(jnp.float32)                 # (block_kv, D)
    v = v_ref[0].astype(jnp.float32)
    if quantized:                                    # fused int8 dequant
        k = k * ks_ref[0].astype(jnp.float32)        # scales (block_kv, 1)
        v = v * vs_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kp = kpos_ref[...]                               # (1, block_kv)
    mask = _slot_mask(kp, qpos_ref[0, 0], plen_ref[0, 0],
                      kind=kind, window=window)      # (1, block_kv)
    if paged:
        # ungranted table entries stream pool block 0 — drop them wholesale
        # (a freed block's stale kv_pos may otherwise pass the ring mask)
        mask = mask & (tbl_ref[pl.program_id(0), j] >= 0)
    s = jnp.where(mask, s, _NEG)

    m_prev = m_s[...]                                # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)     # (G, block_kv)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(local == bps - 1)
    def _flush():
        o_m[0, 0, 0] = m_s[...]
        o_l[0, 0, 0] = l_s[...]
        o_acc[0, 0, 0] = acc_s[...]


def _pack_queries(q, Hk: int):
    """(B, 1, H, D) -> (B, Hk, G_pad, D): GQA groups packed per KV head, G
    padded to the f32 sublane count."""
    B, _, H, D = q.shape
    G = H // Hk
    qg = q.reshape(B, Hk, G, D)
    g_pad = -G % 8
    if g_pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, g_pad), (0, 0)))
    return qg, G, G + g_pad


def _pad_inputs(q, k, v, kv_pos, k_scale, v_scale, block_kv: int):
    """Pad the KV axis to a block multiple (padded slots get position -1 so
    the validity mask drops them) and pack queries per KV head."""
    B, S, Hk, D = k.shape
    qg, G, G_pad = _pack_queries(q, Hk)
    s_pad = -S % block_kv
    if s_pad:
        pad4 = ((0, 0), (0, s_pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad4), jnp.pad(v, pad4)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, s_pad)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, pad4)
            v_scale = jnp.pad(v_scale, pad4)
    return qg, k, v, kv_pos, k_scale, v_scale, G, G_pad


def _broadcast_pos(x, batch: int):
    x = jnp.zeros((), jnp.int32) if x is None else jnp.asarray(x, jnp.int32)
    return jnp.broadcast_to(x.reshape(-1, 1) if x.ndim else x,
                            (batch, 1)).astype(jnp.int32)


def _partial_outputs(B: int, Hk: int, n_splits: int, G_pad: int, D: int,
                     bps: int):
    """(out_specs, out_shape, scratch_shapes) for the per-split (m, l, acc)
    partials — shared by the contiguous and paged launches (the index_map
    takes the paged launch's trailing scalar-prefetch table arg as *_)."""
    def idx(b, h, j, *_, _bps=bps):
        return (b, h, j // _bps, 0, 0)

    out_specs = [
        pl.BlockSpec((1, 1, 1, G_pad, 1), idx),
        pl.BlockSpec((1, 1, 1, G_pad, 1), idx),
        pl.BlockSpec((1, 1, 1, G_pad, D), idx),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, Hk, n_splits, G_pad, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hk, n_splits, G_pad, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Hk, n_splits, G_pad, D), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((G_pad, 1), jnp.float32),
        pltpu.VMEM((G_pad, 1), jnp.float32),
        pltpu.VMEM((G_pad, D), jnp.float32),
    ]
    return out_specs, out_shape, scratch


def _finish(m, l, acc, G: int, q, return_partials: bool):
    """Slice off G padding and either combine splits or hand back partials
    (axis 2 is the split axis)."""
    m, l, acc = m[:, :, :, :G], l[:, :, :, :G], acc[:, :, :, :G]
    if return_partials:
        m_loc = m.max(axis=2)
        w = jnp.exp(m - m.max(axis=2, keepdims=True))
        return m_loc, (l * w).sum(axis=2), (acc * w).sum(axis=2)
    out = _combine(m, l, acc, axis=2)                # (B, Hk, G, D)
    B, Hk, _, D = out.shape
    return out.reshape(B, 1, Hk * G, D).astype(q.dtype)


@functools.partial(
    jax.jit, static_argnames=("kind", "window", "softcap", "block_kv",
                              "n_splits", "interpret", "return_partials"))
def flash_decode(q, k, v, kv_pos, q_pos, *, k_scale=None, v_scale=None,
                 kind: str = "causal", window: int = 0, prefix_len=None,
                 softcap: float = 0.0, block_kv: int = 0, n_splits: int = 0,
                 block_tables=None, interpret: bool = False,
                 return_partials: bool = False):
    """One fused decode step against the ring (or paged) cache.

    q: (B, 1, H, D); k, v: (B, S, Hk, D) ring buffers, or — with
    ``block_tables`` (B, T) — an (n_blocks, block_size, Hk, D) shared pool
    (int8 when ``k_scale``/``v_scale`` absmax scales are given, shaped like
    k/v with a trailing 1); kv_pos: (B, S) / (n_blocks, block_size) absolute
    slot positions (-1 == empty); q_pos: scalar or (B,) query position.
    ``block_kv``/``n_splits`` <= 0 derive the tile/split counts from the
    cache length (paged tiles are always one pool block).  Returns
    (B, 1, H, D) in q.dtype, or the raw f32 partials (m, l, acc) of shapes
    (B, Hk, G, 1)/(B, Hk, G, 1)/(B, Hk, G, D) when ``return_partials``
    (sequence-sharded combine, repro.dist.decode).
    """
    if block_tables is not None:
        return _flash_decode_paged(
            q, k, v, kv_pos, block_tables, q_pos, k_scale=k_scale,
            v_scale=v_scale, kind=kind, window=window, prefix_len=prefix_len,
            softcap=softcap, n_splits=n_splits, interpret=interpret,
            return_partials=return_partials)
    B, S, Hk, D = k.shape
    kv_pos = jnp.asarray(kv_pos, jnp.int32)
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (B, S))
    if block_kv <= 0:
        block_kv = _auto_block_kv(S)
    block_kv = min(block_kv, -(-S // 128) * 128)
    quantized = k_scale is not None
    qg, k, v, kv_pos, k_scale, v_scale, G, G_pad = _pad_inputs(
        q, k, v, kv_pos, k_scale, v_scale, block_kv)
    S_pad = k.shape[1]
    n_blocks = S_pad // block_kv
    n_splits = _pick_splits(n_blocks, n_splits)
    bps = n_blocks // n_splits

    # (B, S, Hk, D) -> (B, S, Hk*D): free reshape that turns each per-head
    # KV tile into a contiguous, well-tiled (block_kv, D) slab.
    kr = k.reshape(B, S_pad, Hk * D)
    vr = v.reshape(B, S_pad, Hk * D)
    qp = _broadcast_pos(q_pos, B)
    plen = _broadcast_pos(prefix_len, B)

    smem = lambda: pl.BlockSpec((1, 1), lambda b, h, j: (b, 0),  # noqa: E731
                                memory_space=pltpu.SMEM)
    in_specs = [
        smem(), smem(),
        pl.BlockSpec((1, 1, G_pad, D), lambda b, h, j: (b, h, 0, 0)),
        pl.BlockSpec((1, block_kv, D), lambda b, h, j: (b, j, h)),
        pl.BlockSpec((1, block_kv, D), lambda b, h, j: (b, j, h)),
        pl.BlockSpec((1, block_kv), lambda b, h, j: (b, j)),
    ]
    args = [qp, plen, qg, kr, vr, kv_pos]
    if quantized:
        in_specs += [pl.BlockSpec((1, block_kv, 1), lambda b, h, j: (b, j, h)),
                     pl.BlockSpec((1, block_kv, 1), lambda b, h, j: (b, j, h))]
        args += [k_scale.reshape(B, S_pad, Hk),
                 v_scale.reshape(B, S_pad, Hk)]

    out_specs, out_shape, scratch = _partial_outputs(B, Hk, n_splits, G_pad,
                                                     D, bps)
    m, l, acc = pl.pallas_call(
        functools.partial(_kernel, bps=bps, kind=kind, window=window,
                          softcap=softcap, scale=D ** -0.5,
                          quantized=quantized, paged=False),
        grid=(B, Hk, n_blocks),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(*args)
    return _finish(m, l, acc, G, q, return_partials)


def _flash_decode_paged(q, k, v, kv_pos, block_tables, q_pos, *, k_scale,
                        v_scale, kind: str, window: int, prefix_len,
                        softcap: float, n_splits: int, interpret: bool,
                        return_partials: bool):
    """Paged-pool kernel launch: grid (B, Hk, T) where T is the block-table
    width; the table is a scalar-prefetch operand and every index_map
    dereferences it, so each program streams exactly the pool tile its
    request granted — no gather, no per-request copy of the pool."""
    nb, bs, Hk, D = k.shape
    tbl = jnp.asarray(block_tables, jnp.int32)
    B, T = tbl.shape
    kv_pos = jnp.asarray(kv_pos, jnp.int32)
    qg, G, G_pad = _pack_queries(q, Hk)
    n_splits = _pick_splits(T, n_splits)
    bps = T // n_splits
    quantized = k_scale is not None

    kr = k.reshape(nb, bs, Hk * D)
    vr = v.reshape(nb, bs, Hk * D)
    qp = _broadcast_pos(q_pos, B)
    plen = _broadcast_pos(prefix_len, B)

    def pool_idx(b, h, j, t):
        return (jnp.maximum(t[b, j], 0), 0, h)

    smem = lambda: pl.BlockSpec(                                # noqa: E731
        (1, 1), lambda b, h, j, t: (b, 0), memory_space=pltpu.SMEM)
    in_specs = [
        smem(), smem(),
        pl.BlockSpec((1, 1, G_pad, D), lambda b, h, j, t: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, D), pool_idx),
        pl.BlockSpec((1, bs, D), pool_idx),
        pl.BlockSpec((1, bs), lambda b, h, j, t: (jnp.maximum(t[b, j], 0),
                                                  0)),
    ]
    args = [qp, plen, qg, kr, vr, kv_pos]
    if quantized:
        in_specs += [pl.BlockSpec((1, bs, 1), pool_idx),
                     pl.BlockSpec((1, bs, 1), pool_idx)]
        args += [k_scale.reshape(nb, bs, Hk), v_scale.reshape(nb, bs, Hk)]

    out_specs, out_shape, scratch = _partial_outputs(B, Hk, n_splits, G_pad,
                                                     D, bps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hk, T),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch)
    m, l, acc = pl.pallas_call(
        functools.partial(_kernel, bps=bps, kind=kind, window=window,
                          softcap=softcap, scale=D ** -0.5,
                          quantized=quantized, paged=True),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(tbl, *args)
    return _finish(m, l, acc, G, q, return_partials)


def paged_block_copy(leaf, src, dst, *, interpret: bool = False):
    """Copy physical block ``src``'s tile to block ``dst`` within one
    layer-stacked pool leaf ``(L, n_blocks, ...)`` — the copy-on-write data
    move when a lane diverges from a shared prefix block.

    Grid is (L,), with the (src, dst) pair as a scalar-prefetch operand:
    each program DMAs exactly one flattened ``(1, 1, Z)`` tile out of the
    source block (the index_map dereferences ``src``), and the result is
    scattered back at ``dst`` — the pool itself is never gathered.  Works
    for every leaf dtype (bf16/f32 KV, int8 codes, scale rows, int32
    kv_pos), so the whole tile — validity included — moves verbatim.
    """
    L, nb = leaf.shape[0], leaf.shape[1]
    Z = 1
    for d in leaf.shape[2:]:
        Z *= d
    flat = leaf.reshape(L, nb, Z)
    sd = jnp.stack([jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)])

    def body(sd_ref, x_ref, o_ref):
        del sd_ref
        o_ref[...] = x_ref[...]

    tile = pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(L,),
            in_specs=[pl.BlockSpec((1, 1, Z), lambda l, sd: (l, sd[0], 0))],
            out_specs=pl.BlockSpec((1, 1, Z), lambda l, sd: (l, 0, 0))),
        out_shape=jax.ShapeDtypeStruct((L, 1, Z), flat.dtype),
        interpret=interpret,
    )(sd, flat)
    return flat.at[:, dst].set(tile[:, 0]).reshape(leaf.shape)


# ---------------------------------------------------------------------------
# XLA fallback: identical semantics without Pallas.  Paged pools are
# gathered through the table first (bit-identical to the contiguous ring
# when T*bs == ring length — the engine's greedy-parity invariant).
# ---------------------------------------------------------------------------

def flash_decode_xla(q, k, v, kv_pos, q_pos, *, k_scale=None, v_scale=None,
                     kind: str = "causal", window: int = 0, prefix_len=None,
                     softcap: float = 0.0, block_kv: int = 0,
                     block_tables=None, return_partials: bool = False,
                     **_unused):
    """Same signature/semantics as ``flash_decode`` without Pallas.

    ``block_kv`` <= 0 picks the measured policy: a single-pass wide form up
    to REPRO_DECODE_WIDE_MAX (4096) slots, else a ``lax.scan`` over
    2048-slot tiles with in-block dequant and online softmax — O(block)
    temporaries instead of O(cache_len).  An explicit ``block_kv`` >= S
    also selects the wide form."""
    if block_tables is not None:
        k, v, kv_pos, k_scale, v_scale = paged_gather(
            k, v, kv_pos, k_scale, v_scale, block_tables)
    B, S, Hk, D = k.shape
    kv_pos = jnp.asarray(kv_pos, jnp.int32)
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (B, S))
    if block_kv <= 0:
        block_kv = S if S <= _wide_max_s() else _SCAN_BLOCK_KV
    if block_kv >= S:
        return _decode_wide(q, k, v, kv_pos, q_pos, k_scale=k_scale,
                            v_scale=v_scale, kind=kind, window=window,
                            prefix_len=prefix_len, softcap=softcap,
                            return_partials=return_partials)
    quantized = k_scale is not None
    qg, k, v, kv_pos, k_scale, v_scale, G, _ = _pad_inputs(
        q, k, v, kv_pos, k_scale, v_scale, block_kv)
    qg = qg[:, :, :G].astype(jnp.float32)            # no sublane padding here
    S_pad = k.shape[1]
    nb = S_pad // block_kv
    scale = D ** -0.5
    qp = _broadcast_pos(q_pos, B)[:, :, None, None]  # (B, 1, 1, 1)
    plen = _broadcast_pos(prefix_len, B)[:, :, None, None]

    def to_blocks(x):
        return x.reshape((B, nb, block_kv) + x.shape[2:]).swapaxes(0, 1)

    blocks = [to_blocks(k), to_blocks(v), to_blocks(kv_pos)]
    if quantized:
        blocks += [to_blocks(k_scale), to_blocks(v_scale)]

    def kv_step(carry, blk):
        m_run, l_run, acc = carry
        if quantized:
            kb, vb, kpb, ksb, vsb = blk
            kb = kb.astype(jnp.float32) * ksb.astype(jnp.float32)
            vb = vb.astype(jnp.float32) * vsb.astype(jnp.float32)
        else:
            kb, vb, kpb = blk
            kb, vb = kb.astype(jnp.float32), vb.astype(jnp.float32)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = _slot_mask(kpb[:, None, None, :], qp, plen,
                          kind=kind, window=window)  # (B, 1, 1, block_kv)
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m_run, s.max(-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + p.sum(-1, keepdims=True)
        acc_new = acc * corr + jnp.einsum(
            "bhgk,bkhd->bhgd", p, vb, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, G, 1), _NEG, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, 1), jnp.float32)
    a0 = jnp.zeros((B, Hk, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), tuple(blocks))
    if return_partials:
        return m, l, acc
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, 1, Hk * G, D).astype(q.dtype)


def _decode_wide(q, k, v, kv_pos, q_pos, *, k_scale, v_scale, kind: str,
                 window: int, prefix_len, softcap: float,
                 return_partials: bool):
    """Single-pass short-context form: the int8 codes are transposed to
    (B, Hk, S, D) BEFORE dequant (1-byte traffic instead of the 4-byte
    transpose XLA would insert after), then one masked-softmax pass — the
    profitable shape below ``_WIDE_MAX_S``."""
    B, S, Hk, D = k.shape
    G = q.shape[2] // Hk
    qg = q[:, 0].reshape(B, Hk, G, D).astype(jnp.float32)
    kt = k.swapaxes(1, 2)                            # (B, Hk, S, D)
    vt = v.swapaxes(1, 2)
    if k_scale is not None:
        kst = k_scale[..., 0].swapaxes(1, 2)[..., None]   # (B, Hk, S, 1)
        vst = v_scale[..., 0].swapaxes(1, 2)[..., None]
        kf = kt.astype(jnp.float32) * kst.astype(jnp.float32)
        vf = vt.astype(jnp.float32) * vst.astype(jnp.float32)
    else:
        kf, vf = kt.astype(jnp.float32), vt.astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, kf,
                   preferred_element_type=jnp.float32) * D ** -0.5
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qp = _broadcast_pos(q_pos, B).reshape(B, 1, 1, 1)
    plen = _broadcast_pos(prefix_len, B).reshape(B, 1, 1, 1)
    mask = _slot_mask(kv_pos[:, None, None, :], qp, plen,
                      kind=kind, window=window)      # (B, 1, 1, S)
    s = jnp.where(mask, s, _NEG)
    m = s.max(-1, keepdims=True)                     # (B, Hk, G, 1)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = p.sum(-1, keepdims=True)
    acc = jnp.einsum("bhgk,bhkd->bhgd", p, vf,
                     preferred_element_type=jnp.float32)
    if return_partials:
        return m, l, acc
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, 1, Hk * G, D).astype(q.dtype)
