"""Hand-rolled bidirectional ring all-reduce over the federated LoRA payload.

``repro.dist.fed`` used to lean on XLA's generic psum lowering for the
Algorithm-1 aggregation.  This module owns the collective instead: the
payload is flattened, carved into ``2·n`` chunks (n rotating clockwise, n
counter-clockwise — both ICI directions busy every hop), and pushed around
the ring with ``jax.lax.ppermute``:

  reduce-scatter phase   n-1 hops; each hop a device receives its
                         neighbour's partial chunk and runs the FUSED
                         dequant -> accumulate (f32 master) -> requant step
                         (a Pallas kernel on TPU / forced-interpret CI), so
                         the quantized wire chunk is never materialized at
                         full precision outside the hop.
  all-gather phase       n-1 hops; the fully-reduced owned chunk is
                         quantized ONCE and then forwarded verbatim —
                         every device dequantizes the same codes, so the
                         result is replicated bit-identically.

Wire formats (``REPRO_FED_WIRE``): f32 (bit-exact, the deterministic
baseline), bf16, and int8 codes with per-``qblock`` f32 absmax scales
(``REPRO_FED_QBLOCK``, default 128).  Accumulation is ALWAYS f32 ("master"
copy), whatever the wire carries, and the hop schedule is a fixed ring
order — weighted aggregation stays deterministic run-to-run.

Error feedback: quantization error would bias Algorithm 1 (the same sign
error re-enters every round).  Each device therefore keeps a residual the
shape of its padded chunk layout; every quantization event adds the
residual in before encoding and stores back what the wire dropped
(``r <- t - deq(quant(t))``).  Carried across rounds, the bias telescopes
away (tests/test_ring_collective.py measures the convergence).

Chunk geometry and per-hop transfer sizes come from
``repro.core.comm.ring_wire_plan`` — the SAME plan prices the round in
``repro.core.comm.collective_bytes_per_round`` and ``repro.dist.fed
.expected_collective_bytes``, and the optional ``byte_ledger`` argument
records the actual nbytes of every ppermute'd buffer at trace time, so the
Fig. 5 comm metric is one number measured three ways.  (A fourth way rides
on top: ``repro.dist.fedcomm`` replays the captured ledger into the
``repro.obs`` tracer as per-hop events + wire-byte counters every round,
and each hop's ops are wrapped in a ``jax.named_scope``
(``obs.ring.<axis>.d<dir>.rs_hop<h>``/``ag_hop<h>``) so XLA device traces
name the hop schedule.)

All collective entry points here must be called from inside a
``shard_map`` body where the axis names are bound (``repro.dist.fedcomm``
is the wrapper that owns the shard_map).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.comm import ring_wire_plan, wire_format, wire_qblock

# rows of (qblock,) lanes one fused-hop program handles
_BLOCK_ROWS = 8


def _use_kernels() -> bool:
    """Mirror of ``repro.kernels.ops.use_kernels`` (no import to keep this
    module free of the attention-kernel dependency chain)."""
    return (jax.default_backend() == "tpu" or
            os.environ.get("REPRO_FORCE_KERNELS") == "1")


# ---------------------------------------------------------------------------
# Fused hop: dequant(recv) -> accumulate (f32 master) -> EF requant
# ---------------------------------------------------------------------------

def _quant_rows(t):
    """(R, Q) f32 -> int8 codes + (R, 1) f32 absmax scales.  jnp.round is
    round-half-to-even in BOTH the Pallas and jnp paths, so forced-interpret
    CI and the fallback agree bitwise."""
    s = jnp.max(jnp.abs(t), axis=1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-30)
    q = jnp.clip(jnp.round(t / s), -127.0, 127.0)
    return q, s


def _hop_int8_kernel(acc_ref, codes_ref, scales_ref, res_ref,
                     oacc_ref, ocodes_ref, oscales_ref, ores_ref):
    """One program: dequantize the received tile from its absmax scales,
    fold it into the f32 master accumulator, then requantize (residual
    added in, new residual out) for the next hop's send — the chunk never
    round-trips through HBM at full precision between these steps."""
    acc = acc_ref[...] + codes_ref[...].astype(jnp.float32) * scales_ref[...]
    oacc_ref[...] = acc
    t = acc + res_ref[...]
    q, s = _quant_rows(t)
    ocodes_ref[...] = q.astype(jnp.int8)
    oscales_ref[...] = s
    ores_ref[...] = t - q * s


def _hop_bf16_kernel(acc_ref, codes_ref, res_ref,
                     oacc_ref, ocodes_ref, ores_ref):
    acc = acc_ref[...] + codes_ref[...].astype(jnp.float32)
    oacc_ref[...] = acc
    t = acc + res_ref[...]
    o = t.astype(jnp.bfloat16)
    ocodes_ref[...] = o
    ores_ref[...] = t - o.astype(jnp.float32)


def _rows(x, qblock: int):
    r = x.reshape(-1, qblock)
    pad = -r.shape[0] % _BLOCK_ROWS
    if pad:
        r = jnp.pad(r, ((0, pad), (0, 0)))
    return r, pad


def _hop_pallas(acc, codes, scales, res, *, wire: str, qblock: int):
    """Pallas launch of the fused hop over (rows, qblock) tiles."""
    R0 = acc.size // qblock
    acc_r, _ = _rows(acc, qblock)
    res_r, _ = _rows(res, qblock)
    codes_r, _ = _rows(codes, qblock)
    R = acc_r.shape[0]
    grid = (R // _BLOCK_ROWS,)
    row_spec = pl.BlockSpec((_BLOCK_ROWS, qblock), lambda i: (i, 0))
    interpret = jax.default_backend() != "tpu"
    if wire == "int8":
        scale_spec = pl.BlockSpec((_BLOCK_ROWS, 1), lambda i: (i, 0))
        scales_r = scales.reshape(-1, 1)
        if scales_r.shape[0] != R:
            scales_r = jnp.pad(scales_r, ((0, R - scales_r.shape[0]), (0, 0)))
        oacc, ocodes, oscales, ores = pl.pallas_call(
            _hop_int8_kernel,
            grid=grid,
            in_specs=[row_spec, row_spec, scale_spec, row_spec],
            out_specs=[row_spec, row_spec, scale_spec, row_spec],
            out_shape=[
                jax.ShapeDtypeStruct((R, qblock), jnp.float32),
                jax.ShapeDtypeStruct((R, qblock), jnp.int8),
                jax.ShapeDtypeStruct((R, 1), jnp.float32),
                jax.ShapeDtypeStruct((R, qblock), jnp.float32),
            ],
            interpret=interpret,
        )(acc_r, codes_r, scales_r, res_r)
        return (oacc[:R0].reshape(acc.shape),
                ocodes[:R0].reshape(acc.shape).astype(jnp.int8),
                oscales[:R0, 0],
                ores[:R0].reshape(acc.shape))
    oacc, ocodes, ores = pl.pallas_call(
        _hop_bf16_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, row_spec],
        out_specs=[row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((R, qblock), jnp.float32),
            jax.ShapeDtypeStruct((R, qblock), jnp.bfloat16),
            jax.ShapeDtypeStruct((R, qblock), jnp.float32),
        ],
        interpret=interpret,
    )(acc_r, codes_r, res_r)
    return (oacc[:R0].reshape(acc.shape), ocodes[:R0].reshape(acc.shape),
            None, ores[:R0].reshape(acc.shape))


def _hop_jnp(acc, codes, scales, res, *, wire: str, qblock: int):
    """Oracle of the fused hop — identical arithmetic, no Pallas."""
    if wire == "int8":
        deq = (codes.reshape(-1, qblock).astype(jnp.float32) *
               scales.reshape(-1, 1)).reshape(acc.shape)
    else:
        deq = codes.astype(jnp.float32)
    acc = acc + deq
    t = acc + res
    if wire == "int8":
        q, s = _quant_rows(t.reshape(-1, qblock))
        return (acc, q.astype(jnp.int8).reshape(acc.shape), s[:, 0],
                (t.reshape(-1, qblock) - q * s).reshape(acc.shape))
    o = t.astype(jnp.bfloat16)
    return acc, o, None, t - o.astype(jnp.float32)


def fused_hop(acc, codes, scales, res, *, wire: str, qblock: int):
    """deq(recv) + accumulate + EF requant, one fused step.

    acc/res: (c,) f32 master chunk and its error-feedback residual;
    codes: (c,) wire-dtype received chunk (int8 or bf16);
    scales: (c // qblock,) f32 absmax scales (int8 wire only, else None).
    Returns (new_acc, send_codes, send_scales, new_res).  Pass
    ``codes=None`` for the quantize-only form (the first send of a phase:
    nothing received yet, encode the local value)."""
    if codes is None:
        codes = jnp.zeros(acc.shape, jnp.int8 if wire == "int8"
                          else jnp.bfloat16)
        if wire == "int8":
            scales = jnp.zeros((acc.size // qblock,), jnp.float32)
    if _use_kernels():
        return _hop_pallas(acc, codes, scales, res, wire=wire, qblock=qblock)
    return _hop_jnp(acc, codes, scales, res, wire=wire, qblock=qblock)


def _dequant_chunk(codes, scales, *, wire: str, qblock: int):
    if wire == "int8":
        return (codes.reshape(-1, qblock).astype(jnp.float32) *
                scales.reshape(-1, 1)).reshape(-1)
    return codes.astype(jnp.float32)


# ---------------------------------------------------------------------------
# The ring
# ---------------------------------------------------------------------------

def _ledger_add(ledger, axis, *bufs):
    if ledger is not None:
        ledger.append((axis, sum(b.size * b.dtype.itemsize for b in bufs
                                 if b is not None)))


def _chunk(x, idx, c):
    """x: (n·c,) -> the (c,) chunk at traced index ``idx``."""
    return jax.lax.dynamic_slice_in_dim(x, idx * c, c, 0)


def _set_chunk(x, idx, v, c):
    return jax.lax.dynamic_update_slice_in_dim(x, v, idx * c, 0)


def _ring_one_axis(flat, axis: str, n: int, *, wire: str, qblock: int,
                   residual, byte_ledger):
    """One n-way bidirectional ring all-reduce of a flat f32 payload.

    Called inside a shard_map body with ``axis`` bound.  ``flat`` is this
    device's local contribution; ``residual`` is the (2·n·c,) carried EF
    residual (or None -> zeros).  Returns (reduced (len(flat),) replicated
    across the axis, new residual)."""
    plan = ring_wire_plan(flat.size, n, wire, qblock)
    c = plan.chunk_elems
    total = plan.n_chunks * c
    me = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]

    padded = jnp.zeros((total,), jnp.float32).at[:flat.size].set(
        flat.astype(jnp.float32))
    res = (jnp.zeros((total,), jnp.float32) if residual is None
           else residual.reshape(total).astype(jnp.float32))
    out = jnp.zeros((total,), jnp.float32)

    for d, perm in ((0, fwd), (1, bwd)):
        acc = jax.lax.dynamic_slice_in_dim(padded, d * n * c, n * c, 0)
        rsd = jax.lax.dynamic_slice_in_dim(res, d * n * c, n * c, 0)
        sgn = 1 if d == 0 else -1

        def s_idx(h):
            return (me - sgn * h) % n

        # -- reduce-scatter: n-1 hops, fused dequant/accumulate/requant --
        first = _chunk(acc, s_idx(0), c)
        if wire == "f32":
            codes, scales = first, None          # identity wire, no EF
        else:
            _, codes, scales, r_new = fused_hop(
                first, None, None, _chunk(rsd, s_idx(0), c),
                wire=wire, qblock=qblock)
            rsd = _set_chunk(rsd, s_idx(0), r_new, c)
        for h in range(n - 1):
            with jax.named_scope(f"obs.ring.{axis}.d{d}.rs_hop{h}"):
                _ledger_add(byte_ledger, axis, codes, scales)
                codes = jax.lax.ppermute(codes, axis, perm)
                if scales is not None:
                    scales = jax.lax.ppermute(scales, axis, perm)
                r_idx = s_idx(h + 1)
                if wire == "f32":
                    new_acc = _chunk(acc, r_idx, c) + codes
                    codes = new_acc
                else:
                    new_acc, codes, scales, r_new = fused_hop(
                        _chunk(acc, r_idx, c), codes, scales,
                        _chunk(rsd, r_idx, c), wire=wire, qblock=qblock)
                    rsd = _set_chunk(rsd, r_idx, r_new, c)
                acc = _set_chunk(acc, r_idx, new_acc, c)

        # -- all-gather: quantized owned chunk forwarded verbatim --
        own = s_idx(n - 1)
        owned_val = (codes if wire == "f32"
                     else _dequant_chunk(codes, scales, wire=wire,
                                         qblock=qblock))
        outd = jnp.zeros((n * c,), jnp.float32)
        outd = _set_chunk(outd, own, owned_val, c)
        for h in range(n - 1):
            with jax.named_scope(f"obs.ring.{axis}.d{d}.ag_hop{h}"):
                _ledger_add(byte_ledger, axis, codes, scales)
                codes = jax.lax.ppermute(codes, axis, perm)
                if scales is not None:
                    scales = jax.lax.ppermute(scales, axis, perm)
                idx = s_idx(h)  # chunk owned by my (h+1)-away upstream
                                # neighbour
                outd = _set_chunk(
                    outd, idx,
                    codes if wire == "f32"
                    else _dequant_chunk(codes, scales, wire=wire,
                                        qblock=qblock),
                    c)
        out = jax.lax.dynamic_update_slice_in_dim(out, outd, d * n * c, 0)
        res = jax.lax.dynamic_update_slice_in_dim(res, rsd, d * n * c, 0)

    return out[:flat.size], res


def ring_allreduce(x, axes, axis_sizes: dict, *, wire: str = None,
                   qblock: int = None, residuals: dict = None,
                   byte_ledger: list = None):
    """Bidirectional ring all-reduce of ``x`` over ``axes`` (hierarchical:
    one ring per axis, innermost first — per-axis bytes match the per-axis
    accounting of ``collective_bytes_per_round``).

    Must run inside a shard_map body binding every axis in ``axes``.
    ``residuals`` maps axis -> carried EF residual (see ``residual_len``);
    pass None for fresh zeros (quantization error then discarded — biased;
    fine for one-shot reductions, wrong for training rounds).  Returns
    (reduced x, {axis: new residual}).
    """
    wire = wire or wire_format()
    qblock = qblock or wire_qblock()
    flat = x.reshape(-1).astype(jnp.float32)
    new_res = {}
    for ax in axes:
        n = axis_sizes[ax]
        if n <= 1:
            continue
        r = (residuals or {}).get(ax)
        flat, new_res[ax] = _ring_one_axis(
            flat, ax, n, wire=wire, qblock=qblock, residual=r,
            byte_ledger=byte_ledger)
    return flat.reshape(x.shape).astype(x.dtype), new_res


def residual_len(n_elems: int, n: int, wire: str = None,
                 qblock: int = None) -> int:
    """Length of the per-axis error-feedback residual: the padded chunk
    layout (2·n·chunk_elems) of the ring plan."""
    plan = ring_wire_plan(n_elems, n, wire, qblock)
    return plan.n_chunks * plan.chunk_elems
