"""Time-series data pipeline.

Offline container: the seven benchmark datasets (Weather/Traffic/
Electricity/ETT*) and the ACN EV-charging dataset are unavailable, so each
gets a statistical simulator matched to its published characteristics
(feature count, granularity, periodicities, trend — Table 1 of the paper and
the ACN description in §4.3).  The pipeline itself (windowing, splits,
normalization hand-off, batching) is the production component and is
dataset-agnostic: point ``load_csv`` at real data and everything downstream
is unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    features: int
    timesteps: int
    steps_per_day: int            # granularity -> daily period in steps
    trend: float = 0.0            # per-step linear drift (std units)
    weekly: bool = True
    noise: float = 0.3


# Table 1 of the paper
DATASETS = {
    "weather":     DatasetSpec("weather", 21, 52_696, 144, 0.0, False, 0.25),
    "traffic":     DatasetSpec("traffic", 862, 17_544, 24, 0.0, True, 0.2),
    "electricity": DatasetSpec("electricity", 321, 26_304, 24, 1e-5, True, 0.2),
    "etth1":       DatasetSpec("etth1", 7, 17_420, 24, 0.0, True, 0.3),
    "etth2":       DatasetSpec("etth2", 7, 17_420, 24, 0.0, True, 0.35),
    "ettm1":       DatasetSpec("ettm1", 7, 69_680, 96, 0.0, True, 0.3),
    "ettm2":       DatasetSpec("ettm2", 7, 69_680, 96, 0.0, True, 0.35),
    # ACN (paper §4.3): 2 sites, strong weekday pattern, upward trend
    "acn-caltech": DatasetSpec("acn-caltech", 54, 13_870, 24, 4e-5, True, 0.4),
    "acn-jpl":     DatasetSpec("acn-jpl", 40, 13_870, 24, 5e-5, True, 0.4),
}


def generate(spec: DatasetSpec, *, seed: int = 0,
             timesteps: Optional[int] = None) -> np.ndarray:
    """Simulate (T, M) multivariate series with daily/weekly structure."""
    rng = np.random.default_rng(seed)
    T = timesteps or spec.timesteps
    M = spec.features
    t = np.arange(T, dtype=np.float32)
    day = spec.steps_per_day
    # per-channel random phase/amplitude daily cycle
    phase = rng.uniform(0, 2 * np.pi, M).astype(np.float32)
    amp = rng.uniform(0.5, 1.5, M).astype(np.float32)
    x = amp[None] * np.sin(2 * np.pi * t[:, None] / day + phase[None])
    # harmonics
    x += 0.3 * amp[None] * np.sin(4 * np.pi * t[:, None] / day + 2 * phase[None])
    if spec.weekly:
        week = day * 7
        wd = ((t % week) < day * 5).astype(np.float32)   # weekday indicator
        x += 0.8 * wd[:, None] * rng.uniform(0.3, 1.0, M)[None].astype(np.float32)
    if spec.trend:
        x += spec.trend * t[:, None]
    # cross-channel correlation via low-rank mixing
    mix = rng.normal(0, 1, (M, M)).astype(np.float32)
    mix = 0.85 * np.eye(M, dtype=np.float32) + 0.15 * mix / np.sqrt(M)
    x = x @ mix
    # AR(1) noise
    eps = rng.normal(0, spec.noise, (T, M)).astype(np.float32)
    for i in range(1, T):
        eps[i] += 0.7 * eps[i - 1]
    return (x + eps).astype(np.float32)


def load_csv(path: str) -> np.ndarray:
    """Real-data entry point: CSV of shape (T, M) (header allowed)."""
    return np.genfromtxt(path, delimiter=",", skip_header=1,
                         dtype=np.float32)


def train_test_split(series: np.ndarray,
                     train_frac: float = 0.8) -> Tuple[np.ndarray, np.ndarray]:
    """Paper §4.1: 80% / 20% chronological split."""
    n = int(len(series) * train_frac)
    return series[:n], series[n:]


def make_windows(series: np.ndarray, lookback: int, horizon: int,
                 *, stride: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """(T, M) -> x: (n, L, M), y: (n, T_h, M) sliding windows."""
    T = len(series)
    n = (T - lookback - horizon) // stride + 1
    assert n > 0, (T, lookback, horizon)
    idx = np.arange(n) * stride
    x = np.stack([series[i:i + lookback] for i in idx])
    y = np.stack([series[i + lookback:i + lookback + horizon] for i in idx])
    return x, y


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, *,
            seed: int = 0, drop_last: bool = True
            ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(x))
    end = len(x) - (len(x) % batch_size if drop_last else 0)
    for i in range(0, end, batch_size):
        sel = order[i:i + batch_size]
        yield x[sel], y[sel]


def sample_batch(x: np.ndarray, y: np.ndarray, batch_size: int, *,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    sel = rng.integers(0, len(x), batch_size)
    return x[sel], y[sel]
