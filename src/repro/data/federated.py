"""Non-IID client partitioning for the federated experiments.

Each edge device (EV charging station / sensor) sees a different slice of
the channel set and time range, plus a device-specific scale/offset —
producing the skewed distributions the paper's clustering step targets.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.data.timeseries import make_windows


def partition_clients(series: np.ndarray, num_clients: int, *,
                      seed: int = 0, channels_per_client: int = 0,
                      hetero_scale: float = 0.5) -> List[np.ndarray]:
    """(T, M) -> list of per-client (T_s, M_s) series (non-IID)."""
    rng = np.random.default_rng(seed)
    T, M = series.shape
    cpc = channels_per_client or max(1, M // 4)
    cpc = min(cpc, M)
    out = []
    for c in range(num_clients):
        chans = rng.choice(M, size=cpc, replace=False)
        # staggered time ranges (devices come online at different times)
        start = rng.integers(0, T // 4)
        length = rng.integers(T // 2, T - start)
        local = series[start:start + length][:, chans].copy()
        # device-specific affine skew
        scale = 1.0 + hetero_scale * rng.normal(0, 1)
        offset = hetero_scale * rng.normal(0, 1)
        out.append((local * scale + offset).astype(np.float32))
    return out


def client_windows(client_series: List[np.ndarray], lookback: int,
                   horizon: int, *, max_windows: int = 512, seed: int = 0):
    """Per-client (x, y) window arrays, subsampled to ``max_windows``."""
    rng = np.random.default_rng(seed)
    out = []
    for s in client_series:
        if len(s) < lookback + horizon + 1:
            # pad short clients by tiling
            reps = (lookback + horizon + 1) // max(len(s), 1) + 1
            s = np.tile(s, (reps, 1))
        x, y = make_windows(s, lookback, horizon)
        if len(x) > max_windows:
            sel = rng.choice(len(x), max_windows, replace=False)
            x, y = x[sel], y[sel]
        out.append((x, y))
    return out


def client_weights(client_data) -> np.ndarray:
    """Paper's w_{s,c}: aggregation weight = local dataset size."""
    return np.array([len(x) for x, _ in client_data], dtype=np.float32)
