"""Synthetic token streams for LM smoke tests / examples (offline container:
no real corpora). Markov-chain tokens give non-trivial, learnable structure
so training-loss decrease is a meaningful signal."""

from __future__ import annotations

import numpy as np


def markov_tokens(num_tokens: int, vocab: int, *, seed: int = 0,
                  branching: int = 8) -> np.ndarray:
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, size=(vocab, branching))
    out = np.empty(num_tokens, dtype=np.int32)
    t = int(rng.integers(0, vocab))
    for i in range(num_tokens):
        out[i] = t
        t = int(nxt[t, rng.integers(0, branching)])
    return out


def lm_batches(tokens: np.ndarray, batch: int, seq: int, *, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, batch)
        x = np.stack([tokens[s:s + seq] for s in starts])
        y = np.stack([tokens[s + 1:s + seq + 1] for s in starts])
        yield {"tokens": x, "labels": y}
