"""FSLSTM baseline — federated stacked LSTM (Abdel-Sater & Hamza 2021,
paper reference [1]).  Two stacked LSTM layers over the multivariate
series, last hidden state -> linear head to the full horizon.  Federation
ships FULL weights (no PEFT) — this is what makes it the paper's
communication-overhead strawman."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _init_lstm_layer(key, d_in: int, d_hidden: int):
    k1, k2 = jax.random.split(key)
    s_in, s_h = d_in ** -0.5, d_hidden ** -0.5
    return {
        "wx": (jax.random.normal(k1, (d_in, 4 * d_hidden)) * s_in
               ).astype(jnp.float32),
        "wh": (jax.random.normal(k2, (d_hidden, 4 * d_hidden)) * s_h
               ).astype(jnp.float32),
        "b": jnp.zeros((4 * d_hidden,), jnp.float32)
             .at[d_hidden:2 * d_hidden].set(1.0),      # forget-gate bias 1
    }


def init(key, *, channels: int, horizon: int, d_hidden: int = 128,
         layers: int = 2):
    ks = jax.random.split(key, layers + 1)
    stack = [_init_lstm_layer(ks[i], channels if i == 0 else d_hidden,
                              d_hidden) for i in range(layers)]
    s = d_hidden ** -0.5
    return {
        "layers": stack,
        "head": (jax.random.normal(ks[-1], (d_hidden, horizon * channels))
                 * s).astype(jnp.float32),
    }


def _lstm_scan(lp, x):
    """x: (B, L, d_in) -> hidden sequence (B, L, dh)."""
    B, L, _ = x.shape
    dh = lp["wh"].shape[0]
    xw = x @ lp["wx"] + lp["b"][None, None, :]

    def step(carry, xt):
        h, c = carry
        gates = xt + h @ lp["wh"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((B, dh)), jnp.zeros((B, dh))
    _, hs = jax.lax.scan(step, h0, xw.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)


def forward(params, x):
    """x: (B, L, M) -> (B, T, M)."""
    B, L, M = x.shape
    mu = x.mean(1, keepdims=True)
    sd = x.std(1, keepdims=True) + 1e-5
    h = (x - mu) / sd
    for lp in params["layers"]:
        h = _lstm_scan(lp, h)
    T = params["head"].shape[1] // M          # horizon from head shape
    y = (h[:, -1, :] @ params["head"]).reshape(B, T, M)
    return y * sd + mu


def loss(params, batch):
    pred = forward(params, batch["x"])
    return jnp.mean(jnp.square(pred - batch["y"]))
