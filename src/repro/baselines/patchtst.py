"""PatchTST baseline (Nie et al., ICLR 2023) and its federated variant
Fed-PatchTST (paper §4.2 "For the sake of federated comparison...").

RevIN + channel independence + patching + bidirectional transformer
encoder + flatten head. Reuses the FedTime front-end with a small dense
encoder config and full (non-causal) attention — the architectural deltas
vs FedTime are exactly the paper's: no LLM backbone, no LoRA (federation
ships full weights), no DPO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import FedTimeConfig, ModelConfig
from repro.core.patching import (channel_merge, channel_split,
                                 init_patch_embed, make_patches, num_patches,
                                 patch_embed)
from repro.core.revin import init_revin, revin_denorm, revin_norm
from repro.models.layers.linear import dense, init_dense
from repro.models.transformer import _init_block, forward_hidden


def make_config(*, lookback: int = 512, horizon: int = 96,
                d_model: int = 128, num_layers: int = 3,
                num_heads: int = 16, d_ff: int = 256,
                patch_len: int = 16, stride: int = 8) -> ModelConfig:
    """PatchTST/64-flavored encoder config."""
    return ModelConfig(
        name="patchtst", family="dense", num_layers=num_layers,
        d_model=d_model, num_heads=num_heads, num_kv_heads=num_heads,
        d_ff=d_ff, vocab_size=1, activation="gelu",
        param_dtype="float32", compute_dtype="float32",
        fedtime=FedTimeConfig(lookback=lookback, horizon=horizon,
                              patch_len=patch_len, patch_stride=stride,
                              qlora=False),
        source="arXiv:2211.14730 (PatchTST)")


def init(cfg: ModelConfig, key, *, num_channels: int = 1):
    ft = cfg.fedtime
    N = num_patches(ft.lookback, ft.patch_len, ft.patch_stride)
    kp, kl, kh = jax.random.split(key, 3)
    keys = jax.random.split(kl, cfg.num_layers)
    return {
        "patch": init_patch_embed(kp, ft.patch_len, N, cfg.d_model),
        "layers": jax.vmap(lambda k: _init_block(k, cfg, jnp.float32))(keys),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), jnp.float32)},
        "head": init_dense(kh, N * cfg.d_model, ft.horizon, jnp.float32),
        "revin": init_revin(num_channels),
    }


def forward(params, cfg: ModelConfig, x):
    """x: (B, L, M) -> (B, T, M). Bidirectional encoder (PatchTST)."""
    ft = cfg.fedtime
    B, L, M = x.shape
    xn, stats = revin_norm(params["revin"], x.astype(jnp.float32))
    u = channel_split(xn)
    p = make_patches(u, ft.patch_len, ft.patch_stride)
    h = patch_embed(params["patch"], p)
    N = h.shape[1]
    h = forward_hidden({"layers": params["layers"],
                        "final_norm": params["final_norm"]}, cfg, h,
                       positions=jnp.arange(N, dtype=jnp.int32),
                       remat=False, kind="full")
    y = dense(params["head"], h.reshape(B * M, N * cfg.d_model))
    y = channel_merge(y, B, M)
    return revin_denorm(params["revin"], y, stats)


def loss(params, cfg: ModelConfig, batch):
    pred = forward(params, cfg, batch["x"])
    return jnp.mean(jnp.square(pred - batch["y"]))
