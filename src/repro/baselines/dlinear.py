"""DLinear baseline (Zeng et al., AAAI 2023): series decomposition
(moving-average trend + remainder) with per-component linear maps L -> T,
channel-independent."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init(key, lookback: int, horizon: int):
    k1, k2 = jax.random.split(key)
    s = lookback ** -0.5
    return {
        "w_trend": (jax.random.normal(k1, (lookback, horizon)) * s
                    ).astype(jnp.float32),
        "w_season": (jax.random.normal(k2, (lookback, horizon)) * s
                     ).astype(jnp.float32),
    }


def _moving_avg(x, k: int = 25):
    """x: (B, L, M) -> trend via centered moving average (edge-padded)."""
    pad_l, pad_r = (k - 1) // 2, k // 2
    xp = jnp.concatenate([jnp.repeat(x[:, :1], pad_l, 1), x,
                          jnp.repeat(x[:, -1:], pad_r, 1)], axis=1)
    c = jnp.cumsum(xp, axis=1)
    zero = jnp.zeros_like(c[:, :1])
    c = jnp.concatenate([zero, c], axis=1)
    return (c[:, k:] - c[:, :-k]) / k


def forward(params, x):
    """x: (B, L, M) -> (B, T, M)."""
    trend = _moving_avg(x)
    season = x - trend
    yt = jnp.einsum("blm,lt->btm", trend, params["w_trend"])
    ys = jnp.einsum("blm,lt->btm", season, params["w_season"])
    return yt + ys


def loss(params, batch):
    pred = forward(params, batch["x"])
    return jnp.mean(jnp.square(pred - batch["y"]))
