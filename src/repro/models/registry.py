"""Unified model API: family dispatch for init / loss / prefill / decode.

Every family exposes the same functional surface so the launcher, the
federated trainer, and the dry-run don't care which architecture they're
driving:

  init(cfg, key)                          -> params
  loss(params, cfg, batch)                -> scalar (LM cross-entropy + aux)
  prefill(params, cfg, batch)             -> (cache, last logits)
  decode_step(params, cfg, cache, batch)  -> (logits, cache)
  init_cache(cfg, batch_size, seq_len, force_window) -> cache pytree

Batch dicts:
  dense/moe/ssm/hybrid: {"tokens": (B,S), "labels": (B,S)}
  vlm:    {"patches": (B,P,vis_d), "tokens": (B,St), "labels": (B,St)}
  encdec: {"frames": (B,F,d), "tokens": (B,S), "labels": (B,S)}
  decode: {"token": (B,1), "pos": scalar}
"""

from __future__ import annotations

from types import SimpleNamespace

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import (encdec, moe_transformer, transformer, vlm,
                          xlstm_model, zamba2)
from repro.models.losses import chunked_ce


def _dense_api():
    def loss(params, cfg, batch):
        h = transformer.forward(params, cfg, batch["tokens"])
        return chunked_ce(h, params, cfg, batch["labels"])

    def prefill(params, cfg, batch, *, force_window=0, cache_len=0,
                true_len=None):
        return transformer.prefill(params, cfg, batch["tokens"],
                                   force_window=force_window,
                                   cache_len=cache_len, true_len=true_len)

    def decode_step(params, cfg, cache, batch, *, force_window=0):
        return transformer.decode_step(params, cfg, cache, batch["token"],
                                       batch["pos"],
                                       force_window=force_window,
                                       block_tbl=batch.get("block_tbl"),
                                       ring_len=batch.get("ring_len"))

    return SimpleNamespace(init=transformer.init, loss=loss, prefill=prefill,
                           decode_step=decode_step,
                           init_cache=transformer.init_cache)


def _moe_api():
    def loss(params, cfg, batch):
        h, aux = moe_transformer.forward(params, cfg, batch["tokens"])
        return chunked_ce(h, params, cfg, batch["labels"]) + aux

    def prefill(params, cfg, batch, *, force_window=0, cache_len=0,
                true_len=None):
        return moe_transformer.prefill(params, cfg, batch["tokens"],
                                       force_window=force_window,
                                       cache_len=cache_len,
                                       true_len=true_len)

    def decode_step(params, cfg, cache, batch, *, force_window=0):
        return moe_transformer.decode_step(params, cfg, cache,
                                           batch["token"], batch["pos"],
                                           force_window=force_window,
                                           block_tbl=batch.get("block_tbl"),
                                           ring_len=batch.get("ring_len"))

    return SimpleNamespace(init=moe_transformer.init, loss=loss,
                           prefill=prefill, decode_step=decode_step,
                           init_cache=moe_transformer.init_cache)


def _vlm_api():
    def loss(params, cfg, batch):
        h = vlm.forward(params, cfg, batch["patches"], batch["tokens"])
        # predict only the text suffix
        nI = cfg.vlm.num_image_tokens
        h_txt = h[:, nI:, :]
        return chunked_ce(h_txt, params, cfg, batch["labels"])

    def prefill(params, cfg, batch, *, force_window=0, cache_len=0,
                true_len=None):
        if true_len is not None:
            raise ValueError("prefill bucketing (true_len) is only supported "
                             "for attention-ring-cache families (dense/moe)")
        return vlm.prefill(params, cfg, batch["patches"], batch["tokens"],
                           force_window=force_window, cache_len=cache_len)

    def decode_step(params, cfg, cache, batch, *, force_window=0):
        return vlm.decode_step(params, cfg, cache, batch["token"],
                               batch["pos"], force_window=force_window)

    return SimpleNamespace(init=vlm.init, loss=loss, prefill=prefill,
                           decode_step=decode_step,
                           init_cache=vlm.init_cache)


def _encdec_api():
    def loss(params, cfg, batch):
        h = encdec.forward(params, cfg, batch["frames"], batch["tokens"])
        return chunked_ce(h, params, cfg, batch["labels"])

    def prefill(params, cfg, batch, *, force_window=0, cache_len=0,
                true_len=None):
        if true_len is not None:
            raise ValueError("prefill bucketing (true_len) is only supported "
                             "for attention-ring-cache families (dense/moe)")
        return encdec.prefill(params, cfg, batch["frames"], batch["tokens"],
                              force_window=force_window,
                              cache_len=cache_len)

    def decode_step(params, cfg, cache, batch, *, force_window=0):
        return encdec.decode_step(params, cfg, cache, batch["token"],
                                  batch["pos"], force_window=force_window)

    return SimpleNamespace(init=encdec.init, loss=loss, prefill=prefill,
                           decode_step=decode_step,
                           init_cache=encdec.init_cache)


def _ssm_api():
    def loss(params, cfg, batch):
        h = xlstm_model.forward(params, cfg, batch["tokens"])
        return chunked_ce(h, params, cfg, batch["labels"])

    def prefill(params, cfg, batch, *, force_window=0, cache_len=0,
                true_len=None):
        if true_len is not None:
            raise ValueError("prefill bucketing (true_len) is only supported "
                             "for attention-ring-cache families (dense/moe)")
        return xlstm_model.prefill(params, cfg, batch["tokens"],
                                   force_window=force_window,
                                   cache_len=cache_len)

    def decode_step(params, cfg, cache, batch, *, force_window=0):
        return xlstm_model.decode_step(params, cfg, cache, batch["token"],
                                       batch["pos"],
                                       force_window=force_window)

    return SimpleNamespace(init=xlstm_model.init, loss=loss, prefill=prefill,
                           decode_step=decode_step,
                           init_cache=xlstm_model.init_cache)


def _hybrid_api():
    def loss(params, cfg, batch):
        h = zamba2.forward(params, cfg, batch["tokens"])
        return chunked_ce(h, params, cfg, batch["labels"])

    def prefill(params, cfg, batch, *, force_window=0, cache_len=0,
                true_len=None):
        if true_len is not None:
            raise ValueError("prefill bucketing (true_len) is only supported "
                             "for attention-ring-cache families (dense/moe)")
        return zamba2.prefill(params, cfg, batch["tokens"],
                              force_window=force_window,
                              cache_len=cache_len)

    def decode_step(params, cfg, cache, batch, *, force_window=0):
        return zamba2.decode_step(params, cfg, cache, batch["token"],
                                  batch["pos"], force_window=force_window)

    return SimpleNamespace(init=zamba2.init, loss=loss, prefill=prefill,
                           decode_step=decode_step,
                           init_cache=zamba2.init_cache)


_FAMILIES = {
    "dense": _dense_api,
    "moe": _moe_api,
    "vlm": _vlm_api,
    "encdec": _encdec_api,
    "ssm": _ssm_api,
    "hybrid": _hybrid_api,
}


def get_model(cfg: ModelConfig):
    return _FAMILIES[cfg.family]()


# ---------------------------------------------------------------------------
# Batch construction (smoke tests + dry-run specs share these shapes)
# ---------------------------------------------------------------------------

def train_batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Shape/dtype tree for a training batch (as jax.ShapeDtypeStruct-able
    (shape, dtype) tuples)."""
    if cfg.family == "vlm":
        nI = cfg.vlm.num_image_tokens
        st = seq - nI
        return {
            "patches": ((batch, nI, cfg.vlm.vision_embed_dim), jnp.bfloat16),
            "tokens": ((batch, st), jnp.int32),
            "labels": ((batch, st), jnp.int32),
        }
    if cfg.family == "encdec":
        F = min(seq, cfg.encdec.max_source_len)
        return {
            "frames": ((batch, F, cfg.d_model), jnp.bfloat16),
            "tokens": ((batch, seq), jnp.int32),
            "labels": ((batch, seq), jnp.int32),
        }
    return {
        "tokens": ((batch, seq), jnp.int32),
        "labels": ((batch, seq), jnp.int32),
    }


def decode_batch_shapes(cfg: ModelConfig, batch: int) -> dict:
    return {
        "token": ((batch, 1), jnp.int32),
        "pos": ((), jnp.int32),
    }
