"""xLSTM language model (xlstm-350m): mLSTM blocks with periodic sLSTM.

Layer pattern: every ``slstm_every``-th block is sLSTM, the rest mLSTM.
Scanned as groups of (slstm_every - 1) mLSTM blocks + 1 sLSTM block so the
whole stack lowers as two nested scans.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.embeddings import init_embedding
from repro.models.layers.linear import init_dense
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.models.layers.xlstm import (
    init_mlstm_block, init_mlstm_cache, init_slstm_block, init_slstm_cache,
    mlstm_block_decode, mlstm_block_forward, slstm_block_decode,
    slstm_block_forward)
from repro.models.transformer import _seq_constraint, embed_tokens, logits_fn


def _group_counts(cfg: ModelConfig):
    k = cfg.xlstm.slstm_every
    assert cfg.num_layers % k == 0, (cfg.num_layers, k)
    return cfg.num_layers // k, k - 1        # (n_groups, mlstm per group)


def init(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    nG, nM = _group_counts(cfg)
    ke, km, ks, kh = jax.random.split(key, 4)
    mkeys = jax.random.split(km, nG * nM).reshape(nG, nM, 2)
    skeys = jax.random.split(ks, nG)
    mlstm = jax.vmap(jax.vmap(lambda k: {
        "norm": init_rmsnorm(cfg.d_model),
        "block": init_mlstm_block(k, cfg, dtype)}))(mkeys)
    slstm = jax.vmap(lambda k: {
        "norm": init_rmsnorm(cfg.d_model),
        "block": init_slstm_block(k, cfg, dtype)})(skeys)
    p = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "mlstm": mlstm,                       # (nG, nM, ...)
        "slstm": slstm,                       # (nG, ...)
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense(kh, cfg.d_model, cfg.vocab_size, dtype)
    return p


def forward(params, cfg: ModelConfig, tokens, *, remat: bool = True):
    """tokens (B,S) -> final hidden (B,S,d)."""
    x = embed_tokens(params, cfg, tokens)

    def m_layer(h, lp):
        y, _ = mlstm_block_forward(lp["block"], cfg,
                                   rmsnorm(lp["norm"], h, cfg.norm_eps))
        return _seq_constraint(h + y), None

    def group(h, gp):
        m_fn = jax.checkpoint(m_layer, prevent_cse=False) if remat else m_layer
        h, _ = jax.lax.scan(m_fn, h, gp["mlstm"])
        y, _ = slstm_block_forward(gp["slstm"]["block"], cfg,
                                   rmsnorm(gp["slstm"]["norm"], h,
                                           cfg.norm_eps))
        return _seq_constraint(h + y), None

    if remat:
        group = jax.checkpoint(group, prevent_cse=False)
    x, _ = jax.lax.scan(group, _seq_constraint(x),
                        {"mlstm": params["mlstm"], "slstm": params["slstm"]})
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decode (constant-size recurrent state — long_500k runs natively)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               *, force_window: int = 0, dtype=jnp.bfloat16):
    del seq_len, force_window                # state is O(1) in sequence length
    nG, nM = _group_counts(cfg)
    m = jax.vmap(jax.vmap(lambda _: init_mlstm_cache(cfg, batch, dtype)))(
        jnp.arange(nG * nM).reshape(nG, nM))
    s = jax.vmap(lambda _: init_slstm_cache(cfg, batch, dtype))(jnp.arange(nG))
    return {"mlstm": m, "slstm": s}


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                force_window: int = 0):
    del pos, force_window
    x = embed_tokens(params, cfg, token)

    def m_layer(h, lp_cache):
        lp, c = lp_cache
        y, c2 = mlstm_block_decode(lp["block"], cfg,
                                   rmsnorm(lp["norm"], h, cfg.norm_eps), c)
        return h + y, c2

    def group(h, gp_cache):
        gp, gc = gp_cache
        h, mc = jax.lax.scan(m_layer, h, (gp["mlstm"], gc["mlstm"]))
        y, sc = slstm_block_decode(gp["slstm"]["block"], cfg,
                                   rmsnorm(gp["slstm"]["norm"], h,
                                           cfg.norm_eps), gc["slstm"])
        return h + y, {"mlstm": mc, "slstm": sc}

    x, new_cache = jax.lax.scan(
        group, x,
        ({"mlstm": params["mlstm"], "slstm": params["slstm"]}, cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache


def prefill(params, cfg: ModelConfig, tokens, *, force_window: int = 0,
            cache_len: int = 0):
    """Run the recurrence over the prompt, materializing final states.

    For recurrent models prefill == forward while carrying states; we re-run
    the chunked forms with state threading.
    """
    del force_window, cache_len
    x = embed_tokens(params, cfg, tokens)

    def m_layer(h, lp):
        y, st = mlstm_block_forward(lp["block"], cfg,
                                    rmsnorm(lp["norm"], h, cfg.norm_eps),
                                    return_cache=True)
        return h + y, st

    def group(h, gp):
        h, m_states = jax.lax.scan(m_layer, h, gp["mlstm"])
        y, s_state = slstm_block_forward(gp["slstm"]["block"], cfg,
                                         rmsnorm(gp["slstm"]["norm"], h,
                                                 cfg.norm_eps))
        return h + y, {"mlstm": m_states, "slstm": s_state}

    x, states = jax.lax.scan(
        group, x, {"mlstm": params["mlstm"], "slstm": params["slstm"]})
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return states, logits_fn(params, cfg, x[:, -1:, :])
