"""Encoder-decoder backbone (seamless-m4t-medium).

The audio front-end (mel + conv codec) is a stub per the assignment:
the model consumes precomputed frame embeddings (B, F, d_model). We
implement the full transformer: bidirectional encoder over frames,
autoregressive decoder with self- + cross-attention over text tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.attention import (
    attention, attn_cross_decode, attn_decode, init_attention,
    init_attn_cache)
from repro.models.layers.embeddings import init_embedding
from repro.models.layers.linear import dense, init_dense
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.models.transformer import (
    BLOCK_KV, BLOCK_Q, BLOCKWISE_THRESHOLD, _seq_constraint, embed_tokens,
    logits_fn)


def _init_enc_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg, dtype=dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg, dtype=dtype),
        "cross_norm": init_rmsnorm(cfg.d_model),
        "cross": init_attention(k2, cfg, dtype=dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def init(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kf, kenc, kdec = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.encdec.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    return {
        "frame_proj": init_dense(kf, cfg.d_model, cfg.d_model, dtype),
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "decoder": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames, *, remat: bool = True):
    """frames (B,F,d_model) stub embeddings -> encoder memory (B,F,d)."""
    F = frames.shape[1]
    positions = jnp.arange(F, dtype=jnp.int32)
    x = dense(params["frame_proj"],
              frames.astype(jnp.dtype(cfg.compute_dtype)))
    bq, bkv = (BLOCK_Q, BLOCK_KV) if F >= BLOCKWISE_THRESHOLD else (0, 0)

    def body(h, lp):
        a = attention(lp["attn"], cfg,
                      rmsnorm(lp["attn_norm"], h, cfg.norm_eps),
                      positions=positions, kind="full",
                      block_q=bq, block_kv=bkv)
        h = h + a
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps),
                    cfg.activation)
        return _seq_constraint(h), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, _seq_constraint(x), params["encoder"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, frames, tokens, *, remat: bool = True):
    """Teacher-forced decode. Returns final decoder hidden (B,S,d)."""
    memory = encode(params, cfg, frames, remat=remat)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    mem_pos = jnp.arange(memory.shape[1], dtype=jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    bq, bkv = (BLOCK_Q, BLOCK_KV) if S >= BLOCKWISE_THRESHOLD else (0, 0)

    def body(h, lp):
        a = attention(lp["attn"], cfg,
                      rmsnorm(lp["attn_norm"], h, cfg.norm_eps),
                      positions=positions, kind="causal",
                      window=cfg.sliding_window, block_q=bq, block_kv=bkv)
        h = h + a
        c = attention(lp["cross"], cfg,
                      rmsnorm(lp["cross_norm"], h, cfg.norm_eps),
                      positions=positions, kind="full", kv_x=memory,
                      kv_positions=mem_pos, use_rope=False)
        h = h + c
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps),
                    cfg.activation)
        return _seq_constraint(h), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, _seq_constraint(x), params["decoder"])
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               *, force_window: int = 0, dtype=jnp.bfloat16):
    """Self-attn ring caches + cross-attention memory K/V per decoder layer."""
    dh = cfg.resolved_head_dim()
    w = force_window or cfg.sliding_window
    cl = min(seq_len, w) if w > 0 else seq_len
    F = cfg.encdec.max_source_len
    L = cfg.num_layers
    self_c = jax.vmap(lambda _: init_attn_cache(batch, cl, cfg.num_kv_heads,
                                                dh, dtype))(jnp.arange(L))
    return {
        "self": self_c,
        "mem_k": jnp.zeros((L, batch, F, cfg.num_kv_heads, dh), dtype),
        "mem_v": jnp.zeros((L, batch, F, cfg.num_kv_heads, dh), dtype),
        "mem_pos": jnp.full((batch, F), -1, jnp.int32),
    }


def prefill(params, cfg: ModelConfig, frames, tokens, *,
            force_window: int = 0, cache_len: int = 0):
    """Encode source + precompute cross K/V + build self cache from prompt."""
    from repro.models.transformer import _scatter_ring
    memory = encode(params, cfg, frames, remat=False)
    B, S = tokens.shape
    F = memory.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    mem_pos_row = jnp.arange(F, dtype=jnp.int32)
    mem_pos = jnp.broadcast_to(mem_pos_row[None], (B, F))
    x = embed_tokens(params, cfg, tokens)
    bq, bkv = (BLOCK_Q, BLOCK_KV) if S >= BLOCKWISE_THRESHOLD else (0, 0)
    w = force_window or cfg.sliding_window
    total = max(S, cache_len)
    cl = min(total, w) if w > 0 else total
    cdt = jnp.dtype(cfg.compute_dtype)
    dh = cfg.resolved_head_dim()

    def body(h, lp):
        a_in = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, (k, v) = attention(lp["attn"], cfg, a_in, positions=positions,
                              kind="causal", window=w, block_q=bq,
                              block_kv=bkv, return_kv=True)
        sc = _scatter_ring(k.astype(cdt), v.astype(cdt), positions, cl)
        h = h + a
        c_in = rmsnorm(lp["cross_norm"], h, cfg.norm_eps)
        c, (mk, mv) = attention(lp["cross"], cfg, c_in, positions=positions,
                                kind="full", kv_x=memory,
                                kv_positions=mem_pos_row, use_rope=False,
                                return_kv=True)
        h = h + c
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps),
                    cfg.activation)
        return _seq_constraint(h), (sc, mk.astype(cdt), mv.astype(cdt))

    x, (self_c, mem_k, mem_v) = jax.lax.scan(body, _seq_constraint(x),
                                             params["decoder"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    cache = {"self": self_c, "mem_k": mem_k, "mem_v": mem_v,
             "mem_pos": mem_pos}
    return cache, logits_fn(params, cfg, x[:, -1:, :])


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                force_window: int = 0):
    x = embed_tokens(params, cfg, token)
    w = force_window or cfg.sliding_window

    def body(h, lp_cache):
        lp, sc, mk, mv = lp_cache
        a, sc2 = attn_decode(lp["attn"], cfg,
                             rmsnorm(lp["attn_norm"], h, cfg.norm_eps),
                             sc, pos, window=w)
        h = h + a
        c = attn_cross_decode(lp["cross"], cfg,
                              rmsnorm(lp["cross_norm"], h, cfg.norm_eps),
                              mk, mv, cache["mem_pos"])
        h = h + c
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps),
                    cfg.activation)
        return h, sc2

    x, self_new = jax.lax.scan(
        body, x, (params["decoder"], cache["self"], cache["mem_k"],
                  cache["mem_v"]))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = dict(cache, self=self_new)
    return logits_fn(params, cfg, x), new_cache
