"""Dense decoder-only transformer family.

Covers: qwen3-* (GQA + qk-norm), smollm (llama-arch), gemma2 (local/global
alternating attention + logit softcaps + post-block norms), and the paper's
own LLaMA-2 backbone.  Layers are stacked with ``jax.vmap`` at init and run
with ``jax.lax.scan`` (compile-time economy for the 512-device dry-run);
training wraps each block in ``jax.checkpoint`` and pins the residual stream
to a Megatron-style (batch→data, seq→model) layout so remat checkpoints stay
small (DESIGN.md §5).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.attention import (
    attention, attn_decode, init_attention, init_attn_cache)
from repro.models.layers.embeddings import embed, init_embedding, unembed
from repro.models.layers.linear import dense, init_dense
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.norms import init_rmsnorm, rmsnorm

# Sequence length at/above which attention switches to the blockwise
# online-softmax path (memory-bounded); block sizes chosen 128-aligned.
import os as _os
BLOCKWISE_THRESHOLD = 4096
BLOCK_Q = int(_os.environ.get("REPRO_BLOCK_Q", "512"))
BLOCK_KV = int(_os.environ.get("REPRO_BLOCK_KV", "2048"))


def _seq_constraint(x, *, decode: bool = False):
    """Pin residual stream to (batch->data, seq->model) when a mesh is
    active; no-op outside pjit/mesh contexts or when dims don't divide."""
    from repro.dist.sharding import residual_constraint  # lazy
    return residual_constraint(x, decode=decode)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg, dtype=dtype),
        "mlp_norm": init_rmsnorm(cfg.d_model),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }
    if cfg.post_block_norm:
        p["post_attn_norm"] = init_rmsnorm(cfg.d_model)
        p["post_mlp_norm"] = init_rmsnorm(cfg.d_model)
    return p


def init(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    if cfg.local_global_alternating:
        assert cfg.num_layers % 2 == 0
        n_pairs = cfg.num_layers // 2
        keys = jax.random.split(kl, 2 * n_pairs).reshape(2, n_pairs, 2)
        layers = {
            "local": jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys[0]),
            "global": jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys[1]),
        }
    else:
        keys = jax.random.split(kl, cfg.num_layers)
        layers = jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys)
    p = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense(kh, cfg.d_model, cfg.vocab_size, dtype)
    return p


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _block(p, cfg: ModelConfig, x, *, positions, window, kind="causal",
           prefix_len=None, block_q=0, block_kv=0):
    gemma = cfg.post_block_norm
    h = attention(p["attn"], cfg, rmsnorm(p["attn_norm"], x, cfg.norm_eps,
                                          gemma_style=gemma),
                  positions=positions, kind=kind, window=window,
                  prefix_len=prefix_len, block_q=block_q, block_kv=block_kv)
    if gemma:
        h = rmsnorm(p["post_attn_norm"], h, cfg.norm_eps, gemma_style=True)
    x = x + h
    h = mlp(p["mlp"], rmsnorm(p["mlp_norm"], x, cfg.norm_eps,
                              gemma_style=gemma), cfg.activation)
    if gemma:
        h = rmsnorm(p["post_mlp_norm"], h, cfg.norm_eps, gemma_style=True)
    return x + h


def _block_decode(p, cfg: ModelConfig, x_t, cache, pos, *, window,
                  prefix_len=None, block_tbl=None, ring_len=None):
    gemma = cfg.post_block_norm
    h, cache = attn_decode(p["attn"], cfg,
                           rmsnorm(p["attn_norm"], x_t, cfg.norm_eps,
                                   gemma_style=gemma),
                           cache, pos, window=window, prefix_len=prefix_len,
                           block_tbl=block_tbl, ring_len=ring_len)
    if gemma:
        h = rmsnorm(p["post_attn_norm"], h, cfg.norm_eps, gemma_style=True)
    x_t = x_t + h
    h = mlp(p["mlp"], rmsnorm(p["mlp_norm"], x_t, cfg.norm_eps,
                              gemma_style=gemma), cfg.activation)
    if gemma:
        h = rmsnorm(p["post_mlp_norm"], h, cfg.norm_eps, gemma_style=True)
    return x_t + h, cache


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def forward_hidden(params, cfg: ModelConfig, x, *, positions,
                   prefix_len=None, remat: bool = True,
                   kind: str = "causal"):
    """Embedded input (B,S,d) -> final hidden (B,S,d), scanning layers."""
    S = x.shape[1]
    blockwise = S >= BLOCKWISE_THRESHOLD
    bq, bkv = (BLOCK_Q, BLOCK_KV) if blockwise else (0, 0)
    kind = "prefix" if prefix_len is not None else kind

    def body(h, lp):
        if cfg.local_global_alternating:
            h = _block(lp["local"], cfg, h, positions=positions,
                       window=cfg.sliding_window, kind=kind,
                       prefix_len=prefix_len, block_q=bq, block_kv=bkv)
            h = _seq_constraint(h)
            h = _block(lp["global"], cfg, h, positions=positions,
                       window=0, kind=kind, prefix_len=prefix_len,
                       block_q=bq, block_kv=bkv)
        else:
            h = _block(lp, cfg, h, positions=positions,
                       window=cfg.sliding_window, kind=kind,
                       prefix_len=prefix_len, block_q=bq, block_kv=bkv)
        return _seq_constraint(h), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, _seq_constraint(x), params["layers"])
    return rmsnorm(params["final_norm"], x, cfg.norm_eps,
                   gemma_style=cfg.post_block_norm)


def embed_tokens(params, cfg: ModelConfig, tokens):
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.compute_dtype))
    if cfg.embedding_multiplier != 1.0:
        x = x * jnp.asarray(cfg.embedding_multiplier, x.dtype)
    return x


def logits_fn(params, cfg: ModelConfig, hidden):
    if cfg.tie_embeddings:
        lg = unembed(params["embed"], hidden)
    else:
        lg = dense(params["lm_head"], hidden)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        lg = c * jnp.tanh(lg / c)
    return lg


def forward(params, cfg: ModelConfig, tokens, *, prefix_len=None,
            remat: bool = True):
    """tokens (B,S) -> final hidden (B,S,d). Use losses.chunked_ce for LM
    loss (never materializes full logits)."""
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    return forward_hidden(params, cfg, x, positions=positions,
                          prefix_len=prefix_len, remat=remat)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _cache_lengths(cfg: ModelConfig, seq_len: int, *, force_window: int = 0):
    """(local_len, global_len) ring-buffer sizes for this config."""
    w = force_window or cfg.sliding_window
    local_len = min(seq_len, w) if w > 0 else seq_len
    if cfg.local_global_alternating:
        return min(seq_len, cfg.sliding_window), seq_len
    return local_len, local_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               *, force_window: int = 0, dtype=jnp.bfloat16):
    dh = cfg.resolved_head_dim()
    ll, gl = _cache_lengths(cfg, seq_len, force_window=force_window)
    if cfg.local_global_alternating:
        n_pairs = cfg.num_layers // 2
        mk = lambda n, L: jax.vmap(  # noqa: E731
            lambda _: init_attn_cache(batch, L, cfg.num_kv_heads, dh, dtype)
        )(jnp.arange(n))
        return {"local": mk(n_pairs, ll), "global": mk(n_pairs, gl)}
    mk = jax.vmap(lambda _: init_attn_cache(batch, ll, cfg.num_kv_heads, dh,
                                            dtype))
    return mk(jnp.arange(cfg.num_layers))


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                force_window: int = 0, prefix_len=None, block_tbl=None,
                ring_len=None):
    """token (B,1) int32, pos scalar -> (logits (B,1,V), new cache).

    ``block_tbl``/``ring_len`` select the paged-pool cache layout (uniform
    rings only — every layer shares one block geometry and one table; see
    repro.serve.cache_pool.PagedCachePool)."""
    x = embed_tokens(params, cfg, token)
    w = force_window or cfg.sliding_window

    if cfg.local_global_alternating:
        if block_tbl is not None:
            raise ValueError("paged KV pools require uniform ring lengths; "
                             "local/global alternating layers keep "
                             "contiguous lanes")
        def body(h, lp_cache):
            lp, c = lp_cache
            h, c_l = _block_decode(lp["local"], cfg, h, c["local"], pos,
                                   window=cfg.sliding_window,
                                   prefix_len=prefix_len)
            h, c_g = _block_decode(lp["global"], cfg, h, c["global"], pos,
                                   window=0, prefix_len=prefix_len)
            return h, {"local": c_l, "global": c_g}
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        def body(h, lp_cache):
            lp, c = lp_cache
            h, c2 = _block_decode(lp, cfg, h, c, pos, window=w,
                                  prefix_len=prefix_len,
                                  block_tbl=block_tbl, ring_len=ring_len)
            return h, c2
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps,
                gemma_style=cfg.post_block_norm)
    return logits_fn(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# Prefill: full forward capturing KV into ring caches + last-token logits
# ---------------------------------------------------------------------------

def _scatter_ring(k, v, positions, cache_len):
    """k,v: (B,S,Hk,dh) post-RoPE -> ring cache of cache_len slots holding
    the last ``cache_len`` positions (int8-quantized when REPRO_KV_INT8)."""
    from repro.models.layers.attention import _quant_kv, kv_cache_int8
    S = k.shape[1]
    take = min(S, cache_len)
    pos_tail = positions[-take:]
    slots = jnp.mod(pos_tail, cache_len)
    B = k.shape[0]

    def scatter(val):
        return jnp.zeros((B, cache_len) + val.shape[2:], val.dtype).at[
            :, slots].set(val[:, -take:])

    cp = jnp.full((B, cache_len), -1, jnp.int32).at[:, slots].set(
        jnp.broadcast_to(pos_tail[None], (B, take)))
    if kv_cache_int8():
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        return {"k": scatter(kq), "v": scatter(vq),
                "k_scale": scatter(ks), "v_scale": scatter(vs),
                "kv_pos": cp}
    return {"k": scatter(k), "v": scatter(v), "kv_pos": cp}


def _finalize_prefill(params, cfg: ModelConfig, x, cache, true_len):
    """Last-token logits + (when ``true_len`` (B,) is given) bucketed-prompt
    fixup: logits are gathered at row position ``true_len - 1`` (causal
    masking makes that hidden state independent of the right padding) and
    ring slots written by pad positions are invalidated (kv_pos -> -1)."""
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps,
                gemma_style=cfg.post_block_norm)
    B, S = x.shape[:2]
    if true_len is None:
        return cache, logits_fn(params, cfg, x[:, -1:, :])
    tl = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32).reshape(-1), (B,))
    last = x[jnp.arange(B), jnp.clip(tl - 1, 0, S - 1)][:, None, :]

    def drop_pad(c):
        # kv_pos: (L, B, cache_len) — pad slots carry positions >= true_len
        return {**c, "kv_pos": jnp.where(c["kv_pos"] >= tl[None, :, None],
                                         -1, c["kv_pos"])}

    if isinstance(cache, dict) and "local" in cache:
        cache = {"local": drop_pad(cache["local"]),
                 "global": drop_pad(cache["global"])}
    else:
        cache = drop_pad(cache)
    return cache, logits_fn(params, cfg, last)


def prefill(params, cfg: ModelConfig, tokens, *, force_window: int = 0,
            prefix_len=None, cache_len: int = 0, true_len=None):
    """tokens (B,S) -> (cache, last-token logits (B,1,V)).

    Runs the full-sequence trunk block-by-block (scan), capturing each
    layer's (k, v) into its ring buffer.  ``true_len`` (B,) marks rows as
    right-padded to a bucket length: logits come from the last *real* token
    and pad-written ring slots are masked invalid (the serving engine's
    prefill-bucketing path — bounds the number of prefill signatures).
    """
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    blockwise = S >= BLOCKWISE_THRESHOLD
    bq, bkv = (BLOCK_Q, BLOCK_KV) if blockwise else (0, 0)
    kind = "prefix" if prefix_len is not None else "causal"
    ll, gl = _cache_lengths(cfg, max(S, cache_len), force_window=force_window)
    cache_dtype = jnp.dtype(cfg.compute_dtype)

    def attn_with_capture(lp, h, window, cache_len):
        gemma = cfg.post_block_norm
        a_in = rmsnorm(lp["attn_norm"], h, cfg.norm_eps, gemma_style=gemma)
        y, (k, v) = attention(lp["attn"], cfg, a_in, positions=positions,
                              kind=kind, window=window, prefix_len=prefix_len,
                              block_q=bq, block_kv=bkv, return_kv=True)
        if gemma:
            y = rmsnorm(lp["post_attn_norm"], y, cfg.norm_eps,
                        gemma_style=True)
        c = _scatter_ring(k.astype(cache_dtype), v.astype(cache_dtype),
                          positions, cache_len)
        return y, c

    def full_block(lp, h, window, cache_len):
        gemma = cfg.post_block_norm
        y, c = attn_with_capture(lp, h, window, cache_len)
        h = h + y
        m = mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps,
                                   gemma_style=gemma), cfg.activation)
        if gemma:
            m = rmsnorm(lp["post_mlp_norm"], m, cfg.norm_eps, gemma_style=True)
        return h + m, c

    if cfg.local_global_alternating:
        def body(h, lp):
            h, c_l = full_block(lp["local"], h, cfg.sliding_window, ll)
            h = _seq_constraint(h)
            h, c_g = full_block(lp["global"], h, 0, gl)
            return _seq_constraint(h), {"local": c_l, "global": c_g}
    else:
        w = force_window or cfg.sliding_window
        def body(h, lp):
            h, c = full_block(lp, h, w, ll)
            return _seq_constraint(h), c

    x, cache = jax.lax.scan(body, _seq_constraint(x), params["layers"])
    return _finalize_prefill(params, cfg, x, cache, true_len)
