"""Loss functions.

``chunked_ce`` computes LM cross-entropy by scanning over sequence chunks so
the (B, S, vocab) logits tensor is never materialized — required at the
assigned scales (e.g. qwen3 train_4k: 256×4096×151936 logits would be ~2.5 TB
in f32 globally).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _ce_chunk(hidden_chunk, table, head, labels_chunk, softcap):
    """hidden (B,c,d) -> mean-able (sum_loss, count)."""
    if table is not None:
        logits = jnp.einsum("bcd,vd->bcv", hidden_chunk, table)
    else:
        logits = hidden_chunk @ head
    logits = logits.astype(jnp.float32)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_chunk[..., None],
                               axis=-1)[..., 0]
    mask = labels_chunk >= 0
    loss = jnp.where(mask, lse - gold, 0.0)
    return loss.sum(), mask.sum()


def chunked_ce(hidden, params, cfg, labels, *, chunk: int = 512) -> jnp.ndarray:
    """hidden: (B,S,d); labels: (B,S) int32, -1 = ignore. Scalar mean CE."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:            # largest chunk <= requested that divides S
        chunk -= 1
    n = S // chunk
    dt = jnp.dtype(cfg.compute_dtype)
    table = params["embed"]["table"].astype(dt) if cfg.tie_embeddings else None
    head = None if cfg.tie_embeddings else params["lm_head"]["w"].astype(dt)

    hs = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h, l = xs
        s, c = _ce_chunk(h.astype(dt), table, head, l, cfg.final_logit_softcap)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32)), (hs, ls))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)


def mse(pred, target) -> jnp.ndarray:
    """Paper Eq. (5): mean squared forecasting error."""
    return jnp.mean(jnp.square(pred.astype(jnp.float32) -
                               target.astype(jnp.float32)))


def mae(pred, target) -> jnp.ndarray:
    return jnp.mean(jnp.abs(pred.astype(jnp.float32) -
                            target.astype(jnp.float32)))
