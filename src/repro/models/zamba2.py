"""Zamba2 hybrid: Mamba2 backbone + weight-shared attention blocks.
[arXiv:2411.15242]

Every ``shared_attn_every`` Mamba2 layers, one of ``num_shared_blocks``
(round-robin) weight-shared transformer blocks runs on the concatenation of
the current hidden state and the original embedding (2·d_model input,
d_model output) — Zamba2's signature "shared attention with embedding
re-injection".  The shared block's weights are *reused* across all its
applications; only the KV cache is per-application.

FedTime interaction (DESIGN.md §4): the shared block carries the LoRA
adapters — one adapter serves 9 applications, the smallest federated payload
of all assigned archs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.attention import (
    attention, attn_decode, init_attention, init_attn_cache)
from repro.models.layers.embeddings import init_embedding
from repro.models.layers.linear import init_dense
from repro.models.layers.mamba2 import (
    init_mamba2, init_mamba2_cache, mamba2_decode, mamba2_forward)
from repro.models.layers.mlp import init_mlp, mlp
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.models.transformer import (
    BLOCK_KV, BLOCK_Q, BLOCKWISE_THRESHOLD, _seq_constraint, embed_tokens,
    logits_fn)


def _group_counts(cfg: ModelConfig):
    k = cfg.hybrid.shared_attn_every
    assert cfg.num_layers % k == 0, (cfg.num_layers, k)
    return cfg.num_layers // k, k            # (n_groups, mamba per group)


def _init_shared_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    d2 = 2 * cfg.d_model
    return {
        "attn_norm": init_rmsnorm(d2),
        "attn": init_attention(k1, cfg, q_in=d2, kv_in=d2,
                               out_dim=cfg.d_model, dtype=dtype),
        "mlp_norm": init_rmsnorm(d2),
        "mlp": init_mlp(k2, d2, cfg.d_ff, cfg.activation, dtype,
                        out_dim=cfg.d_model),
    }


def init(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    nG, nM = _group_counts(cfg)
    ke, km, ks, kh = jax.random.split(key, 4)
    mkeys = jax.random.split(km, nG * nM).reshape(nG, nM, 2)
    skeys = jax.random.split(ks, cfg.hybrid.num_shared_blocks)
    mamba = jax.vmap(jax.vmap(lambda k: {
        "norm": init_rmsnorm(cfg.d_model),
        "block": init_mamba2(k, cfg, dtype)}))(mkeys)
    shared = jax.vmap(lambda k: _init_shared_block(k, cfg, dtype))(skeys)
    p = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "mamba": mamba,                       # (nG, nM, ...)
        "shared": shared,                     # (num_shared_blocks, ...)
        "final_norm": init_rmsnorm(cfg.d_model),
        "lm_head": init_dense(kh, cfg.d_model, cfg.vocab_size, dtype),
    }
    return p


def _select_shared(params, g_idx):
    """Round-robin shared block: tree-select block (g_idx % n)."""
    n = jax.tree.leaves(params["shared"])[0].shape[0]
    sel = jnp.mod(g_idx, n)
    return jax.tree.map(lambda a: a[sel], params["shared"])


def forward(params, cfg: ModelConfig, tokens, *, remat: bool = True):
    """tokens (B,S) -> final hidden (B,S,d)."""
    x0 = embed_tokens(params, cfg, tokens)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    bq, bkv = (BLOCK_Q, BLOCK_KV) if S >= BLOCKWISE_THRESHOLD else (0, 0)
    nG, nM = _group_counts(cfg)

    def m_layer(h, lp):
        y, _ = mamba2_forward(lp["block"], cfg,
                              rmsnorm(lp["norm"], h, cfg.norm_eps))
        return _seq_constraint(h + y), None

    def group(h, gp):
        sp = _select_shared(params, gp["idx"])
        a_in = jnp.concatenate([h, x0], axis=-1)
        y = attention(sp["attn"], cfg,
                      rmsnorm(sp["attn_norm"], a_in, cfg.norm_eps),
                      positions=positions, kind="causal",
                      block_q=bq, block_kv=bkv)
        h = h + y
        a_in = jnp.concatenate([h, x0], axis=-1)
        h = h + mlp(sp["mlp"], rmsnorm(sp["mlp_norm"], a_in, cfg.norm_eps),
                    cfg.activation)
        m_fn = jax.checkpoint(m_layer, prevent_cse=False) if remat else m_layer
        h, _ = jax.lax.scan(m_fn, _seq_constraint(h), gp["mamba"])
        return h, None

    if remat:
        group = jax.checkpoint(group, prevent_cse=False)
    x, _ = jax.lax.scan(group, _seq_constraint(x0),
                        {"mamba": params["mamba"],
                         "idx": jnp.arange(nG, dtype=jnp.int32)})
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               *, force_window: int = 0, dtype=jnp.bfloat16):
    del force_window                          # attention here is always global
    nG, nM = _group_counts(cfg)
    dh = cfg.resolved_head_dim()
    m = jax.vmap(jax.vmap(lambda _: init_mamba2_cache(cfg, batch, dtype)))(
        jnp.arange(nG * nM).reshape(nG, nM))
    attn_c = jax.vmap(lambda _: init_attn_cache(batch, seq_len,
                                                cfg.num_kv_heads, dh, dtype))(
        jnp.arange(nG))
    return {"mamba": m, "attn": attn_c}


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                force_window: int = 0):
    del force_window
    x0 = embed_tokens(params, cfg, token)
    nG, nM = _group_counts(cfg)

    def m_layer(h, lp_cache):
        lp, c = lp_cache
        y, c2 = mamba2_decode(lp["block"], cfg,
                              rmsnorm(lp["norm"], h, cfg.norm_eps), c)
        return h + y, c2

    def group(h, gp_cache):
        gp, gc = gp_cache
        sp = _select_shared(params, gp["idx"])
        a_in = jnp.concatenate([h, x0], axis=-1)
        y, ac = attn_decode(sp["attn"], cfg,
                            rmsnorm(sp["attn_norm"], a_in, cfg.norm_eps),
                            gc["attn"], pos, window=0)
        h = h + y
        a_in = jnp.concatenate([h, x0], axis=-1)
        h = h + mlp(sp["mlp"], rmsnorm(sp["mlp_norm"], a_in, cfg.norm_eps),
                    cfg.activation)
        h, mc = jax.lax.scan(m_layer, h, (gp["mamba"], gc["mamba"]))
        return h, {"mamba": mc, "attn": ac}

    x, new_cache = jax.lax.scan(
        group, x0,
        ({"mamba": params["mamba"], "idx": jnp.arange(nG, dtype=jnp.int32)},
         {"mamba": cache["mamba"], "attn": cache["attn"]}))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache


def prefill(params, cfg: ModelConfig, tokens, *, force_window: int = 0,
            cache_len: int = 0):
    """Prompt prefill: chunked forward threading SSM states + attn KV."""
    del force_window
    from repro.models.transformer import _scatter_ring
    x0 = embed_tokens(params, cfg, tokens)
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    bq, bkv = (BLOCK_Q, BLOCK_KV) if S >= BLOCKWISE_THRESHOLD else (0, 0)
    nG, nM = _group_counts(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)
    total = max(S, cache_len)
    zero = init_cache(cfg, B, total, dtype=cdt)

    def m_layer(h, lp):
        y, st = mamba2_forward(lp["block"], cfg,
                               rmsnorm(lp["norm"], h, cfg.norm_eps),
                               return_cache=True)
        return _seq_constraint(h + y), st

    def group(h, gp):
        sp = _select_shared(params, gp["idx"])
        a_in = jnp.concatenate([h, x0], axis=-1)
        y, (k, v) = attention(sp["attn"], cfg,
                              rmsnorm(sp["attn_norm"], a_in, cfg.norm_eps),
                              positions=positions, kind="causal",
                              block_q=bq, block_kv=bkv, return_kv=True)
        ac = _scatter_ring(k.astype(cdt), v.astype(cdt), positions, total)
        h = h + y
        a_in = jnp.concatenate([h, x0], axis=-1)
        h = h + mlp(sp["mlp"], rmsnorm(sp["mlp_norm"], a_in, cfg.norm_eps),
                    cfg.activation)
        h, m_states = jax.lax.scan(m_layer, h, gp["mamba"])
        return h, {"mamba": m_states, "attn": ac}

    x, st = jax.lax.scan(group, _seq_constraint(x0),
                         {"mamba": params["mamba"],
                          "idx": jnp.arange(nG, dtype=jnp.int32)})
    del zero
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    cache = {"mamba": st["mamba"], "attn": st["attn"]}
    return cache, logits_fn(params, cfg, x[:, -1:, :])
