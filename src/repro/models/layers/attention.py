"""Attention: GQA + qk-norm + logit softcap + sliding window + prefix-LM,
with a memory-bounded blockwise (online-softmax) path for long sequences and
a ring-buffer KV cache for decode.

Position-based masking: every mask is derived from absolute positions of the
query rows (``q_pos``) and of the KV slots (``kv_pos``); a slot with position
``-1`` is invalid (empty ring-buffer slot).  This one rule serves training,
prefill, sliding-window decode and prefix-LM uniformly.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers.embeddings import apply_rope
from repro.models.layers.linear import dense, init_dense
from repro.models.layers.norms import init_rmsnorm, rmsnorm

_NEG_INF = float(jnp.finfo(jnp.float32).min)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attention(key, cfg, *, q_in: int | None = None, kv_in: int | None = None,
                   out_dim: int | None = None, dtype=jnp.float32):
    """q/k/v/o projections (+ optional per-head qk RMSNorm scales)."""
    dh = cfg.resolved_head_dim()
    q_in = q_in or cfg.d_model
    kv_in = kv_in or q_in
    out_dim = out_dim or cfg.d_model
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": init_dense(kq, q_in, cfg.num_heads * dh, dtype),
        "wk": init_dense(kk, kv_in, cfg.num_kv_heads * dh, dtype),
        "wv": init_dense(kv, kv_in, cfg.num_kv_heads * dh, dtype),
        "wo": init_dense(ko, cfg.num_heads * dh, out_dim, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh)
        p["k_norm"] = init_rmsnorm(dh)
    return p


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def _as_b(pos, batch):
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None, :], (batch, pos.shape[0]))
    return pos


def _mask(q_pos, kv_pos, kind: str, window: int, prefix_len) -> jnp.ndarray:
    """(B, 1, 1, Sq, Skv) boolean mask from absolute positions."""
    qp = q_pos[:, None, None, :, None]
    kp = kv_pos[:, None, None, None, :]
    valid = kp >= 0
    if kind == "causal":
        m = kp <= qp
    elif kind == "prefix":
        pl = jnp.asarray(prefix_len, jnp.int32).reshape(-1, 1, 1, 1, 1)
        m = (kp <= qp) | (kp < pl)
    elif kind == "full":
        m = jnp.ones(qp.shape[:-1] + (kp.shape[-1],), bool)
    else:
        raise ValueError(kind)
    if window > 0 and kind != "full":
        m = m & (qp - kp < window)
    return m & valid


# ---------------------------------------------------------------------------
# Scaled dot-product attention (naive + blockwise online-softmax)
# ---------------------------------------------------------------------------

def _scores(q, k, scale: float, softcap: float) -> jnp.ndarray:
    """q: (B,Sq,Hk,G,D)  k: (B,Skv,Hk,D) -> (B,Hk,G,Sq,Skv) float32."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def sdpa(q, k, v, *, q_pos, kv_pos, kind: str = "causal", window: int = 0,
         prefix_len=None, softcap: float = 0.0,
         block_q: int = 0, block_kv: int = 0,
         k_scale=None, v_scale=None) -> jnp.ndarray:
    """General SDPA.

    q: (B, Sq, H, D); k, v: (B, Skv, Hk, D); returns (B, Sq, H, D).
    ``block_q``/``block_kv`` > 0 selects the memory-bounded blockwise path
    (required for 32k+ sequences; see DESIGN.md §3).  Ragged lengths are
    handled by padding the tail block with invalid (position -1) slots.

    ``k_scale``/``v_scale`` ((B, Skv, Hk, 1) absmax scales) mark k/v as an
    int8-quantized cache; the blockwise path dequantizes per KV block inside
    the scan, so the full cache is never materialized at compute precision.
    """
    B, Sq, H, D = q.shape
    Hk = k.shape[2]
    G = H // Hk
    scale = D ** -0.5
    q_pos = _as_b(q_pos, B)
    kv_pos = _as_b(kv_pos, B)
    quantized = k_scale is not None

    if block_kv <= 0 or k.shape[1] <= block_kv:
        # single logical KV block: dequant here is already blockwise
        if quantized:
            k = _dequant_kv(k, k_scale, q.dtype)
            v = _dequant_kv(v, v_scale, q.dtype)
        qg = q.reshape(B, Sq, Hk, G, D)
        s = _scores(qg, k, scale, softcap)
        m = _mask(q_pos, kv_pos, kind, window, prefix_len)
        s = jnp.where(m, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # fully-masked rows produce uniform garbage; zero them via the mask
        p = jnp.where(m.any(-1, keepdims=True), p, 0.0).astype(q.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
        return o.reshape(B, Sq, H, D)

    # ---- blockwise path: outer map over Q blocks, inner scan over KV ----
    # ragged tails are padded: KV slots with position -1 (masked invalid),
    # Q rows with position -1 (fully masked; sliced off the output)
    Skv = k.shape[1]
    pad_kv = -Skv % block_kv
    if pad_kv:
        pad4 = ((0, 0), (0, pad_kv), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad4), jnp.pad(v, pad4)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_kv)), constant_values=-1)
        if quantized:
            k_scale = jnp.pad(k_scale, pad4)
            v_scale = jnp.pad(v_scale, pad4)
    if block_q <= 0 or Sq < block_q:
        block_q = Sq
    pad_q = -Sq % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
    Sq_pad, Skv_pad = q.shape[1], k.shape[1]
    nq, nk = Sq_pad // block_q, Skv_pad // block_kv
    qg = q.reshape(B, Sq_pad, Hk, G, D)

    k_blocks = k.reshape(B, nk, block_kv, Hk, D)
    v_blocks = v.reshape(B, nk, block_kv, Hk, D)
    kp_blocks = kv_pos.reshape(B, nk, block_kv)
    if quantized:
        ks_blocks = k_scale.reshape(B, nk, block_kv, Hk, 1)
        vs_blocks = v_scale.reshape(B, nk, block_kv, Hk, 1)

    def one_q_block(args):
        qb, qpb = args                      # (B,block_q,Hk,G,D), (B,block_q)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, blk):
            m_run, l_run, acc = carry
            if quantized:                   # fused in-scan dequant
                kb, vb, kpb, ksb, vsb = blk
                kb = _dequant_kv(kb, ksb, qb.dtype)
                vb = _dequant_kv(vb, vsb, qb.dtype)
            else:
                kb, vb, kpb = blk           # (B,block_kv,Hk,D), (B,block_kv)
            s = _scores(qb, kb, scale, softcap)           # (B,Hk,G,bq,bk) f32
            msk = _mask(qpb, kpb, kind, window, prefix_len)
            s = jnp.where(msk, s, _NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, G, block_q), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hk, G, block_q, D), jnp.float32)
        xs = [k_blocks.transpose(1, 0, 2, 3, 4),
              v_blocks.transpose(1, 0, 2, 3, 4),
              kp_blocks.transpose(1, 0, 2)]
        if quantized:
            xs += [ks_blocks.transpose(1, 0, 2, 3, 4),
                   vs_blocks.transpose(1, 0, 2, 3, 4)]
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), tuple(xs))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out                           # (B,Hk,G,block_q,D)

    qg_blocks = qg.reshape(B, nq, block_q, Hk, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp_blocks = q_pos.reshape(B, nq, block_q).transpose(1, 0, 2)
    outs = jax.lax.map(one_q_block, (qg_blocks, qp_blocks))
    # outs: (nq, B, Hk, G, block_q, D) -> (B, nq·block_q, Hk, G, D)
    o = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq_pad, Hk, G, D)
    return o.reshape(B, Sq_pad, H, D)[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill / encoder / cross)
# ---------------------------------------------------------------------------

def _project_qkv(params, cfg, x, kv_x, positions, kv_positions, use_rope):
    dh = cfg.resolved_head_dim()
    B, Sq = x.shape[0], x.shape[1]
    kv_x = x if kv_x is None else kv_x
    Skv = kv_x.shape[1]
    q = dense(params["wq"], x).reshape(B, Sq, cfg.num_heads, dh)
    k = dense(params["wk"], kv_x).reshape(B, Skv, cfg.num_kv_heads, dh)
    v = dense(params["wv"], kv_x).reshape(B, Skv, cfg.num_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, _as_b(positions, B), cfg.rope_theta)
        k = apply_rope(k, _as_b(kv_positions, B), cfg.rope_theta)
    return q, k, v


def attention(params, cfg, x, *, positions, kind: str = "causal",
              window: int = 0, prefix_len=None, kv_x=None, kv_positions=None,
              use_rope: bool = True, block_q: int = 0, block_kv: int = 0,
              return_kv: bool = False):
    """Full-sequence attention. x: (B, S, d_in) -> (B, S, out_dim)."""
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(params, cfg, x, kv_x, positions, kv_positions, use_rope)
    o = sdpa(q, k, v, q_pos=positions, kv_pos=kv_positions, kind=kind,
             window=window, prefix_len=prefix_len,
             softcap=cfg.attn_logit_softcap,
             block_q=block_q, block_kv=block_kv)
    B, Sq = x.shape[0], x.shape[1]
    y = dense(params["wo"], o.reshape(B, Sq, -1))
    if return_kv:
        return y, (k, v)
    return y


# ---------------------------------------------------------------------------
# Decode with ring-buffer KV cache
# ---------------------------------------------------------------------------

def kv_cache_int8() -> bool:
    """int8 KV-cache quantization (per-slot-per-head absmax scales): halves
    the decode memory term — §Perf iteration 11. Env-gated so baselines
    stay reproducible."""
    import os
    return os.environ.get("REPRO_KV_INT8", "0") == "1"


def init_attn_cache(batch: int, cache_len: int, num_kv_heads: int, head_dim: int,
                    dtype=jnp.bfloat16):
    if kv_cache_int8():
        return {
            "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim),
                           jnp.int8),
            "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim),
                           jnp.int8),
            "k_scale": jnp.zeros((batch, cache_len, num_kv_heads, 1),
                                 jnp.bfloat16),
            "v_scale": jnp.zeros((batch, cache_len, num_kv_heads, 1),
                                 jnp.bfloat16),
            "kv_pos": jnp.full((batch, cache_len), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "kv_pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def _quant_kv(x):
    """(B, S, Hk, dh) -> (int8 codes, bf16 scales (B,S,Hk,1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequant_kv(q, scale, dtype):
    return (q.astype(jnp.float32) *
            scale.astype(jnp.float32)).astype(dtype)


def _decode_block_kv() -> int:
    """KV block streamed per decode step through the fused path (0 == derive
    from the cache length; the Pallas kernel additionally splits blocks
    across KV splits).  Read per call so REPRO_DECODE_BLOCK_KV behaves like
    every other REPRO_ flag."""
    return int(os.environ.get("REPRO_DECODE_BLOCK_KV", "0"))


def attn_decode(params, cfg, x_t, cache, pos, *, window: int = 0,
                kind: str = "causal", prefix_len=None, block_tbl=None,
                ring_len=None):
    """One decode step.

    x_t: (B, 1, d_in); ``pos`` scalar int32 (synchronous batch decode) OR
    (B,) int32 per-row positions (ragged continuous-batching decode: every
    row advances independently; ``pos[b] == -1`` marks row ``b`` inactive —
    its ring slot is left untouched and its output is fully masked).
    cache: ring buffer from ``init_attn_cache`` (cache_len == window for SWA
    layers, == max_seq for global layers).  Returns (y_t, new_cache).

    Paged mode (``block_tbl`` (B, T) + ``ring_len``): the cache leaves are a
    shared block pool — k/v (n_blocks, block_size, Hk, dh), kv_pos
    (n_blocks, block_size) — and each row's ring slot ``pos % ring_len``
    resolves through its block-table row to a physical pool slot.  Writes
    scatter with ``mode="drop"``: inactive rows and ungranted blocks index
    out of bounds and write nothing, so no freeze pass over the pool is
    needed (allocator invariant: live requests never share a block).

    Attention over the cache goes through the fused flash-decode path
    (``repro.kernels.ops.flash_decode``): Pallas kernel on TPU /
    REPRO_FORCE_KERNELS=1 (block tables ride a scalar-prefetch operand),
    wide/blockwise XLA fallback elsewhere — the kernel and the scan
    fallback dequantize the int8 cache tile-by-tile inside the streamed
    pass (the fallback's short-cache wide form, <= REPRO_DECODE_WIDE_MAX
    slots, trades one O(S) dequant copy for measured speed).  Under an
    active
    mesh with a seq-sharded cache (REPRO_CACHE_SHARD=seq) the step runs
    per-shard with a psum-style combine over ``model``
    (``repro.dist.decode``; paged pools shard the block axis).
    REPRO_FLASH_DECODE=0 restores the legacy dequantize-then-sdpa step.
    """
    B = x_t.shape[0]
    paged = block_tbl is not None
    int8 = "k_scale" in cache
    pos = jnp.asarray(pos, jnp.int32)
    ragged = pos.ndim == 1
    if paged and not ragged:
        raise ValueError("paged decode requires per-row (B,) positions")
    cache_len = None if paged else cache["k"].shape[1]
    pos_b = pos[:, None] if ragged else jnp.full((B, 1), pos, jnp.int32)
    q, k_t, v_t = _project_qkv(
        params, cfg, x_t, None,
        positions=pos_b, kv_positions=pos_b, use_rope=True)

    if paged:
        n_blocks, bs = cache["k"].shape[:2]
        active = pos >= 0
        rl = jnp.asarray(ring_len, jnp.int32)
        slot = jnp.mod(jnp.maximum(pos, 0), rl)             # (B,) ring slot
        pb = block_tbl[jnp.arange(B), slot // bs]           # physical block
        off = slot % bs
        # out-of-bounds index == dropped write (inactive / ungranted rows)
        widx = jnp.where(active & (pb >= 0), pb, n_blocks)

        def upd(buf, val):
            return buf.at[widx, off].set(val[:, 0].astype(buf.dtype),
                                         mode="drop")

        def upd_pos(buf):
            return buf.at[widx, off].set(pos, mode="drop")
    elif ragged:
        # per-row ring slot: every row writes its own slot; inactive rows
        # (pos < 0) keep the old slot contents and stay fully masked below
        active = pos >= 0
        slots = jnp.mod(jnp.maximum(pos, 0), cache_len)        # (B,)
        bidx = jnp.arange(B)

        def upd(buf, val):
            old = buf[bidx, slots]                             # (B, ...)
            keep = active.reshape((B,) + (1,) * (old.ndim - 1))
            return buf.at[bidx, slots].set(
                jnp.where(keep, val[:, 0].astype(buf.dtype), old))

        def upd_pos(buf):
            old = buf[bidx, slots]
            return buf.at[bidx, slots].set(jnp.where(active, pos, old))
    else:
        slot = jnp.mod(pos, cache_len)

        def upd(buf, val):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, val.astype(buf.dtype), slot, axis=1)

        def upd_pos(buf):
            return jax.lax.dynamic_update_slice_in_dim(
                buf, jnp.full((B, 1), pos, jnp.int32), slot, axis=1)

    new_cache = {}
    if int8:
        kq, ks = _quant_kv(k_t)
        vq, vs = _quant_kv(v_t)
        new_cache["k"] = upd(cache["k"], kq)
        new_cache["v"] = upd(cache["v"], vq)
        new_cache["k_scale"] = upd(cache["k_scale"], ks)
        new_cache["v_scale"] = upd(cache["v_scale"], vs)
    else:
        new_cache["k"] = upd(cache["k"], k_t)
        new_cache["v"] = upd(cache["v"], v_t)
    pos_new = upd_pos(cache["kv_pos"])
    new_cache["kv_pos"] = pos_new

    from repro.kernels import ops
    if ops.flash_decode_enabled():
        from repro.dist.decode import seq_shard_mesh, sharded_flash_decode
        kw = dict(k_scale=new_cache.get("k_scale"),
                  v_scale=new_cache.get("v_scale"),
                  kind=kind, window=window, prefix_len=prefix_len,
                  softcap=cfg.attn_logit_softcap,
                  block_kv=_decode_block_kv())  # kernels clamp to cache_len
        if paged:
            kw["block_tables"] = block_tbl
        # sharded layout: slot axis for rings, block axis for paged pools
        mesh = seq_shard_mesh(n_blocks if paged else cache_len)
        if mesh is not None:
            o = sharded_flash_decode(q, new_cache["k"], new_cache["v"],
                                     pos_new, pos, mesh, **kw)
        else:
            o = ops.flash_decode(q, new_cache["k"], new_cache["v"],
                                 pos_new, pos, **kw)
    else:
        # legacy path: full-cache dequant + naive sdpa (A/B baseline only;
        # the blockwise scales-aware sdpa is reachable via block_kv > 0)
        k_leg, v_leg, pos_leg = new_cache["k"], new_cache["v"], pos_new
        ks_leg = new_cache.get("k_scale")
        vs_leg = new_cache.get("v_scale")
        if paged:
            from repro.kernels.flash_decode import paged_gather
            k_leg, v_leg, pos_leg, ks_leg, vs_leg = paged_gather(
                k_leg, v_leg, pos_leg, ks_leg, vs_leg, block_tbl)
        if int8:
            k_full = _dequant_kv(k_leg, ks_leg, q.dtype)
            v_full = _dequant_kv(v_leg, vs_leg, q.dtype)
        else:
            k_full, v_full = k_leg, v_leg
        o = sdpa(q, k_full, v_full,
                 q_pos=pos_b, kv_pos=pos_leg,
                 kind=kind, window=window, prefix_len=prefix_len,
                 softcap=cfg.attn_logit_softcap)
    y = dense(params["wo"], o.reshape(B, 1, -1))
    return y, new_cache


def attn_cross_decode(params, cfg, x_t, mem_k, mem_v, mem_pos):
    """Cross-attention decode step against fixed encoder memory (k/v
    precomputed at prefill).  Same fused decode path as self-attention
    (kind="full": every valid memory slot participates)."""
    B = x_t.shape[0]
    dh = cfg.resolved_head_dim()
    q = dense(params["wq"], x_t).reshape(B, 1, cfg.num_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
    from repro.kernels import ops
    if ops.flash_decode_enabled():
        return dense(params["wo"], ops.flash_decode(
            q, mem_k, mem_v, mem_pos, jnp.zeros((), jnp.int32),
            kind="full", softcap=cfg.attn_logit_softcap,
            block_kv=_decode_block_kv()).reshape(B, 1, -1))
    o = sdpa(q, mem_k, mem_v,
             q_pos=jnp.zeros((B, 1), jnp.int32), kv_pos=mem_pos,
             kind="full", softcap=cfg.attn_logit_softcap)
    return dense(params["wo"], o.reshape(B, 1, -1))
