"""Rotary position embeddings + token/vocab embedding helpers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for RoPE, shape (head_dim // 2,) float32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply rotary embedding.

    x: (..., S, H, Dh) — rotated over the last dim.
    positions: broadcastable to (..., S) int32 absolute positions.
    """
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, dh/2)
    # insert head axis: (..., S, 1, dh/2)
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rot.astype(x.dtype)


def init_embedding(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["table"][tokens]


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied LM head: (..., d) @ (vocab, d)^T -> (..., vocab)."""
    return jnp.einsum("...d,vd->...v", x, params["table"])
