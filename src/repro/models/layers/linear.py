"""Linear projection with first-class LoRA / QLoRA support.

Every linear in the framework goes through ``dense(p, x)``.  The parameter
dict ``p`` dispatches the math:

  {"w"}                                   -> plain matmul
  {"w", "lora_a", "lora_b", "lora_scale"} -> W x + s * B (A x)      (LoRA)
  {"w_nf4", "absmax", ...}                -> dequant(W) x [+ LoRA]  (QLoRA)

This is the paper's C2 mechanism (PEFT) made architecture-agnostic: the
federated layer only ever reads/writes the ``lora_a``/``lora_b`` leaves
(see repro.core.lora), while the base weight stays frozen (and optionally
NF4-quantized) on the device.

NF4 layout: ``w_nf4`` is uint8 of shape (in_dim, out_dim // 2) — two 4-bit
codes packed per byte along the output dim; ``absmax`` is float32 of shape
(in_dim * out_dim // qblock,).  The quantization block size is derived from
the array shapes, so no static metadata needs to ride in the pytree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_dense(key, in_dim: int, out_dim: int, dtype=jnp.float32,
               scale: float | None = None):
    if scale is None:
        scale = in_dim ** -0.5
    w = jax.random.normal(key, (in_dim, out_dim)) * scale
    return {"w": w.astype(dtype)}


def dense(p, x: jnp.ndarray) -> jnp.ndarray:
    """Apply a (possibly LoRA-adapted, possibly NF4-quantized) linear map."""
    if "w_nf4" in p:
        from repro.core.quant import nf4_dequant  # lazy: avoid import cycle
        w = nf4_dequant(p["w_nf4"], p["absmax"]).astype(x.dtype)
    else:
        w = p["w"].astype(x.dtype)
    y = x @ w
    if "lora_a" in p:
        a = p["lora_a"].astype(x.dtype)
        b = p["lora_b"].astype(x.dtype)
        y = y + (x @ a) @ b * p["lora_scale"].astype(x.dtype)
    return y


def dense_out_dim(p) -> int:
    if "w_nf4" in p:
        return p["w_nf4"].shape[-1] * 2
    return p["w"].shape[-1]
