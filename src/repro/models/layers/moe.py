"""Mixture-of-Experts block: top-k router + capacity-based dispatch.

Dispatch is GShard-style (one-hot dispatch/combine einsums with per-group
token capacity) so that compiled FLOPs reflect the *routed* compute
(top-k / E of dense), which is what the roofline analysis must see — a
"compute every expert densely and mask" implementation would overstate MoE
FLOPs by E/k.

Sharding note (DESIGN.md §5): expert weights are (E, d, d_ff) arrays; the
baseline shards d_ff over the ``model`` axis (tensor-parallel experts) since
the assigned expert counts (60, 8) do not divide the 16-way model axis.
Expert-parallel + all-to-all is a §Perf variant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.mlp import init_mlp, mlp


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d, f, e = cfg.d_model, m.expert_d_ff or cfg.d_ff, m.num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s_in, s_ff = d ** -0.5, f ** -0.5
    p = {
        "router": {"w": (jax.random.normal(kr, (d, e)) * s_in).astype(jnp.float32)},
        # routed experts: stacked (E, d, f) / (E, f, d)
        "gate_proj": (jax.random.normal(kg, (e, d, f)) * s_in).astype(dtype),
        "up_proj": (jax.random.normal(ku, (e, d, f)) * s_in).astype(dtype),
        "down_proj": (jax.random.normal(kd, (e, f, d)) * s_ff).astype(dtype),
    }
    if m.num_shared_experts > 0:
        # shared experts are always-on; fuse into one wide MLP
        p["shared"] = init_mlp(ks, d, m.num_shared_experts * f,
                               cfg.activation, dtype)
    return p


def _capacity(group: int, top_k: int, num_experts: int, cf: float) -> int:
    c = int(group * top_k / num_experts * cf) + 1
    return max(4, -(-c // 4) * 4)        # round up to multiple of 4


def moe_block(params, cfg: ModelConfig, x: jnp.ndarray, *,
              group_size: int = 512):
    """x: (B, S, d) -> (y, aux_loss). Capacity-dropped tokens fall through
    with zero routed contribution (shared experts / residual still apply)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    g = min(group_size, T)
    assert T % g == 0, (T, g)
    nG = T // g
    C = _capacity(g, m.top_k, m.num_experts, m.capacity_factor)

    xt = x.reshape(nG, g, d)
    logits = jnp.einsum("Ggd,de->Gge", xt.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                 # (G,g,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)   # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)             # renormalize top-k

    # position of each (token, k) assignment inside its expert's buffer
    onehot = jax.nn.one_hot(expert_idx, m.num_experts, dtype=jnp.int32)  # (G,g,k,E)
    flat = onehot.reshape(nG, g * m.top_k, m.num_experts)
    pos = jnp.cumsum(flat, axis=1) - 1                      # (G,g*k,E)
    pos = (pos * flat).sum(-1).reshape(nG, g, m.top_k)      # (G,g,k)
    in_cap = pos < C

    # dispatch: (G,g,E,C) binary; combine: same with gate weights.
    # Built per-k (python loop, k<=4) to avoid the (G,g,k,E,C) tensor.
    dispatch = jnp.zeros((nG, g, m.num_experts, C), x.dtype)
    combine = jnp.zeros((nG, g, m.num_experts, C), x.dtype)
    for kk in range(m.top_k):
        oe = jax.nn.one_hot(expert_idx[..., kk], m.num_experts,
                            dtype=x.dtype)                  # (G,g,E)
        oc = jax.nn.one_hot(jnp.where(in_cap[..., kk], pos[..., kk], C),
                            C + 1, dtype=x.dtype)[..., :C]  # (G,g,C)
        d_k = oe[..., :, None] * oc[..., None, :]           # (G,g,E,C)
        dispatch = dispatch + d_k
        combine = combine + d_k * gate_vals[..., kk, None, None].astype(x.dtype)

    def expert_compute(disp, comb, xg):
        """(G',g,E,C) x (G',g,d) -> (G',g,d) routed output."""
        expert_in = jnp.einsum("Ggec,Ggd->Gecd", disp, xg)   # (G',E,C,d)
        if cfg.activation == "swiglu":
            h = jax.nn.silu(jnp.einsum("Gecd,edf->Gecf", expert_in,
                                       params["gate_proj"].astype(x.dtype)))
            h = h * jnp.einsum("Gecd,edf->Gecf", expert_in,
                               params["up_proj"].astype(x.dtype))
        elif cfg.activation == "geglu":
            h = jax.nn.gelu(jnp.einsum("Gecd,edf->Gecf", expert_in,
                                       params["gate_proj"].astype(x.dtype)),
                            approximate=True)
            h = h * jnp.einsum("Gecd,edf->Gecf", expert_in,
                               params["up_proj"].astype(x.dtype))
        else:
            h = jax.nn.gelu(jnp.einsum("Gecd,edf->Gecf", expert_in,
                                       params["up_proj"].astype(x.dtype)),
                            approximate=True)
        expert_out = jnp.einsum("Gecf,efd->Gecd", h,
                                params["down_proj"].astype(x.dtype))
        return jnp.einsum("Ggec,Gecd->Ggd", comb, expert_out)

    # Slab-scanned expert compute (REFUTED §Perf hypothesis: the scan blocks
    # SPMD propagation into the body — 6.8x FLOPs, worse memory. Kept
    # opt-in for single-host use; default off.)
    import os
    want = int(os.environ.get("REPRO_MOE_SLABS", "1"))
    n_slabs = want if want > 1 and nG % max(want, 1) == 0 else 1
    if n_slabs > 1:
        slab = nG // n_slabs
        def body(_, args):
            return None, expert_compute(*args)
        _, ys = jax.lax.scan(
            body, None,
            (dispatch.reshape(n_slabs, slab, g, m.num_experts, C),
             combine.reshape(n_slabs, slab, g, m.num_experts, C),
             xt.reshape(n_slabs, slab, g, d)))
        y = ys.reshape(nG, g, d)
    else:
        y = expert_compute(dispatch, combine, xt)
    y = y.reshape(B, S, d)

    if "shared" in params:
        y = y + mlp(params["shared"], x, cfg.activation)

    # load-balance auxiliary loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], m.num_experts, dtype=jnp.float32),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs) \
        * m.router_aux_loss_coef
    return y, aux
