"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, true recurrence).  [arXiv:2405.04517]

mLSTM is computed in the max-stabilized chunkwise form (TPU adaptation: the
original is a fused CUDA recurrence; chunkwise turns it into MXU matmuls +
one ``lax.scan`` over chunk states, exactly like Mamba2's SSD — but with an
exponential input gate that requires running-max stabilization and a
normalizer state).

Cell (per head):
  C_t = f_t C_{t-1} + i_t k_t v_t^T        (matrix memory,  f=σ(f̃), i=exp(ĩ))
  n_t = f_t n_{t-1} + i_t k_t              (normalizer)
  h_t = (C_t^T q_t) / max(|n_t^T q_t|, exp(-m_t))   with running log-max m_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.linear import dense, init_dense
from repro.models.layers.norms import init_rmsnorm, rmsnorm


def mlstm_dims(cfg: ModelConfig):
    x = cfg.xlstm
    d_inner = int(x.mlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    dh = d_inner // H
    return d_inner, H, dh


def init_mlstm_block(key, cfg: ModelConfig, dtype=jnp.float32):
    x = cfg.xlstm
    d_inner, H, dh = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    s = cfg.d_model ** -0.5
    return {
        "up": init_dense(ks[0], cfg.d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (x.conv_width, d_inner)) *
                   (x.conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": init_dense(ks[2], d_inner, d_inner, dtype),
        "wk": init_dense(ks[3], d_inner, d_inner, dtype),
        "wv": init_dense(ks[4], d_inner, d_inner, dtype),
        "w_if": {"w": (jax.random.normal(ks[5], (d_inner, 2 * H)) * s
                       ).astype(jnp.float32)},
        "b_if": jnp.concatenate([jnp.zeros((H,)),                    # i bias
                                 jnp.linspace(3.0, 6.0, H)]),        # f bias
        "norm": init_rmsnorm(d_inner),
        "down": init_dense(ks[6], d_inner, cfg.d_model, dtype),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    x = cfg.xlstm
    d_inner, H, dh = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv_buf": jnp.zeros((batch, x.conv_width - 1, d_inner), dtype),
    }


def _mlstm_qkvif(params, cfg, x):
    d_inner, H, dh = mlstm_dims(cfg)
    up = dense(params["up"], x)
    xm, z = jnp.split(up, 2, axis=-1)
    cx = jax.nn.silu(_causal_conv(xm, params["conv_w"].astype(x.dtype),
                                  params["conv_b"].astype(x.dtype)))
    B_, S = x.shape[0], x.shape[1]
    q = dense(params["wq"], cx).reshape(B_, S, H, dh) * (dh ** -0.5)
    k = dense(params["wk"], cx).reshape(B_, S, H, dh)
    v = dense(params["wv"], xm).reshape(B_, S, H, dh)
    gates = (cx.astype(jnp.float32) @ params["w_if"]["w"] +
             params["b_if"][None, None, :])
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                     # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_pre)
    return q, k, v, z, i_pre, log_f


def mlstm_block_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                        state=None, return_cache: bool = False):
    """x: (B, S, d_model) -> (y, state). Chunked stabilized mLSTM."""
    xc = cfg.xlstm
    d_inner, H, dh = mlstm_dims(cfg)
    B_, S, _ = x.shape
    Lc = min(xc.chunk_size, S)
    pad = (-S) % Lc
    if pad:
        # pad to a chunk multiple (outputs sliced back; see mamba2 note)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nC = S // Lc

    q, k, v, z, i_pre, log_f = _mlstm_qkvif(params, cfg, x)
    if return_cache:
        W = xc.conv_width
        up = dense(params["up"], x)
        xm_tail, _ = jnp.split(up, 2, axis=-1)
        tail = xm_tail[:, max(0, S - pad - (W - 1)):S - pad, :]
        if tail.shape[1] < W - 1:
            tail = jnp.pad(tail, ((0, 0), (W - 1 - tail.shape[1], 0), (0, 0)))

    def chunkify(a):  # (B,S,...) -> (nC,B,Lc,...)
        return a.reshape((B_, nC, Lc) + a.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, a.ndim + 1)))

    qc, kc, vc = chunkify(q.astype(jnp.float32)), chunkify(
        k.astype(jnp.float32)), chunkify(v.astype(jnp.float32))
    ic, fc = chunkify(i_pre), chunkify(log_f)

    if state is None:
        C0 = jnp.zeros((B_, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B_, H, dh), jnp.float32)
        m0 = jnp.zeros((B_, H), jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    causal = jnp.tril(jnp.ones((Lc, Lc), bool))

    def chunk_step(carry, inp):
        C_st, n_st, m_st = carry
        qb, kb, vb, ib, fb = inp          # (B,L,H,dh) / (B,L,H)
        cum = jnp.cumsum(fb, axis=1)                               # (B,L,H)
        # intra weights  w_ij = cum_i - cum_j + i_j   (j <= i)
        w = cum[:, :, None, :] - cum[:, None, :, :] + ib[:, None, :, :]
        w = jnp.where(causal[None, :, :, None], w, -jnp.inf)       # (B,Li,Lj,H)
        s_row = cum + m_st[:, None, :]                             # state path
        m_row = jnp.maximum(w.max(axis=2), s_row)                  # (B,L,H)
        m_row = jnp.maximum(m_row, 0.0)  # lower-bound: |den| floor uses exp(-m)
        p = jnp.exp(w - m_row[:, :, None, :])                      # (B,Li,Lj,H)
        qk = jnp.einsum("blhd,bmhd->blmh", qb, kb)                 # (B,Li,Lj,H)
        num = jnp.einsum("blmh,bmhd->blhd", p * qk, vb)
        den = jnp.einsum("blmh->blh", p * qk)
        st_scale = jnp.exp(s_row - m_row)                          # (B,L,H)
        num = num + st_scale[..., None] * jnp.einsum(
            "blhd,bhde->blhe", qb, C_st)
        den = den + st_scale * jnp.einsum("blhd,bhd->blh", qb, n_st)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_row))[..., None]

        # state update (to end of chunk)
        cum_L = cum[:, -1, :]                                      # (B,H)
        w_end = cum_L[:, None, :] - cum[:, :, :] + ib              # (B,L,H)
        m_next = jnp.maximum(m_st + cum_L, w_end.max(axis=1))
        sc = jnp.exp(w_end - m_next[:, None, :])                   # (B,L,H)
        C_new = (jnp.exp(m_st + cum_L - m_next)[:, :, None, None] * C_st +
                 jnp.einsum("blh,blhd,blhe->bhde", sc, kb, vb))
        n_new = (jnp.exp(m_st + cum_L - m_next)[:, :, None] * n_st +
                 jnp.einsum("blh,blhd->bhd", sc, kb))
        return (C_new, n_new, m_next), h

    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                    (qc, kc, vc, ic, fc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B_, S, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(params["down"], y)
    if pad:
        out = out[:, :S - pad]
    new_state = {"C": Cf, "n": nf, "m": mf}
    if return_cache:
        new_state["conv_buf"] = tail
    return out, new_state


def mlstm_block_decode(params, cfg: ModelConfig, x_t, cache):
    """x_t: (B,1,d_model) single-step recurrent mLSTM."""
    d_inner, H, dh = mlstm_dims(cfg)
    B_ = x_t.shape[0]
    up = dense(params["up"], x_t)
    xm, z = jnp.split(up, 2, axis=-1)
    buf = jnp.concatenate([cache["conv_buf"],
                           xm.astype(cache["conv_buf"].dtype)], axis=1)
    w = params["conv_w"].astype(x_t.dtype)
    cx = jax.nn.silu(jnp.einsum("bwc,wc->bc", buf, w) +
                     params["conv_b"].astype(x_t.dtype))[:, None, :]
    new_buf = buf[:, 1:, :]
    q = dense(params["wq"], cx).reshape(B_, H, dh).astype(jnp.float32) * (dh ** -0.5)
    k = dense(params["wk"], cx).reshape(B_, H, dh).astype(jnp.float32)
    v = dense(params["wv"], xm).reshape(B_, H, dh).astype(jnp.float32)
    gates = (cx[:, 0].astype(jnp.float32) @ params["w_if"]["w"] +
             params["b_if"][None, :])
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                     # (B,H)
    log_f = jax.nn.log_sigmoid(f_pre)

    m_new = jnp.maximum(log_f + cache["m"], i_pre)
    f_sc = jnp.exp(log_f + cache["m"] - m_new)
    i_sc = jnp.exp(i_pre - m_new)
    C_new = f_sc[:, :, None, None] * cache["C"] + \
        i_sc[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = f_sc[:, :, None] * cache["n"] + i_sc[:, :, None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.einsum("bhd,bhd->bh", q, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    h = h.reshape(B_, 1, d_inner).astype(x_t.dtype)
    y = rmsnorm(params["norm"], h, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(params["down"], y)
    return out, {"C": C_new, "n": n_new, "m": m_new, "conv_buf": new_buf}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, exponential gating, block-diagonal recurrence)
# ---------------------------------------------------------------------------

def slstm_dims(cfg: ModelConfig):
    H = cfg.num_heads
    dh = cfg.d_model // H
    d_ff = int(cfg.xlstm.slstm_proj_factor * cfg.d_model)
    return H, dh, d_ff


def init_slstm_block(key, cfg: ModelConfig, dtype=jnp.float32):
    H, dh, d_ff = slstm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_in": init_dense(ks[0], d, 4 * d, dtype),     # z,i,f,o pre-acts
        "r": (jax.random.normal(ks[1], (4, H, dh, dh)) * (dh ** -0.5)
              ).astype(jnp.float32),                    # recurrent, block-diag
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              jnp.ones((d,)) * 3.0,     # f bias
                              jnp.zeros((d,))]),
        "norm": init_rmsnorm(d),
        "ffn_gate": init_dense(ks[2], d, d_ff, dtype),
        "ffn_up": init_dense(ks[3], d, d_ff, dtype),
        "ffn_down": init_dense(ks[4], d_ff, d, dtype),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "m": z, "h": z}


def _slstm_cell(params, cfg, x_pre, state):
    """One sLSTM step. x_pre: (B, 4d) input pre-activations (before recurrent
    contribution); state dict of (B, d)."""
    H, dh, _ = slstm_dims(cfg)
    d = cfg.d_model
    B_ = x_pre.shape[0]
    hprev = state["h"].reshape(B_, H, dh)
    rec = jnp.einsum("ghde,bhd->gbhe", params["r"], hprev).reshape(4, B_, d)
    pre = x_pre.astype(jnp.float32) + \
        jnp.concatenate([rec[0], rec[1], rec[2], rec[3]], axis=-1) + \
        params["b"][None, :]
    zp, ip, fp, op = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zp)
    o = jax.nn.sigmoid(op)
    log_f = jax.nn.log_sigmoid(fp)
    m_new = jnp.maximum(log_f + state["m"], ip)
    i_sc = jnp.exp(ip - m_new)
    f_sc = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_sc * state["c"] + i_sc * z
    n_new = f_sc * state["n"] + i_sc
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_block_forward(params, cfg: ModelConfig, x: jnp.ndarray, state=None):
    """x: (B, S, d_model) -> (y, state). Sequential scan over time."""
    B_, S, d = x.shape
    x_pre = dense(params["w_in"], x)                                # (B,S,4d)
    st = state if state is not None else init_slstm_cache(cfg, B_)

    def step(carry, xt):
        new = _slstm_cell(params, cfg, xt, carry)
        return new, new["h"]

    st_f, hs = jax.lax.scan(step, st, x_pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)                       # (B,S,d)
    h = rmsnorm(params["norm"], h, cfg.norm_eps)
    y = dense(params["ffn_down"],
              jax.nn.gelu(dense(params["ffn_gate"], h), approximate=True) *
              dense(params["ffn_up"], h))
    return y, st_f


def slstm_block_decode(params, cfg: ModelConfig, x_t, cache):
    x_pre = dense(params["w_in"], x_t)[:, 0, :]
    st = _slstm_cell(params, cfg, x_pre, cache)
    h = rmsnorm(params["norm"], st["h"][:, None, :].astype(x_t.dtype),
                cfg.norm_eps)
    y = dense(params["ffn_down"],
              jax.nn.gelu(dense(params["ffn_gate"], h), approximate=True) *
              dense(params["ffn_up"], h))
    return y, st
