"""Feed-forward blocks: SwiGLU / GeGLU / plain GELU."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.linear import dense, init_dense


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32,
             out_dim: int | None = None):
    out_dim = out_dim or d_model
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "gate": init_dense(k1, d_model, d_ff, dtype),
            "up": init_dense(k2, d_model, d_ff, dtype),
            "down": init_dense(k3, d_ff, out_dim, dtype),
        }
    return {
        "up": init_dense(k1, d_model, d_ff, dtype),
        "down": init_dense(k2, d_ff, out_dim, dtype),
    }


def mlp(params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "swiglu":
        h = jax.nn.silu(dense(params["gate"], x)) * dense(params["up"], x)
    elif activation == "geglu":
        h = jax.nn.gelu(dense(params["gate"], x), approximate=True) * dense(params["up"], x)
    elif activation == "gelu":
        h = jax.nn.gelu(dense(params["up"], x), approximate=True)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return dense(params["down"], h)
