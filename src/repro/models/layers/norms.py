"""Normalization layers (from scratch — no flax)."""

from __future__ import annotations

import jax.numpy as jnp


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6, *, gemma_style: bool = False):
    """RMSNorm in f32, cast back. ``gemma_style`` uses (1 + scale)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * (var + eps) ** -0.5
    scale = params["scale"].astype(jnp.float32)
    y = y * (1.0 + scale) if gemma_style else y * scale
    return y.astype(dtype)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * (var + eps) ** -0.5
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)
