"""Mamba2 (SSD — state-space duality) block, chunked-parallel formulation.

TPU adaptation (DESIGN.md §3): instead of the CUDA selective-scan kernel, we
use the chunkwise matmul decomposition — intra-chunk attention-like matmuls
(MXU friendly) + an inter-chunk ``lax.scan`` over the (H, P, N) state.  All
decay exponentials are differences of cumulative *negative* log-decays, so
every ``exp`` argument is ≤ 0 (stable by construction, no max-shift needed).

State update:   h_t = exp(dt_t * -exp(A_log)) h_{t-1} + (dt_t x_t) ⊗ B_t
Output:         y_t = C_t · h_t + D ⊙ x_t
Gating/out:     out = out_proj( RMSNorm(y) * silu(z) )
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.linear import dense, init_dense
from repro.models.layers.norms import init_rmsnorm, rmsnorm


def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    # in_proj emits [z, x, B, C, dt]
    conv_dim = d_inner + 2 * s.state_dim
    return d_inner, n_heads, conv_dim


def init_mamba2(key, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * s.state_dim + n_heads
    p = {
        "in_proj": init_dense(k1, cfg.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(k2, (s.conv_width, conv_dim)) *
                   (s.conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (n_heads,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))).astype(jnp.float32),
        "norm": init_rmsnorm(d_inner),
        "out_proj": init_dense(k4, d_inner, cfg.d_model, dtype),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,Cd); w: (W,Cd)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, n_heads, _ = mamba2_dims(cfg)
    z, xc, Bm, Cm, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + s.state_dim,
         2 * d_inner + 2 * s.state_dim],
        axis=-1)
    return z, xc, Bm, Cm, dt


def mamba2_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                   initial_state=None, return_cache: bool = False):
    """x: (B, S, d_model) -> (y, final_state).  S must divide by chunk_size."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    B_, S, _ = x.shape
    Lc = min(s.chunk_size, S)
    pad = (-S) % Lc
    if pad:
        # pad to a chunk multiple; outputs are sliced back. NOTE: the
        # returned state then reflects the padded steps — callers that
        # thread state (prefill) must pass chunk-aligned S.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nC = S // Lc

    zxbcdt = dense(params["in_proj"], x)
    z, xc, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    if return_cache:
        W = s.conv_width
        tail = conv_in[:, max(0, S - pad - (W - 1)):S - pad, :]
        if tail.shape[1] < W - 1:
            tail = jnp.pad(tail, ((0, 0), (W - 1 - tail.shape[1], 0), (0, 0)))
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"].astype(x.dtype),
                                        params["conv_b"].astype(x.dtype)))
    xc, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + s.state_dim], axis=-1)

    H, P, N = n_heads, s.head_dim, s.state_dim
    xh = xc.reshape(B_, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])          # (B,S,H)
    la = -jnp.exp(params["A_log"])[None, None, :] * dt              # (B,S,H) <= 0
    xb = xh.astype(jnp.float32) * dt[..., None]                     # dt folded into x

    # chunk views
    xb_c = xb.reshape(B_, nC, Lc, H, P)
    B_c = Bm.reshape(B_, nC, Lc, N).astype(jnp.float32)
    C_c = Cm.reshape(B_, nC, Lc, N).astype(jnp.float32)
    la_c = la.reshape(B_, nC, Lc, H)
    cum = jnp.cumsum(la_c, axis=2)                                  # (B,C,L,H)

    # ---- intra-chunk (causal "attention" with decay) ----
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]             # (B,C,Li,Lj,H)
    causal = jnp.tril(jnp.ones((Lc, Lc), bool))
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)                                            # <= 1
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)
    w = cb[..., None] * decay                                       # (B,C,Li,Lj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xb_c)

    # ---- chunk states + inter-chunk scan ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,C,L,H)
    S_chunk = jnp.einsum("bclh,bcln,bclhp->bchpn",
                         decay_to_end, B_c, xb_c)                   # (B,C,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                         # (B,C,H)

    h0 = (initial_state if initial_state is not None
          else jnp.zeros((B_, H, P, N), jnp.float32))

    def chunk_step(h, inp):
        s_c, cd = inp                                               # (B,H,P,N),(B,H)
        h_out = h                                                   # state BEFORE chunk
        h_new = h * cd[:, :, None, None] + s_c
        return h_new, h_out

    h_final, h_before = jax.lax.scan(
        chunk_step, h0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)                    # (B,C,H,P,N)

    y_inter = jnp.einsum("bcln,bchpn->bclhp", C_c, h_before) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(B_, S, H, P) + \
        params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, S, d_inner).astype(x.dtype)

    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(params["out_proj"], y)
    if pad:
        out = out[:, :S - pad]
    if return_cache:
        return out, {"ssm_state": h_final, "conv_buf": tail}
    return out, h_final


# ---------------------------------------------------------------------------
# Decode (single-step recurrence)
# ---------------------------------------------------------------------------

def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    return {
        "ssm_state": jnp.zeros((batch, n_heads, s.head_dim, s.state_dim),
                               jnp.float32),
        "conv_buf": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def mamba2_decode(params, cfg: ModelConfig, x_t: jnp.ndarray, cache):
    """x_t: (B, 1, d_model) -> (y_t, new_cache)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    B_ = x_t.shape[0]
    zxbcdt = dense(params["in_proj"], x_t)
    z, xc, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)                # (B,1,Cd)

    buf = jnp.concatenate([cache["conv_buf"],
                           conv_in.astype(cache["conv_buf"].dtype)], axis=1)
    w = params["conv_w"].astype(x_t.dtype)                          # (W,Cd)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", buf, w) + params["conv_b"].astype(x_t.dtype))
    new_buf = buf[:, 1:, :]
    xc1, Bm1, Cm1 = jnp.split(conv_out, [d_inner, d_inner + s.state_dim],
                              axis=-1)

    H, P, N = n_heads, s.head_dim, s.state_dim
    xh = xc1.reshape(B_, H, P).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) +
                          params["dt_bias"][None, :])               # (B,H)
    a = jnp.exp(-jnp.exp(params["A_log"])[None, :] * dt1)           # (B,H)
    xb = xh * dt1[..., None]
    h_new = cache["ssm_state"] * a[:, :, None, None] + \
        jnp.einsum("bhp,bn->bhpn", xb, Bm1.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm1.astype(jnp.float32), h_new) + \
        params["D"][None, :, None] * xh
    y = y.reshape(B_, 1, d_inner).astype(x_t.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(params["out_proj"], y)
    return out, {"ssm_state": h_new, "conv_buf": new_buf}
