"""VLM backbone (paligemma-3b): stub SigLIP patch embeddings -> linear
projector -> gemma-style prefix-LM decoder.

Vision tower carve-out per the assignment: patch embeddings arrive
precomputed with shape (B, num_image_tokens, vision_embed_dim); we implement
the projector + the language decoder with bidirectional attention over the
image prefix and causal attention over the text suffix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.linear import dense, init_dense
from repro.models import transformer as tfm


def init(cfg: ModelConfig, key) -> dict:
    kv, kt = jax.random.split(key)
    p = tfm.init(cfg, kt)
    p["vis_proj"] = init_dense(kv, cfg.vlm.vision_embed_dim, cfg.d_model,
                               jnp.dtype(cfg.param_dtype))
    return p


def _merge(params, cfg: ModelConfig, patches, tokens):
    """(B,P,vis_d) + (B,St) -> merged (B, P+St, d_model)."""
    vis = dense(params["vis_proj"],
                patches.astype(jnp.dtype(cfg.compute_dtype)))
    txt = tfm.embed_tokens(params, cfg, tokens)
    return jnp.concatenate([vis, txt], axis=1)


def forward(params, cfg: ModelConfig, patches, tokens, *,
            remat: bool = True):
    """Prefix-LM forward. Returns final hidden over the merged sequence."""
    x = _merge(params, cfg, patches, tokens)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    prefix = jnp.full((x.shape[0],), cfg.vlm.num_image_tokens, jnp.int32)
    return tfm.forward_hidden(params, cfg, x, positions=positions,
                              prefix_len=prefix, remat=remat)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               *, force_window: int = 0, dtype=jnp.bfloat16):
    return tfm.init_cache(cfg, batch, seq_len, force_window=force_window,
                          dtype=dtype)


def prefill(params, cfg: ModelConfig, patches, tokens, *,
            force_window: int = 0, cache_len: int = 0):
    """Image + prompt prefill -> (cache, last logits).

    Reuses the dense-transformer prefill on the merged embedding sequence
    (prefix-LM mask over the image tokens).
    """
    from repro.models.layers.norms import rmsnorm
    from repro.models.transformer import (
        BLOCK_KV, BLOCK_Q, BLOCKWISE_THRESHOLD, _scatter_ring,
        _seq_constraint, logits_fn)
    from repro.models.layers.attention import attention
    from repro.models.layers.mlp import mlp

    x = _merge(params, cfg, patches, tokens)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    prefix = jnp.full((B,), cfg.vlm.num_image_tokens, jnp.int32)
    bq, bkv = (BLOCK_Q, BLOCK_KV) if S >= BLOCKWISE_THRESHOLD else (0, 0)
    w = force_window or cfg.sliding_window
    total = max(S, cache_len)
    cl = min(total, w) if w > 0 else total
    cdt = jnp.dtype(cfg.compute_dtype)

    def body(h, lp):
        a_in = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, (k, v) = attention(lp["attn"], cfg, a_in, positions=positions,
                              kind="prefix", prefix_len=prefix, window=w,
                              block_q=bq, block_kv=bkv, return_kv=True)
        c = _scatter_ring(k.astype(cdt), v.astype(cdt), positions, cl)
        h = h + a
        h = h + mlp(lp["mlp"], rmsnorm(lp["mlp_norm"], h, cfg.norm_eps),
                    cfg.activation)
        return _seq_constraint(h), c

    x, cache = jax.lax.scan(body, _seq_constraint(x), params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return cache, logits_fn(params, cfg, x[:, -1:, :])


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                force_window: int = 0):
    prefix = jnp.full((token.shape[0],), cfg.vlm.num_image_tokens, jnp.int32)
    return tfm.decode_step(params, cfg, cache, token, pos,
                           force_window=force_window, prefix_len=prefix)
