"""MoE decoder family: mixtral-8x7b (top-2, SWA) and qwen2-moe-a2.7b
(4 shared + 60 routed, top-4).

Identical trunk to the dense transformer, with the FFN replaced by the
capacity-dispatched MoE block; the router aux loss threads through the layer
scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.attention import (
    attention, attn_decode, init_attention, init_attn_cache)
from repro.models.layers.moe import init_moe, moe_block
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.models.transformer import (
    BLOCK_KV, BLOCK_Q, BLOCKWISE_THRESHOLD, _seq_constraint, embed_tokens,
    logits_fn)
from repro.models.layers.embeddings import init_embedding
from repro.models.layers.linear import init_dense


def _init_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg, dtype=dtype),
        "moe_norm": init_rmsnorm(cfg.d_model),
        "moe": init_moe(k2, cfg, dtype),
    }


def init(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kh = jax.random.split(key, 3)
    keys = jax.random.split(kl, cfg.num_layers)
    p = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_dense(kh, cfg.d_model, cfg.vocab_size, dtype)
    return p


def forward(params, cfg: ModelConfig, tokens, *, remat: bool = True):
    """tokens (B,S) -> (final hidden, total aux loss)."""
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    blockwise = S >= BLOCKWISE_THRESHOLD
    bq, bkv = (BLOCK_Q, BLOCK_KV) if blockwise else (0, 0)

    def body(carry, lp):
        h, aux = carry
        a = attention(lp["attn"], cfg,
                      rmsnorm(lp["attn_norm"], h, cfg.norm_eps),
                      positions=positions, kind="causal",
                      window=cfg.sliding_window, block_q=bq, block_kv=bkv)
        h = h + a
        m, aux_l = moe_block(lp["moe"], cfg,
                             rmsnorm(lp["moe_norm"], h, cfg.norm_eps))
        h = _seq_constraint(h + m)
        return (h, aux + aux_l), None

    body_fn = jax.checkpoint(body, prevent_cse=False) if remat else body
    (x, aux), _ = jax.lax.scan(
        body_fn, (_seq_constraint(x), jnp.zeros((), jnp.float32)),
        params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               *, force_window: int = 0, dtype=jnp.bfloat16):
    dh = cfg.resolved_head_dim()
    w = force_window or cfg.sliding_window
    cl = min(seq_len, w) if w > 0 else seq_len
    return jax.vmap(lambda _: init_attn_cache(batch, cl, cfg.num_kv_heads,
                                              dh, dtype))(
        jnp.arange(cfg.num_layers))


def decode_step(params, cfg: ModelConfig, cache, token, pos, *,
                force_window: int = 0, block_tbl=None, ring_len=None):
    x = embed_tokens(params, cfg, token)
    w = force_window or cfg.sliding_window

    def body(h, lp_cache):
        lp, c = lp_cache
        a, c2 = attn_decode(lp["attn"], cfg,
                            rmsnorm(lp["attn_norm"], h, cfg.norm_eps),
                            c, pos, window=w, block_tbl=block_tbl,
                            ring_len=ring_len)
        h = h + a
        m, _ = moe_block(lp["moe"], cfg,
                         rmsnorm(lp["moe_norm"], h, cfg.norm_eps))
        return h + m, c2

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, x), new_cache


def prefill(params, cfg: ModelConfig, tokens, *, force_window: int = 0,
            cache_len: int = 0, true_len=None):
    from repro.models.transformer import _finalize_prefill, _scatter_ring
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    blockwise = S >= BLOCKWISE_THRESHOLD
    bq, bkv = (BLOCK_Q, BLOCK_KV) if blockwise else (0, 0)
    w = force_window or cfg.sliding_window
    total = max(S, cache_len)
    cl = min(total, w) if w > 0 else total
    cache_dtype = jnp.dtype(cfg.compute_dtype)

    def body(h, lp):
        a_in = rmsnorm(lp["attn_norm"], h, cfg.norm_eps)
        a, (k, v) = attention(lp["attn"], cfg, a_in, positions=positions,
                              kind="causal", window=w, block_q=bq,
                              block_kv=bkv, return_kv=True)
        c = _scatter_ring(k.astype(cache_dtype), v.astype(cache_dtype),
                          positions, cl)
        h = h + a
        m, _ = moe_block(lp["moe"], cfg,
                         rmsnorm(lp["moe_norm"], h, cfg.norm_eps))
        return _seq_constraint(h + m), c

    x, cache = jax.lax.scan(body, _seq_constraint(x), params["layers"])
    return _finalize_prefill(params, cfg, x, cache, true_len)
