"""qwen2-moe-a2.7b — MoE decoder, 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from repro.configs.base import ModelConfig, MoEConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                          # routed expert intermediate size
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        num_shared_experts=4,
        expert_d_ff=1408,
        capacity_factor=1.25,
    ),
    decode_sliding_window=4096,
    fedtime=FedTimeConfig(),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2-moe-a2.7b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=128,
        vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      expert_d_ff=128, capacity_factor=1.5),
        param_dtype="float32",
        compute_dtype="float32",
    )
