"""paligemma-3b — VLM: SigLIP (stub) + gemma decoder backbone.
[arXiv:2407.07726]

Vision tower carve-out: ``input_specs()`` provides precomputed SigLIP patch
embeddings; we implement the projector + gemma-style prefix-LM decoder.
"""

from repro.configs.base import ModelConfig, VLMConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,                     # MQA
    head_dim=256,                       # gemma-2b head_dim
    d_ff=16_384,
    vocab_size=257_216,
    rope_theta=10_000.0,
    activation="geglu",
    tie_embeddings=True,
    embedding_multiplier=45.254833995939045,  # sqrt(2048)
    vlm=VLMConfig(
        num_image_tokens=256,
        vision_embed_dim=1152,
        prefix_lm=True,
    ),
    decode_sliding_window=4096,
    fedtime=FedTimeConfig(),
    source="arXiv:2407.07726 (PaliGemma)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="paligemma-3b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        embedding_multiplier=16.0,
        vlm=VLMConfig(num_image_tokens=16, vision_embed_dim=96, prefix_lm=True),
        param_dtype="float32",
        compute_dtype="float32",
    )
