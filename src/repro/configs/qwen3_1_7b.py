"""qwen3-1.7b — dense decoder, qk-norm, GQA. [hf:Qwen/Qwen3-8B family card]"""

from repro.configs.base import ModelConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    activation="swiglu",
    tie_embeddings=True,
    decode_sliding_window=4096,
    fedtime=FedTimeConfig(),
    source="hf:Qwen/Qwen3-8B (1.7B sibling card)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-1.7b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
