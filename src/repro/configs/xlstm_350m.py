"""xlstm-350m — sLSTM + mLSTM recurrent blocks (attention-free).
[arXiv:2405.04517]

d_ff=0 in the assignment: xLSTM blocks carry their own up/down projections
(pre-up-projection mLSTM blocks, post-FFN sLSTM blocks) instead of a separate
transformer FFN.
"""

from repro.configs.base import ModelConfig, XLSTMConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    activation="swiglu",
    xlstm=XLSTMConfig(
        slstm_every=6,                  # blocks 5, 11, 17, 23 are sLSTM
        mlstm_proj_factor=2.0,
        slstm_proj_factor=1.333,
        conv_width=4,
        chunk_size=128,
    ),
    fedtime=FedTimeConfig(),
    source="arXiv:2405.04517 (xLSTM, 350M)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="xlstm-350m-smoke",
        num_layers=2,
        d_model=256,
        num_heads=2,
        num_kv_heads=2,
        head_dim=128,
        vocab_size=512,
        xlstm=XLSTMConfig(slstm_every=2, chunk_size=32),
        param_dtype="float32",
        compute_dtype="float32",
    )
