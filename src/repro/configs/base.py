"""Configuration system for the repro framework.

A single ``ModelConfig`` dataclass describes every architecture family the
framework supports (dense decoder, MoE decoder, encoder-decoder, VLM, SSM,
hybrid).  Each assigned architecture gets one module in ``repro.configs``
exporting ``CONFIG`` (the exact published dims) and ``smoke_config()`` (a
reduced variant for CPU smoke tests).

Configs are plain frozen dataclasses — hashable so they can be closed over
by jitted functions as static data.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (GShard-style dispatch)."""

    num_experts: int = 8
    top_k: int = 2
    num_shared_experts: int = 0          # Qwen2-MoE style always-on experts
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # d_ff of each routed expert (may differ from the dense d_ff)
    expert_d_ff: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    state_dim: int = 64                  # N — per-head SSM state size
    head_dim: int = 64                   # P — channels per SSM head
    expand: int = 2                      # d_inner = expand * d_model
    conv_width: int = 4                  # depthwise causal conv width
    chunk_size: int = 128                # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block configuration (mLSTM + sLSTM mix)."""

    slstm_every: int = 6                 # every k-th block is sLSTM (rest mLSTM)
    mlstm_proj_factor: float = 2.0       # up-projection factor for mLSTM blocks
    slstm_proj_factor: float = 1.333     # FFN factor for sLSTM blocks
    conv_width: int = 4
    chunk_size: int = 128


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + shared attention block."""

    shared_attn_every: int = 6           # apply the (weight-shared) attn block
                                         # every k mamba layers
    num_shared_blocks: int = 2           # distinct shared transformer blocks
                                         # (Zamba2 uses 2, round-robin)


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder split (seamless-m4t style backbone)."""

    encoder_layers: int = 12
    # decoder layer count == ModelConfig.num_layers
    encoder_bidirectional: bool = True
    max_source_len: int = 4096           # frame-embedding memory length cap


@dataclass(frozen=True)
class VLMConfig:
    """VLM backbone (paligemma style): prefix-LM over stub patch embeddings."""

    num_image_tokens: int = 256          # SigLIP 224px/14 => 256 patches
    vision_embed_dim: int = 1152         # SigLIP-So400m width (stub output)
    prefix_lm: bool = True               # bidirectional attention over prefix


@dataclass(frozen=True)
class FedTimeConfig:
    """The paper's TS front-end (C1) + federation hyper-params (C3/C5)."""

    # --- PatchTST-style front end ---
    lookback: int = 512                  # L
    horizon: int = 96                    # T
    patch_len: int = 16                  # P
    patch_stride: int = 8                # S (overlapping patches)
    revin: bool = True                   # RevIN in forecasting-FT phase
    # --- federation ---
    num_clients: int = 555               # paper's setup
    num_clusters: int = 8                # K in K-means
    clients_per_round: int = 16
    local_steps: int = 40                # paper grid: {40, 80, 200}
    # --- PEFT ---
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_dropout: float = 0.0
    qlora: bool = True                   # NF4-quantize frozen base weights
    qlora_block: int = 64                # absmax block size (NF4 default)
    # --- DPO alignment ---
    dpo_beta: float = 0.1
    dpo_pairs: int = 10_000              # paper: 10K comparison pairs


@dataclass(frozen=True)
class ModelConfig:
    """One config to describe every supported architecture."""

    name: str
    family: str                          # dense | moe | encdec | vlm | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 => d_model // num_heads
    # --- attention variants ---
    qk_norm: bool = False                # Qwen3-style per-head RMSNorm on q,k
    attn_logit_softcap: float = 0.0      # Gemma2 (50.0); 0 disables
    final_logit_softcap: float = 0.0     # Gemma2 (30.0); 0 disables
    sliding_window: int = 0              # 0 => full attention
    local_global_alternating: bool = False   # Gemma2 local/global layer pairs
    rope_theta: float = 10_000.0
    max_seq_len: int = 524_288
    # --- MLP ---
    activation: str = "swiglu"           # swiglu | geglu | gelu
    # --- norm / embedding ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embedding_multiplier: float = 1.0    # Gemma scales embeds by sqrt(d_model)
    post_block_norm: bool = False        # Gemma2 post-norms
    # --- family sub-configs ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    fedtime: Optional[FedTimeConfig] = None
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- provenance ---
    source: str = ""                     # citation (model card / arXiv)
    # --- decode-time overrides ---
    # For pure full-attention archs, long_500k decode runs under this
    # sliding-window variant (see DESIGN.md §4 long_500k policy).
    decode_sliding_window: int = 0

    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim()

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim()

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "encdec", "vlm", "ssm", "hybrid"), self.family
        assert self.num_heads % max(self.num_kv_heads, 1) == 0 or self.family in ("ssm",), (
            f"{self.name}: num_heads={self.num_heads} not divisible by "
            f"num_kv_heads={self.num_kv_heads}"
        )
        if self.family == "moe":
            assert self.moe is not None
        if self.family == "ssm":
            assert self.xlstm is not None or self.ssm is not None
        if self.family == "hybrid":
            assert self.ssm is not None and self.hybrid is not None
        if self.family == "encdec":
            assert self.encdec is not None
        if self.family == "vlm":
            assert self.vlm is not None


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (public pool).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}
