"""gemma2-27b — dense, local+global alternating attention, logit softcap.
[arXiv:2408.00118]"""

from repro.configs.base import ModelConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,                       # gemma2-27b model card
    d_ff=36_864,
    vocab_size=256_000,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,                # local layers' window
    local_global_alternating=True,
    rope_theta=10_000.0,
    activation="geglu",
    tie_embeddings=True,
    embedding_multiplier=67.88225099390856,   # sqrt(4608)
    post_block_norm=True,
    fedtime=FedTimeConfig(),
    source="arXiv:2408.00118 (Gemma 2)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="gemma2-27b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        sliding_window=64,
        embedding_multiplier=16.0,
        param_dtype="float32",
        compute_dtype="float32",
    )
