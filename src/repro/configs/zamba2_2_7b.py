"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]"""

from repro.configs.base import ModelConfig, SSMConfig, HybridConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,                      # mamba2 layers
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10_240,                        # shared block FFN
    vocab_size=32_000,
    activation="geglu",
    ssm=SSMConfig(
        state_dim=64,                   # ssm_state=64 per assignment
        head_dim=64,
        expand=2,
        conv_width=4,
        chunk_size=128,
    ),
    hybrid=HybridConfig(
        shared_attn_every=6,            # 54/6 = 9 shared-block applications
        num_shared_blocks=2,            # Zamba2 round-robins 2 shared blocks
    ),
    fedtime=FedTimeConfig(),
    source="arXiv:2411.15242 (Zamba2)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-2.7b-smoke",
        num_layers=4,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, chunk_size=32),
        hybrid=HybridConfig(shared_attn_every=2, num_shared_blocks=2),
        param_dtype="float32",
        compute_dtype="float32",
    )
