from repro.configs.base import (
    EncDecConfig,
    FedTimeConfig,
    HybridConfig,
    INPUT_SHAPES,
    InputShape,
    MoEConfig,
    ModelConfig,
    SHAPES_BY_NAME,
    SSMConfig,
    VLMConfig,
    XLSTMConfig,
)
from repro.configs.registry import (
    ALL_ARCHS,
    ASSIGNED_ARCHS,
    get_config,
    get_smoke_config,
)

__all__ = [
    "EncDecConfig", "FedTimeConfig", "HybridConfig", "INPUT_SHAPES",
    "InputShape", "MoEConfig", "ModelConfig", "SHAPES_BY_NAME", "SSMConfig",
    "VLMConfig", "XLSTMConfig", "ALL_ARCHS", "ASSIGNED_ARCHS", "get_config",
    "get_smoke_config",
]
