"""The paper's own backbone: LLaMA-2-7B used as the FedTime LLM encoder.
[arXiv:2302.13971 / Touvron et al. 2023; paper §3.2 "LLM Encoder"]

This is the 11th config — not from the assigned pool, but the architecture
the paper itself federates. Used by the FedTime benchmarks and the
paper-representative dry-run/hillclimb pair.
"""

from repro.configs.base import ModelConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="fedtime-llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,                    # llama-2 7B uses MHA
    head_dim=128,
    d_ff=11_008,
    vocab_size=32_000,
    rope_theta=10_000.0,
    activation="swiglu",
    decode_sliding_window=4096,
    fedtime=FedTimeConfig(
        lookback=512,
        horizon=720,
        patch_len=16,
        patch_stride=8,
        num_clients=555,
        num_clusters=8,
        lora_rank=8,
        qlora=True,
    ),
    source="arXiv:2307.09288 (LLaMA-2 7B); paper §3.2",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="fedtime-llama2-7b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        fedtime=FedTimeConfig(
            lookback=96, horizon=24, patch_len=8, patch_stride=4,
            num_clients=8, num_clusters=2, clients_per_round=4,
            local_steps=2, lora_rank=4, dpo_pairs=16,
        ),
        param_dtype="float32",
        compute_dtype="float32",
    )
