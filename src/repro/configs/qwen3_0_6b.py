"""qwen3-0.6b — dense decoder, qk-norm, GQA. [hf:Qwen/Qwen3-8B family card]"""

from repro.configs.base import ModelConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,                       # Qwen3 uses explicit head_dim=128
    d_ff=3072,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    activation="swiglu",
    tie_embeddings=True,
    decode_sliding_window=4096,         # long_500k SWA variant (DESIGN.md §4)
    fedtime=FedTimeConfig(),
    source="hf:Qwen/Qwen3-8B (0.6B sibling card)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="qwen3-0.6b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
