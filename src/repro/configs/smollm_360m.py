"""smollm-360m — llama-arch small dense decoder. [hf:HuggingFaceTB/SmolLM-135M card family]"""

from repro.configs.base import ModelConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,                        # 960 / 15
    d_ff=2560,
    vocab_size=49_152,
    rope_theta=10_000.0,
    activation="swiglu",
    tie_embeddings=True,
    decode_sliding_window=4096,
    fedtime=FedTimeConfig(),
    source="hf:HuggingFaceTB/SmolLM-360M",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="smollm-360m-smoke",
        num_layers=2,
        d_model=192,
        num_heads=3,
        num_kv_heads=1,
        head_dim=64,
        d_ff=384,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
