"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ModelConfig

# arch id -> module name under repro.configs
_ARCH_MODULES: Dict[str, str] = {
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen3-1.7b": "qwen3_1_7b",
    "smollm-360m": "smollm_360m",
    "gemma2-27b": "gemma2_27b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "paligemma-3b": "paligemma_3b",
    "xlstm-350m": "xlstm_350m",
    "zamba2-2.7b": "zamba2_2_7b",
    "fedtime-llama2-7b": "fedtime_llama2_7b",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(
    a for a in _ARCH_MODULES if a != "fedtime-llama2-7b"
)
ALL_ARCHS: Tuple[str, ...] = tuple(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(sorted(_ARCH_MODULES))}"
        )
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    cfg = _module(arch).CONFIG
    cfg.validate()
    return cfg


def get_smoke_config(arch: str) -> ModelConfig:
    cfg = _module(arch).smoke_config()
    cfg.validate()
    return cfg
