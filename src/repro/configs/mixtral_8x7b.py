"""mixtral-8x7b — MoE decoder, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""

from repro.configs.base import ModelConfig, MoEConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    sliding_window=4096,                # SWA on every layer
    rope_theta=1_000_000.0,
    activation="swiglu",
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        num_shared_experts=0,
        expert_d_ff=14_336,
        capacity_factor=1.25,
    ),
    fedtime=FedTimeConfig(),
    source="arXiv:2401.04088 (Mixtral of Experts)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="mixtral-8x7b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=256,
        vocab_size=512,
        sliding_window=64,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=0,
                      expert_d_ff=256, capacity_factor=1.5),
        param_dtype="float32",
        compute_dtype="float32",
    )
