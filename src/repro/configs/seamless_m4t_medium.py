"""seamless-m4t-medium — encoder-decoder multimodal (audio) backbone.
[arXiv:2308.11596]

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: ``input_specs()`` provides precomputed frame embeddings of shape
(batch, frames, d_model). We implement the transformer backbone (encoder +
autoregressive text decoder with cross-attention).
"""

from repro.configs.base import ModelConfig, EncDecConfig, FedTimeConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,                      # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    rope_theta=10_000.0,
    activation="gelu",                  # conformer-adjacent FFN; GELU per card
    tie_embeddings=True,                # shared embed/unembed (m4t text decoder)
    encdec=EncDecConfig(
        encoder_layers=12,
        encoder_bidirectional=True,
        max_source_len=4096,
    ),
    decode_sliding_window=4096,
    fedtime=FedTimeConfig(),
    source="arXiv:2308.11596 (SeamlessM4T, medium)",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        name="seamless-m4t-medium-smoke",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        encdec=EncDecConfig(encoder_layers=2, max_source_len=128),
        param_dtype="float32",
        compute_dtype="float32",
    )
