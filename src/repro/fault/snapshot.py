"""Atomic round-state snapshots for mid-round crash recovery.

The federated trainer's mutable round state is small but scattered:
per-cluster server adapters + FedAdam moments, per-client EF wire
residuals, the staleness buffer of late deltas, the participation clock,
the numpy RNG counters driving cohort sampling, and the virtual clock.
``save_round_state`` packs all of it into one pytree and writes it
through :mod:`repro.train.checkpoint` — which since this PR writes
temp-file + fsync + atomic rename, so a kill-9 mid-write leaves either
the previous complete snapshot or the new complete snapshot, never a
torn file.  ``load_round_state`` refuses anything that is not a valid
snapshot of the expected schema.

Array state rides as ordinary checkpoint leaves (bit-exact restore);
non-array state (RNG counters, the participation clock, buffered-entry
metadata, round logs) is JSON-encoded into a uint8 leaf — numpy's PCG64
state contains 128-bit integers that no array dtype holds, and JSON does.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import numpy as np

from repro.train import checkpoint

__all__ = ["SNAPSHOT_SCHEMA", "save_round_state", "load_round_state"]

SNAPSHOT_SCHEMA = "repro.fault.roundstate/v1"
_META_KEY = "__meta__"


def _pack_json(obj: Any) -> np.ndarray:
    return np.frombuffer(json.dumps(obj).encode("utf-8"), dtype=np.uint8)


def _unpack_json(arr) -> Any:
    return json.loads(np.asarray(arr).tobytes().decode("utf-8"))


def save_round_state(path: str, arrays: Dict[str, Any],
                     meta: Dict[str, Any]) -> int:
    """Write one atomic snapshot.  ``arrays`` is a pytree of array state
    (string-keyed dicts only — no lists, so the template-free load
    round-trips); ``meta`` is any JSON-serializable metadata.  Returns
    bytes written."""
    if _META_KEY in arrays:
        raise ValueError(f"{_META_KEY} is reserved for snapshot metadata")
    tree = dict(arrays)
    tree[_META_KEY] = _pack_json({**meta, "schema": SNAPSHOT_SCHEMA})
    return checkpoint.save(path, tree)


def load_round_state(path: str) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Load a snapshot → ``(meta, arrays)``.  Raises ``ValueError`` on a
    missing/incompatible schema (and ``checkpoint.load`` itself raises on
    truncated or corrupt files)."""
    if not os.path.exists(path):
        raise FileNotFoundError(f"round-state snapshot not found: {path}")
    tree = checkpoint.load(path)
    if _META_KEY not in tree:
        raise ValueError(f"{path} is not a round-state snapshot "
                         f"(missing {_META_KEY})")
    meta = _unpack_json(tree.pop(_META_KEY))
    if meta.get("schema") != SNAPSHOT_SCHEMA:
        raise ValueError(
            f"{path}: snapshot schema {meta.get('schema')!r} != "
            f"{SNAPSHOT_SCHEMA!r}")
    return meta, tree
