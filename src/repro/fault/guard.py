"""Server-side delta validation: the last line of defence before
aggregation.

Two screens, applied to every upload (on-time and drained-from-buffer)
in a round's cohort:

  * **finite** — any NaN/Inf anywhere in the delta rejects it
    (``reason="corrupt"``).  One corrupt client would otherwise poison
    the FedAdam moments for every client in the cluster, permanently.
  * **norm** — a delta whose L2 norm exceeds ``byz_k`` × the cohort
    median norm rejects (``reason="byzantine"``).  The median is taken
    over the finite norms of the *same cohort*, so the attacker cannot
    inflate its own acceptance threshold unless it controls half the
    round (the standard robust-statistics argument; matches the
    MAD-style straggler flagging in ``repro.obs.fleet``).

Validation is cohort-at-once (not per-upload) because the norm screen
needs the cohort median first.
"""

from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["delta_norm", "logits_finite", "validate_deltas"]


def logits_finite(logits):
    """Per-lane finite screen for a ``(B, V)`` logits slice, traceable
    inside jit — the serving mirror of the **finite** delta screen.

    Returns a ``(B,)`` bool vector: ``False`` where any entry of that
    lane's vocab row is NaN/Inf.  The serve step evaluates this on every
    decode step's last-position logits so a poisoned request is caught
    the step it turns non-finite, *before* its sampled token is emitted;
    the engine quarantines only the offending lane (``ok`` is per-lane,
    so neighbours in the same ragged batch are untouched)."""
    return jnp.all(jnp.isfinite(logits), axis=-1)


def delta_norm(tree) -> float:
    """Global L2 norm of a delta pytree (NaN if any leaf is non-finite —
    NaN propagates through the sum, which is exactly what we want the
    finite screen to see)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return 0.0
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                              for l in leaves)))


def validate_deltas(deltas: Sequence, *, byz_k: float = 25.0,
                    norms: Optional[Sequence[float]] = None
                    ) -> List[Tuple[bool, Optional[str], float]]:
    """Validate a round cohort of delta trees.

    Returns one ``(ok, reason, norm)`` per delta, ``reason`` in
    ``{"corrupt", "byzantine", None}``.  Pass precomputed ``norms`` to
    skip the reduction (the trainer already has them for telemetry)."""
    if norms is None:
        norms = [delta_norm(d) for d in deltas]
    norms = [float(n) for n in norms]
    finite = [n for n in norms if math.isfinite(n)]
    med = float(np.median(finite)) if finite else 0.0
    out: List[Tuple[bool, Optional[str], float]] = []
    for n in norms:
        if not math.isfinite(n):
            out.append((False, "corrupt", n))
        elif med > 0.0 and n > byz_k * med:
            out.append((False, "byzantine", n))
        else:
            out.append((True, None, n))
    return out
