"""Declarative, deterministic fault schedules for federated clients.

A :class:`FaultPlan` maps client ids to lists of :class:`Fault` specs and
answers two questions the round loop asks:

  * :meth:`FaultPlan.attempt` — given a client's base (virtual) fit
    duration, how long until its upload arrives, and does it arrive at
    all?  This is where crash/hang/transient/delay faults act, entirely
    on the virtual clock.
  * :meth:`FaultPlan.mutate_delta` — what does the server actually
    *receive*?  This is where corrupt (NaN/Inf) and byzantine
    (norm-scaled) faults act, applied to the post-wire (dequantized)
    delta — modelling damage on the upload path, after the client's
    honest EF quantization.

Plans are plain data: deterministic from their construction (or from the
seed of :meth:`FaultPlan.random`), so a chaos run replays bit-identically
— which is what lets the crash-recovery test compare a kill-9'd round
against an uninterrupted one.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FAULT_KINDS", "SERVE_FAULT_KINDS", "Fault", "FaultPlan",
           "Attempt", "ServingFaultPlan"]

#: crash   — client computes but dies before upload (nothing arrives)
#: hang    — client never returns (arrival at +inf; the deadline excludes it)
#: transient — ``fails`` failed attempts with exponential backoff, then success
#: corrupt — upload arrives with non-finite values (NaN/Inf)
#: byzantine — upload arrives scaled by ``scale`` (norm attack)
#: delay   — upload arrives ``delay_s`` virtual seconds late
FAULT_KINDS = ("crash", "hang", "transient", "corrupt", "byzantine", "delay")

#: Request-scoped fault kinds for the *serving* chaos harness
#: (``ServingFaultPlan``), one per request rather than per client:
#: malformed — prompt carries out-of-vocabulary token ids (quarantined at
#:             submit, before any device work)
#: poison    — NaN injected into the request's logits row mid-decode
#:             (quarantined by the in-step guard; neighbours untouched)
#: deadline  — the request's deadline is set tighter than its decode can
#:             finish (cancelled mid-decode with full reclamation)
#: burst     — the request arrives inside a submit burst that overflows
#:             the bounded queue (exercises cost-aware load shedding)
#: kill      — the engine process is SIGKILL'd while this request is
#:             mid-decode (journal replay must resume it bit-identically)
SERVE_FAULT_KINDS = ("malformed", "poison", "deadline", "burst", "kill")


@dataclass(frozen=True)
class Fault:
    """One fault spec.  ``rounds=None`` fires every round, otherwise only
    on the given rounds."""

    kind: str
    rounds: Optional[FrozenSet[int]] = None
    delay_s: float = 0.0           # delay: extra virtual seconds
    fails: int = 2                 # transient: failed attempts before success
    backoff_s: float = 0.25        # transient: base backoff, doubles per retry
    scale: float = 100.0           # byzantine: delta multiplier
    mode: str = "nan"              # corrupt: "nan" | "inf"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind {self.kind!r}: choose from {FAULT_KINDS}")

    def active(self, round_idx: int) -> bool:
        return self.rounds is None or round_idx in self.rounds


@dataclass(frozen=True)
class Attempt:
    """Outcome of one client's round attempt on the virtual clock."""

    client: int
    round: int
    outcome: str                   # "ok" | "crash" | "hang"
    virtual_s: float               # total virtual duration incl. retries
    retries: int = 0
    kinds: Tuple[str, ...] = ()

    @property
    def uploads(self) -> bool:
        """Does a payload ever reach the server?"""
        return self.outcome == "ok"


@dataclass
class FaultPlan:
    """Per-client fault schedule; see module docstring.

    ``base_fit_s``: if set, every fit costs exactly this many virtual
    seconds (fully deterministic timelines — what the chaos/CI tests
    use).  If ``None``, the measured wall time of the real fit is used as
    the base (what the ``slow_clients`` shim preserves, so straggler
    detection still sees real compute skew plus the injected delay).
    """

    faults: Dict[int, List[Fault]] = field(default_factory=dict)
    base_fit_s: Optional[float] = None
    seed: int = 0

    # -- queries -------------------------------------------------------------

    def faults_for(self, client: int, round_idx: int) -> List[Fault]:
        return [f for f in self.faults.get(int(client), ())
                if f.active(round_idx)]

    def kinds_for(self, client: int, round_idx: int) -> Tuple[str, ...]:
        return tuple(f.kind for f in self.faults_for(client, round_idx))

    def will_upload(self, client: int, round_idx: int) -> bool:
        """False when a crash/hang fault means the fit result is never
        delivered — the round loop skips the (expensive) real fit then."""
        return not ({"crash", "hang"} &
                    set(self.kinds_for(client, round_idx)))

    def fault_rate(self, n_clients: int) -> float:
        return len(self.faults) / max(n_clients, 1)

    # -- timing --------------------------------------------------------------

    def attempt(self, client: int, round_idx: int,
                base_s: float) -> Attempt:
        """Resolve this client's round on the virtual clock.  ``base_s``
        is the duration of one clean fit (``base_fit_s`` overrides the
        caller's measurement when set)."""
        base = self.base_fit_s if self.base_fit_s is not None else base_s
        virtual = base
        retries = 0
        outcome = "ok"
        kinds = self.kinds_for(client, round_idx)
        for f in self.faults_for(client, round_idx):
            if f.kind == "delay":
                virtual += f.delay_s
            elif f.kind == "transient":
                # each failed attempt costs a full fit plus its backoff
                for i in range(f.fails):
                    virtual += base + f.backoff_s * (2 ** i)
                retries += f.fails
            elif f.kind == "crash":
                outcome = "crash"            # dies at upload time
            elif f.kind == "hang":
                outcome = "hang"
                virtual = math.inf
        return Attempt(int(client), round_idx, outcome, virtual,
                       retries, kinds)

    # -- payload -------------------------------------------------------------

    def mutate_delta(self, client: int, round_idx: int, delta):
        """Apply corrupt/byzantine faults to the delta the server
        receives (post-wire: the damage is on the upload path, not in the
        client's honest EF quantization)."""
        for f in self.faults_for(client, round_idx):
            if f.kind == "corrupt":
                bad = jnp.nan if f.mode == "nan" else jnp.inf
                delta = jax.tree.map(
                    lambda l: (l.reshape(-1).at[0].set(bad).reshape(l.shape)
                               if l.size else l), delta)
            elif f.kind == "byzantine":
                delta = jax.tree.map(lambda l: l * f.scale, delta)
        return delta

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_slow_clients(cls, slow: Dict[int, float]) -> "FaultPlan":
        """The legacy ``slow_clients={id: seconds}`` kwarg as a plan:
        pure virtual delay, measured base — straggler-detection tests see
        the same wall_s they used to, without any ``time.sleep``."""
        return cls({int(c): [Fault("delay", delay_s=float(s))]
                    for c, s in slow.items()})

    @classmethod
    def random(cls, n_clients: int, rate: float, rounds: int, *,
               seed: int = 0, kinds: Tuple[str, ...] = FAULT_KINDS[:5],
               per_round_p: float = 0.6,
               base_fit_s: float = 1.0) -> "FaultPlan":
        """Deterministic chaos: ~``rate`` of the clients get one fault of
        a random kind, firing independently per round with probability
        ``per_round_p`` (at least one round always fires).  Same seed →
        same plan, bit for bit."""
        rng = np.random.default_rng(seed)
        faults: Dict[int, List[Fault]] = {}
        for cid in range(n_clients):
            if rng.random() >= rate:
                continue
            kind = kinds[int(rng.integers(len(kinds)))]
            active = frozenset(int(r) for r in range(rounds)
                               if rng.random() < per_round_p)
            if not active:
                active = frozenset({int(rng.integers(max(rounds, 1)))})
            faults[cid] = [Fault(kind, rounds=active)]
        return cls(faults, base_fit_s=base_fit_s, seed=seed)

    @classmethod
    def random_serving(cls, n_requests: int, rate: float, *,
                       seed: int = 0,
                       kinds: Tuple[str, ...] = SERVE_FAULT_KINDS[:4]
                       ) -> "ServingFaultPlan":
        """Request-scoped chaos for the serving harness: ~``rate`` of the
        requests (by index in submission order) each get one fault kind.
        Deterministic from ``seed``, like :meth:`random`.  ``kill`` is
        excluded from the default kinds because the harness injects the
        engine SIGKILL at a chosen step rather than per request."""
        return ServingFaultPlan.random(n_requests, rate, seed=seed,
                                       kinds=kinds)


# ---------------------------------------------------------------------------
# Request-scoped serving faults
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ServingFaultPlan:
    """Per-*request* fault schedule for the serving chaos harness.

    Maps a request's index in submission order to one of
    :data:`SERVE_FAULT_KINDS`.  The harness consumes it declaratively:
    ``malformed`` rewrites the prompt via :meth:`malform_prompt` before
    submit, ``poison`` arms the engine's NaN injector for that request id,
    ``deadline`` submits with an unmeetable deadline, ``burst`` batches
    the submit into an overflow burst, ``kill`` marks where the harness
    SIGKILLs the engine.  Deterministic from construction (or
    :meth:`random`'s seed), so a chaos trace replays bit-identically."""

    faults: Dict[int, str] = field(default_factory=dict)
    seed: int = 0

    def __post_init__(self):
        for idx, kind in self.faults.items():
            if kind not in SERVE_FAULT_KINDS:
                raise ValueError(f"serving fault kind {kind!r} for request "
                                 f"{idx}: choose from {SERVE_FAULT_KINDS}")

    def kind_for(self, request_idx: int) -> Optional[str]:
        return self.faults.get(int(request_idx))

    def indices(self, kind: str) -> Tuple[int, ...]:
        """Request indices carrying ``kind``, in submission order."""
        return tuple(sorted(i for i, k in self.faults.items() if k == kind))

    def fault_rate(self, n_requests: int) -> float:
        return len(self.faults) / max(n_requests, 1)

    def malform_prompt(self, request_idx: int, prompt: np.ndarray,
                       vocab_size: int) -> np.ndarray:
        """Deterministically damage one prompt token to an
        out-of-vocabulary id (the submit-time validator must catch it)."""
        rng = np.random.default_rng((self.seed, int(request_idx)))
        bad = np.array(prompt, dtype=np.int32, copy=True)
        bad[int(rng.integers(bad.shape[0]))] = vocab_size + int(
            rng.integers(1, 7))
        return bad

    @classmethod
    def random(cls, n_requests: int, rate: float, *, seed: int = 0,
               kinds: Tuple[str, ...] = SERVE_FAULT_KINDS[:4]
               ) -> "ServingFaultPlan":
        """~``rate`` of the requests each get one uniformly-chosen fault
        kind; same seed → same plan, bit for bit."""
        rng = np.random.default_rng(seed)
        faults: Dict[int, str] = {}
        for idx in range(n_requests):
            if rng.random() < rate:
                faults[idx] = kinds[int(rng.integers(len(kinds)))]
        return cls(faults, seed=seed)
