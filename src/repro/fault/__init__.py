"""``repro.fault`` — deterministic fault injection + round recovery.

FedTime's premise is millions of edge clients; at that scale crashed,
hung, corrupt, and malicious clients are the steady state, not the
exception.  This package gives the federated trainer the machinery to
*survive* them, deterministically enough to test in CI:

  * :mod:`repro.fault.clock` — a virtual clock.  Fit durations, retry
    backoffs, and round deadlines are virtual seconds, so a chaos run
    covering hours of simulated wall time executes in milliseconds (the
    old ``time.sleep``-based ``slow_clients`` hack is a thin shim over
    this now).
  * :mod:`repro.fault.plan` — :class:`FaultPlan` / :class:`Fault`: a
    declarative per-client fault schedule (crash-before-upload, hang,
    transient-fail-then-recover with exponential backoff, corrupt/NaN
    delta, byzantine-scaled delta, plain delay), deterministic from a
    seed, replayable round by round.
  * :mod:`repro.fault.guard` — server-side delta validation: non-finite
    uploads and norm-outlier (byzantine) uploads are rejected before they
    can poison aggregation.
  * :mod:`repro.fault.snapshot` — atomic round-state snapshots
    (aggregated adapters + FedAdam moments, EF residuals, staleness
    buffer, participation clock, RNG counters, virtual clock) through the
    crash-safe :mod:`repro.train.checkpoint` writer, so a kill-9'd server
    resumes the same round bit-identically.

``train/fed_trainer.federated_fit(fault_plan=..., deadline_s=...,
snapshot_path=...)`` threads all four together; every injected fault,
rejection, retry, and recovery emits through ``repro.obs`` (fleet-ledger
reasons + flight-recorder distress instants).

The same machinery extends into the *serve* layer:
:class:`~repro.fault.plan.ServingFaultPlan` schedules request-scoped
faults (malformed prompt, NaN poison, deadline-buster, submit burst,
engine kill), :func:`~repro.fault.guard.logits_finite` is the in-jit
per-lane screen the serve step runs on every decode slice, and
:class:`VirtualClock` paces request deadlines/TTFT SLOs in
``serve/engine.py`` — see the README "Serving fault tolerance" section.
"""

from repro.fault.clock import VirtualClock
from repro.fault.guard import delta_norm, logits_finite, validate_deltas
from repro.fault.plan import (FAULT_KINDS, SERVE_FAULT_KINDS, Attempt,
                              Fault, FaultPlan, ServingFaultPlan)
from repro.fault.snapshot import (SNAPSHOT_SCHEMA, load_round_state,
                                  save_round_state)

__all__ = [
    "Attempt", "FAULT_KINDS", "Fault", "FaultPlan", "SERVE_FAULT_KINDS",
    "SNAPSHOT_SCHEMA", "ServingFaultPlan", "VirtualClock", "delta_norm",
    "load_round_state", "logits_finite", "save_round_state",
    "validate_deltas",
]
