"""Virtual time for the federated round loop.

All fault-tolerance timing (fit durations, retry backoff, round
deadlines, staleness windows) is measured on this clock, never on
``time.sleep``: a 64-client chaos round with multi-second injected hangs
executes in milliseconds, and the timeline is exactly reproducible —
including across a crash/resume, because the clock is part of the round
snapshot.
"""

from __future__ import annotations

__all__ = ["VirtualClock"]


class VirtualClock:
    """Monotonic virtual clock.  ``now()`` is seconds since the start of
    the simulation; ``advance``/``advance_to`` move it forward (never
    backward — a round deadline that already passed costs nothing extra).
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance virtual clock by {dt} < 0")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        """Move to ``t`` if it is in the future; no-op otherwise."""
        self._t = max(self._t, float(t))
        return self._t

    def __repr__(self) -> str:                # pragma: no cover - cosmetic
        return f"VirtualClock(t={self._t:.3f}s)"
