"""FedTime's federation mapped onto mesh collectives (Algorithm 1, DESIGN.md §3).

Cluster aggregation (Algorithm 1, lines 12-14) is a weighted psum of the
LoRA adapter deltas over the ``data`` axis: each data-slice of the mesh
plays one cluster member, training on its own shard of the batch.  The
cross-site aggregation of the paper's two-site (Caltech/JPL) ACN setting
crosses the ``pod`` axis.  Because ``repro.dist.sharding`` pins the
adapters to replication, the payload each round is exactly the LoRA tree —
FedTime's communication profile (paper Fig. 5): base weights receive no
grads and no traffic.

The aggregation itself runs on the communication fast path by default:
``repro.dist.fedcomm.ring_aggregate`` — the hand-rolled bidirectional ring
all-reduce of ``repro.kernels.ring_allreduce`` on the ``REPRO_FED_WIRE``
wire format (int8 codes + absmax scales, bf16, or f32), with f32 master
accumulation and an error-feedback residual carried between rounds.
``REPRO_FED_RING=0`` restores the generic XLA psum lowering.

``expected_collective_bytes`` recomputes the per-device ring all-reduce
bytes implied by this axis mapping (exact chunk plan, wire encoding
included).  ``repro.core.comm.collective_bytes_per_round`` measures the
same quantity from the comm-accounting side, and the kernel's byte ledger
measures it from the actual ppermute buffers;
``tests/test_dist_fed_mapping.py`` and ``tests/test_ring_collective.py``
keep the three in agreement so the §Roofline collective term and the
paper's Fig. 5 comm metric remain one number measured three ways.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.lora import lora_tree, tree_nbytes
from repro.dist.sharding import _mesh_shape

# Who carries what: every slice along ``data`` is one cluster member; the
# ``pod`` axis separates sites.
CLUSTER_AXIS = "data"
CROSS_SITE_AXIS = "pod"


def aggregation_axes(mesh) -> tuple:
    """Mesh axes the federated psum reduces over, innermost first."""
    shape = _mesh_shape(mesh)
    return tuple(ax for ax in (CLUSTER_AXIS, CROSS_SITE_AXIS)
                 if shape.get(ax, 1) > 1)


def ring_allreduce_bytes(payload_bytes: int, n: int, *,
                         wire: str = "f32") -> int:
    """Per-device bytes moved by an ``n``-way bidirectional ring all-reduce
    of an f32 payload of ``payload_bytes``, in the ``wire`` encoding.

    The count is the kernel's exact chunk plan
    (``repro.core.comm.ring_wire_plan``), not the idealized continuous
    formula: the payload is carved into 2·n chunks of
    ceil(elems / 2n) elements (quantized wires round the chunk up to a
    ``REPRO_FED_QBLOCK`` multiple so absmax scales cover whole blocks), a
    device sends each chunk once per reduce-scatter hop and once per
    all-gather hop, and the int8 wire's per-chunk f32 scales are counted.
    On a divisible f32 payload this reduces exactly to the classic
    2·P·(n-1)/n; non-divisible payloads pay their real padding instead of
    silently truncating to the float formula."""
    from repro.core.comm import ring_wire_bytes
    return ring_wire_bytes(-(-payload_bytes // 4), n, wire)


def adapter_payload_bytes(params) -> int:
    """Bytes of the federated payload — the LoRA tree only (f32)."""
    return tree_nbytes(lora_tree(params))


def expected_collective_bytes(params, mesh, wire: str = None) -> dict:
    """Per-axis ring all-reduce bytes for one aggregation round under this
    module's axis mapping, on the given wire format (default
    ``REPRO_FED_WIRE``).  Must agree with
    ``repro.core.comm.collective_bytes_per_round`` and with the ring
    kernel's measured byte ledger.  Counts payload ELEMENTS directly (like
    the accounting side), so the agreement holds whatever dtype the
    adapters are stored in."""
    from repro.core.comm import ring_wire_bytes, wire_format
    from repro.core.lora import count_params
    shape = _mesh_shape(mesh)
    elems = count_params(lora_tree(params))
    wire = wire or wire_format()
    return {ax: ring_wire_bytes(elems, shape.get(ax, 1), wire)
            for ax in (CLUSTER_AXIS, CROSS_SITE_AXIS)}


def fed_psum(tree, mesh):
    """All-reduce a pytree over the federation axes.  Call from inside a
    ``shard_map``/``pmap`` body where the axis names are bound; outside a
    collective context this is an error by construction."""
    axes = aggregation_axes(mesh)
    if not axes:
        return tree
    return jax.tree.map(lambda x: jax.lax.psum(x, axes), tree)


def mask_members(member_adapters, weights, alive):
    """Partial participation on the mesh path (``repro.fault``): zero out
    dropped members' rows AND weights, renormalizing the surviving
    weights to sum to 1.  Zeroing the rows matters, not just the weights:
    a crashed member's buffer can legitimately hold NaN/Inf, and
    ``0 · NaN = NaN`` — a zero weight alone cannot keep the poison out of
    the reduction.  Returns ``(masked_adapters, renormalized_weights)``
    shaped exactly like the inputs, so the ring fast path's compiled
    cache key is unchanged."""
    alive = jnp.asarray(alive)
    w = jnp.asarray(weights, jnp.float32) * alive.astype(jnp.float32)
    total = w.sum()
    w = jnp.where(total > 0, w / jnp.where(total > 0, total, 1.0), w)

    def zero_dead(a):
        m = alive.reshape((alive.shape[0],) + (1,) * (a.ndim - 1))
        return jnp.where(m.astype(bool), a, jnp.zeros_like(a))

    return jax.tree.map(zero_dead, member_adapters), w


def aggregate_adapters(member_adapters, weights, mesh=None, *,
                       alive=None, wire: str = None, state: dict = None,
                       byte_ledger: list = None):
    """Algorithm 1, lines 12-14: weighted aggregation of member adapter
    trees, Σ_k w_k · Δ_k with Σ w_k = 1 (w_k = n_k / n cluster sizes).

    Every leaf of ``member_adapters`` carries a leading member dim of size
    ``len(weights)``.  Without a real multi-axis mesh this reduces locally.
    On a mesh whose federation axes are live, the member dim is sharded
    over them and the reduction is the hand-rolled bidirectional ring
    all-reduce on the ``wire`` format (default ``REPRO_FED_WIRE``) —
    ``repro.dist.fedcomm.ring_aggregate``, which also accepts the
    error-feedback ``state`` and the measuring ``byte_ledger``; passing
    ``state`` makes this return ``(tree, new_state)``.  ``REPRO_FED_RING=0``
    restores the generic psum lowering below.

    ``alive`` (optional bool/0-1 vector over the member dim) handles
    partial participation: dropped members are excluded via
    :func:`mask_members` — rows zeroed, weights renormalized over the
    survivors — before the reduction, on either lowering."""
    from repro.dist import fedcomm
    if alive is not None:
        member_adapters, weights = mask_members(member_adapters, weights,
                                                alive)
    axes = aggregation_axes(mesh) if mesh is not None else ()
    if axes and isinstance(mesh, Mesh) and fedcomm.ring_enabled():
        return fedcomm.ring_aggregate(member_adapters, weights, mesh,
                                      wire=wire, state=state,
                                      byte_ledger=byte_ledger)

    weights = jnp.asarray(weights, jnp.float32)
    n = weights.shape[0]

    def wsum(w, a):
        return (w.reshape((w.shape[0],) + (1,) * (a.ndim - 1)).astype(a.dtype)
                * a).sum(axis=0)

    if not axes or not isinstance(mesh, Mesh):
        out = jax.tree.map(lambda a: wsum(weights, a), member_adapters)
        return out if state is None else (out, state)

    prod = 1
    for ax in axes:
        prod *= _mesh_shape(mesh)[ax]
    if n % prod:
        raise ValueError(
            f"member dim {n} must divide the federation axes {axes} ({prod})")

    from jax.experimental.shard_map import shard_map
    member_spec = P(axes if len(axes) > 1 else axes[0])

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(member_spec, member_spec),
                       out_specs=P(), check_rep=False)
    def agg(ad, w):
        local = jax.tree.map(lambda a: wsum(w, a), ad)
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), local)

    out = agg(member_adapters, weights)
    return out if state is None else (out, state)
