"""FedTime's federation mapped onto mesh collectives (Algorithm 1, DESIGN.md §3).

Cluster aggregation (Algorithm 1, lines 12-14) is a weighted psum of the
LoRA adapter deltas over the ``data`` axis: each data-slice of the mesh
plays one cluster member, training on its own shard of the batch.  The
cross-site aggregation of the paper's two-site (Caltech/JPL) ACN setting
crosses the ``pod`` axis.  Because ``repro.dist.sharding`` pins the
adapters to replication, the payload each round is exactly the LoRA tree —
FedTime's communication profile (paper Fig. 5): base weights receive no
grads and no traffic.

``expected_collective_bytes`` recomputes the per-device ring all-reduce
bytes implied by this axis mapping.  ``repro.core.comm
.collective_bytes_per_round`` measures the same quantity from the comm-
accounting side; ``tests/test_dist_fed_mapping.py`` keeps the two in
agreement so the §Roofline collective term and the paper's Fig. 5 comm
metric remain one number measured two ways.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.lora import lora_tree, tree_nbytes
from repro.dist.sharding import _mesh_shape

# Who carries what: every slice along ``data`` is one cluster member; the
# ``pod`` axis separates sites.
CLUSTER_AXIS = "data"
CROSS_SITE_AXIS = "pod"


def aggregation_axes(mesh) -> tuple:
    """Mesh axes the federated psum reduces over, innermost first."""
    shape = _mesh_shape(mesh)
    return tuple(ax for ax in (CLUSTER_AXIS, CROSS_SITE_AXIS)
                 if shape.get(ax, 1) > 1)


def ring_allreduce_bytes(payload_bytes: int, n: int) -> int:
    """Per-device bytes moved by an ``n``-way ring all-reduce of a payload:
    2·P·(n-1)/n (reduce-scatter + all-gather phases)."""
    return 0 if n <= 1 else int(2 * payload_bytes * (n - 1) / n)


def adapter_payload_bytes(params) -> int:
    """Bytes of the federated payload — the LoRA tree only."""
    return tree_nbytes(lora_tree(params))


def expected_collective_bytes(params, mesh) -> dict:
    """Per-axis ring all-reduce bytes for one aggregation round under this
    module's axis mapping.  Must agree with
    ``repro.core.comm.collective_bytes_per_round``."""
    shape = _mesh_shape(mesh)
    payload = adapter_payload_bytes(params)
    return {ax: ring_allreduce_bytes(payload, shape.get(ax, 1))
            for ax in (CLUSTER_AXIS, CROSS_SITE_AXIS)}


def fed_psum(tree, mesh):
    """All-reduce a pytree over the federation axes.  Call from inside a
    ``shard_map``/``pmap`` body where the axis names are bound; outside a
    collective context this is an error by construction."""
    axes = aggregation_axes(mesh)
    if not axes:
        return tree
    return jax.tree.map(lambda x: jax.lax.psum(x, axes), tree)


def aggregate_adapters(member_adapters, weights, mesh=None):
    """Algorithm 1, lines 12-14: weighted aggregation of member adapter
    trees, Σ_k w_k · Δ_k with Σ w_k = 1 (w_k = n_k / n cluster sizes).

    Every leaf of ``member_adapters`` carries a leading member dim of size
    ``len(weights)``.  Without a real multi-axis mesh this reduces locally;
    on a mesh whose federation axes are live, the member dim is sharded
    over them and the reduction lowers to an explicit ring all-reduce —
    the mesh-collective form of the paper's cluster aggregation."""
    weights = jnp.asarray(weights, jnp.float32)
    n = weights.shape[0]

    def wsum(w, a):
        return (w.reshape((w.shape[0],) + (1,) * (a.ndim - 1)).astype(a.dtype)
                * a).sum(axis=0)

    axes = aggregation_axes(mesh) if mesh is not None else ()
    if not axes or not isinstance(mesh, Mesh):
        return jax.tree.map(lambda a: wsum(weights, a), member_adapters)

    prod = 1
    for ax in axes:
        prod *= _mesh_shape(mesh)[ax]
    if n % prod:
        raise ValueError(
            f"member dim {n} must divide the federation axes {axes} ({prod})")

    from jax.experimental.shard_map import shard_map
    member_spec = P(axes if len(axes) > 1 else axes[0])

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(member_spec, member_spec),
                       out_specs=P(), check_rep=False)
    def agg(ad, w):
        local = jax.tree.map(lambda a: wsum(w, a), ad)
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), local)

    return agg(member_adapters, weights)
