"""Federated communication fast path (paper Fig. 5 / §C5).

``repro.dist.fed`` maps Algorithm 1's aggregation onto mesh collectives;
this module owns HOW those collectives move: the hand-rolled bidirectional
ring all-reduce of ``repro.kernels.ring_allreduce`` with a quantized wire
format (``REPRO_FED_WIRE=int8|bf16|f32``) and an error-feedback residual
carried between rounds.

Two call sites share the wire machinery:

  * ``ring_aggregate`` — the mesh path.  Every data-slice of the mesh is a
    cluster member; its weighted adapter delta is flattened into ONE
    payload vector and pushed around the ring per federation axis
    (``data``, then ``pod`` cross-site).  The EF residual lives sharded
    over the federation axes (each device carries its own), so repeated
    rounds stay unbiased even on the int8 wire.
  * ``quantize_update`` — the host-loop path.  ``train/fed_trainer`` runs
    the paper's client/server simulation outside any mesh; each client's
    uploaded delta passes through the same quantize/dequant + residual
    step, so Algorithm 1 sees exactly what the wire delivers and
    ``comm.fedtime_round(..., wire=...)`` prices what it meters.

``REPRO_FED_RING=0`` restores the XLA psum lowering in
``fed.aggregate_adapters`` (A/B baseline — ``benchmarks/collectives``
compares the two).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.core.comm import wire_format, wire_qblock
from repro.dist.sharding import _mesh_shape
from repro.kernels.ring_allreduce import (fused_hop, _dequant_chunk,
                                          residual_len, ring_allreduce)


def ring_enabled() -> bool:
    """The ring fast path is the default on a live mesh;
    ``REPRO_FED_RING=0`` falls back to XLA's psum lowering."""
    return os.environ.get("REPRO_FED_RING", "1") != "0"


# one compiled aggregation per (mesh, wire, payload signature): the ring is
# a Python-unrolled hop schedule, so re-tracing it every round would pay
# the full lowering cost 25x in a 25-round federation.  Bounded FIFO so a
# sweep over meshes/configs can't pin executables for the process lifetime.
# Each entry carries (compiled_fn, byte_ledger): the ledger fills at the
# first trace and is bit-identical every subsequent round, so cache hits
# can replay it into the repro.obs tracer without re-compiling.
_AGG_CACHE: dict = {}
_AGG_CACHE_MAX = 32


def _member_elems(member_adapters) -> int:
    """f32 elements of ONE member's adapter payload (leaves carry a
    leading member dim)."""
    return sum(l.size // l.shape[0] for l in jax.tree.leaves(member_adapters))


def init_state(member_adapters, mesh, *, wire: str = None,
               qblock: int = None) -> dict:
    """Zero error-feedback residual state for ``ring_aggregate``:
    ``{axis: (n_devices, residual_len)}`` f32, leading dim sharded over the
    federation axes (every device carries its own residual between
    rounds)."""
    from repro.dist.fed import aggregation_axes
    wire = wire or wire_format()
    shape = _mesh_shape(mesh)
    axes = aggregation_axes(mesh)
    elems = _member_elems(member_adapters)
    prod = 1
    for ax in axes:
        prod *= shape[ax]
    return {ax: jnp.zeros(
        (prod, residual_len(elems, shape[ax], wire, qblock)), jnp.float32)
        for ax in axes}


def ring_aggregate(member_adapters, weights, mesh, *, wire: str = None,
                   qblock: int = None, state: dict = None,
                   byte_ledger: list = None):
    """Algorithm 1, lines 12-14 over the ring fast path: weighted member
    aggregation Σ_k w_k·Δ_k, the member dim sharded over the federation
    axes, the cross-member reduction an explicit bidirectional ring
    all-reduce on the configured wire format.

    ``state`` (from ``init_state``) carries the per-device error-feedback
    residual between rounds.  With ``state=None`` quantization error is
    DISCARDED: fine for a one-shot reduction, but calling this (or
    ``fed.aggregate_adapters``) stateless every round under a quantized
    ``REPRO_FED_WIRE`` re-applies a correlated bias each round — training
    loops must thread the state through.  ``byte_ledger`` (a list)
    receives ``(axis, nbytes)`` per ppermute'd buffer at trace time — the
    measured side of the Fig. 5 three-way byte agreement.

    Returns the aggregated tree, or ``(tree, new_state)`` when ``state``
    is given.

    Partial participation: this kernel reduces whatever rows it is
    handed; drop members BEFORE the call via
    ``repro.dist.fed.mask_members`` (rows zeroed + weights renormalized,
    shapes unchanged) so the compiled executable and its byte ledger are
    reused across cohort changes — see ``fed.aggregate_adapters(alive=)``
    and the ``repro.fault`` round loop.
    """
    from repro.dist.fed import aggregation_axes
    wire = wire or wire_format()
    qblock = qblock or wire_qblock()
    weights = jnp.asarray(weights, jnp.float32)
    n = weights.shape[0]

    def wsum(w, a):
        return (w.reshape((w.shape[0],) + (1,) * (a.ndim - 1)).astype(a.dtype)
                * a).sum(axis=0)

    axes = aggregation_axes(mesh) if mesh is not None else ()
    if not axes or not isinstance(mesh, Mesh):
        out = jax.tree.map(lambda a: wsum(weights, a), member_adapters)
        return out if state is None else (out, state)

    shape = _mesh_shape(mesh)
    prod = 1
    for ax in axes:
        prod *= shape[ax]
    if n % prod:
        raise ValueError(
            f"member dim {n} must divide the federation axes {axes} ({prod})")

    from jax.experimental.shard_map import shard_map
    entry = axes if len(axes) > 1 else axes[0]
    member_spec = P(entry)
    carry_state = state is not None
    st_in = state if carry_state else init_state(member_adapters, mesh,
                                                 wire=wire, qblock=qblock)
    st_spec = {ax: P(entry) for ax in st_in}

    leaves, tdef = jax.tree.flatten(member_adapters)
    shapes = [l.shape[1:] for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    splits = np.cumsum(sizes)[:-1]

    key = (mesh, wire, qblock, tdef, n,
           tuple((tuple(l.shape), str(l.dtype)) for l in leaves))
    agg = ledger = None
    if byte_ledger is None:
        cached = _AGG_CACHE.get(key)
        if cached is not None:
            agg, ledger = cached
    if agg is None:
        # the ledger fills at trace time (first call below) and describes
        # every round identically; cache it with the executable so obs
        # telemetry keeps its per-hop numbers on the hot (cached) path
        ledger = [] if byte_ledger is None else byte_ledger

        @jax.jit
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(member_spec, member_spec, st_spec),
                           out_specs=(P(), st_spec), check_rep=False)
        def agg(ad, w, st):
            local = jax.tree.map(lambda a: wsum(w, a), ad)
            flat = jnp.concatenate(
                [l.reshape(-1).astype(jnp.float32)
                 for l in jax.tree.leaves(local)])
            red, new_res = ring_allreduce(
                flat, axes, shape, wire=wire, qblock=qblock,
                residuals={ax: r[0] for ax, r in st.items()},
                byte_ledger=ledger)
            parts = jnp.split(red, splits)
            out = jax.tree.unflatten(
                tdef, [p.reshape(s) for p, s in zip(parts, shapes)])
            return out, {ax: new_res[ax][None] for ax in st}

        if byte_ledger is None:
            if len(_AGG_CACHE) >= _AGG_CACHE_MAX:
                _AGG_CACHE.pop(next(iter(_AGG_CACHE)))
            _AGG_CACHE[key] = (agg, ledger)

    with obs.span("fedcomm.ring_aggregate", device=True, wire=wire,
                  axes=",".join(axes)):
        out, st_out = agg(member_adapters, weights, st_in)
    _trace_ring_round(ledger, wire)
    return (out, st_out) if carry_state else out


def _trace_ring_round(ledger, wire: str) -> None:
    """Replay one round's measured ppermute ledger into the tracer: a
    ``ring.hop`` instant per chunk transfer and a per-axis
    ``ring.wire_bytes.<axis>`` counter.  The counter's per-round increment
    equals ``repro.dist.fed.expected_collective_bytes`` / ``repro.core.comm
    .collective_bytes_per_round`` for that axis EXACTLY (same plan, fourth
    measurement) — ``tests/test_obs.py`` holds the line."""
    if not ledger or not obs.enabled():
        return
    per_axis: dict = {}
    for i, (ax, nbytes) in enumerate(ledger):
        obs.instant("ring.hop", track=f"ring:{ax}", axis=ax, seq=i,
                    nbytes=nbytes, wire=wire)
        # mergeable sketch, not reservoir: hop-size percentiles stay
        # aggregatable across processes / trace merges
        obs.hist("ring.hop_bytes", float(nbytes), sketch=True)
        per_axis[ax] = per_axis.get(ax, 0) + nbytes
    for ax, nbytes in per_axis.items():
        obs.counter(f"ring.wire_bytes.{ax}", nbytes)
    obs.counter("ring.rounds", 1)


# ---------------------------------------------------------------------------
# Host-loop wire emulation (train/fed_trainer)
# ---------------------------------------------------------------------------

def quantize_update(tree, residual=None, *, wire: str = None,
                    qblock: int = None):
    """One client upload through the wire: quantize the delta tree (EF
    residual added in), return what the server dequantizes plus the new
    residual (flat f32, carried to this client's next round).

    f32 wire is the identity.  Uses the same fused quantize primitives as
    the ring kernel, so the host simulation and the mesh path share one
    wire semantics."""
    wire = wire or wire_format()
    qblock = qblock or wire_qblock()
    if wire == "f32":
        return tree, residual

    leaves, tdef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    splits = np.cumsum([int(np.prod(s)) if s else 1
                        for s in shapes])[:-1]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    pad = -flat.size % qblock
    padded = jnp.pad(flat, (0, pad))
    res = (jnp.zeros_like(padded) if residual is None
           else residual.astype(jnp.float32))
    # encode t = value + residual, keep the wire's loss as the new residual
    t = padded + res
    _, codes, scales, new_res = fused_hop(t, None, None,
                                          jnp.zeros_like(t),
                                          wire=wire, qblock=qblock)
    deq = _dequant_chunk(codes, scales, wire=wire, qblock=qblock)
    parts = jnp.split(deq[:flat.size], splits)
    out = jax.tree.unflatten(
        tdef, [p.reshape(s).astype(l.dtype)
               for p, s, l in zip(parts, shapes, leaves)])
    return out, new_res
