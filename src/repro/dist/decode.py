"""Sequence-sharded flash-decode (DESIGN.md §5 cache layouts).

``REPRO_CACHE_SHARD=seq`` — the default flash-decode layout — puts the ring
cache's slot axis on the ``model`` mesh axis, so no device ever holds the
whole cache.  A decode step then needs a cross-shard softmax: each model
shard runs the flash-decode kernel over its local slots with
``return_partials=True`` (unnormalized online-softmax state), and the
combine

    m* = pmax(m, model)
    out = psum(exp(m - m*) * acc, model) / psum(exp(m - m*) * l, model)

is exactly the kernel's own cross-split (m, l, acc) merge lifted onto mesh
collectives.  Masking needs no adjustment: slots carry absolute positions in
``kv_pos``, which shard with the cache, so ring-validity / causal / window /
prefix masks are position-local facts.

``seq_shard_mesh`` gates the path: it returns the ambient mesh only when a
mesh is active, the ``model`` axis is real, the layout is ``seq``, and the
cache length divides — otherwise ``attn_decode`` stays on the single-shard
kernel and XLA handles whatever layout the arrays actually have.

Ragged continuous-batching steps (``repro.serve.engine``) take this same
path unchanged: ``q_pos`` is per-batch ((B,), sharded over the batch axes
like the queries), so per-slot positions — including the ``-1`` inactive
marker, which fully masks a lane — are shard-local facts exactly like
``kv_pos``; the (m, l, acc) combine is oblivious to which lanes are live.

Paged block pools (``block_tables``) shard the pool's *block* axis over
``model`` instead of a per-request slot axis: each shard owns an
``n_blocks/m`` stripe of physical blocks, the (replicated) table is
localized per shard — entries outside the stripe become -1, i.e. masked —
and the identical (m, l, acc) combine stitches the stripes back together.
A request's blocks land on whichever shards the allocator picked; the
combine is oblivious to that placement exactly as it is to lane liveness.

Copy-on-write prefix sharing composes for free: a shared physical block
appears at the SAME logical index in every sharer's table row, so each
row's entry localizes to the same shard-local index (or -1 off-stripe) —
every sharer attends to the one stored tile, no matter which shard owns
it.  Localization is per-entry and read-only; it never assumes a block
appears in at most one row (tests/test_paged_pool.py drives a duplicated
physical block across rows through the sharded path).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import _batch_axes, _mesh_shape, current_mesh


def seq_shard_mesh(cache_len: int):
    """The ambient mesh when the seq-sharded decode path applies, else
    None."""
    mesh = current_mesh()
    if mesh is None:
        return None
    shape = _mesh_shape(mesh)
    if shape.get("model", 1) <= 1:
        return None
    if os.environ.get("REPRO_CACHE_SHARD", "seq") != "seq":
        return None
    if cache_len % shape["model"]:
        return None
    return mesh


def sharded_flash_decode(q, k, v, kv_pos, q_pos, mesh, *, k_scale=None,
                         v_scale=None, kind: str = "causal", window: int = 0,
                         prefix_len=None, softcap: float = 0.0,
                         block_kv: int = 0, block_tables=None):
    """One decode step against a cache sharded over ``model`` — the slot
    axis of per-request rings, or the block axis of a paged pool
    (``block_tables`` given: k/v are (n_blocks, block_size, Hk, dh), the
    table is replicated and localized inside each shard).  Per-shard kernel
    partials + psum-style combine; same signature/result as
    ``repro.kernels.ops.flash_decode``."""
    from jax.experimental.shard_map import shard_map

    from repro.kernels import ops

    paged = block_tables is not None
    B = q.shape[0]
    shape = _mesh_shape(mesh)
    bax = _batch_axes(B, shape)
    q_spec = P(bax, None, None, None)
    if paged:
        kv_spec = P("model", None, None, None)       # pool block axis
        pos_spec = P("model", None)
    else:
        kv_spec = P(bax, "model", None, None)        # per-request slot axis
        pos_spec = P(bax, "model")
    qp = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32).reshape(-1), (B,))
    plen = jnp.broadcast_to(
        jnp.asarray(0 if prefix_len is None else prefix_len,
                    jnp.int32).reshape(-1), (B,))
    args = [q, k, v, kv_pos, qp, plen]
    specs = [q_spec, kv_spec, kv_spec, pos_spec, P(bax), P(bax)]
    if paged:
        args.append(jnp.asarray(block_tables, jnp.int32))
        specs.append(P(bax, None))                   # replicated over model
    if k_scale is not None:
        args += [k_scale, v_scale]
        specs += [kv_spec, kv_spec]

    @functools.partial(shard_map, mesh=mesh, in_specs=tuple(specs),
                       out_specs=q_spec, check_rep=False)
    def body(q, k, v, kv_pos, qp, plen, *rest):
        rest = list(rest)
        tbl = rest.pop(0) if paged else None
        ks, vs = rest if rest else (None, None)
        if paged:
            # localize the table: this shard owns physical blocks
            # [lo, lo + nb_loc); everything else is another shard's problem
            nb_loc = k.shape[0]
            lo = jax.lax.axis_index("model") * nb_loc
            tbl = jnp.where((tbl >= lo) & (tbl < lo + nb_loc), tbl - lo, -1)
        m, l, acc = ops.flash_decode(
            q, k, v, kv_pos, qp, k_scale=ks, v_scale=vs, kind=kind,
            window=window, prefix_len=plen, softcap=softcap,
            block_kv=block_kv, block_tables=tbl, return_partials=True)
        m_g = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, "model")
        acc_g = jax.lax.psum(acc * w, "model")
        out = acc_g / jnp.maximum(l_g, 1e-30)        # (B_loc, Hk, G, D)
        return out.reshape(out.shape[0], 1, -1,
                           out.shape[-1]).astype(q.dtype)

    return body(*args)
