"""Distribution layer: mesh-aware partition rules + federated collectives.

``repro.dist.sharding`` — partition-spec tables for params (Megatron-style
tensor parallelism over ``model``), optimizer state (ZeRO-1 widening over
``data``/``pod``), KV/SSM caches (flash-decode seq-sharding or
head-sharding), and input batches (data parallelism with replication
fallback).

``repro.dist.fed`` — FedTime's Algorithm 1 aggregation mapped onto mesh
collectives: cluster aggregation reduces over ``data``, cross-site
aggregation crosses ``pod``.

``repro.dist.fedcomm`` — the communication fast path those axes run on:
the hand-rolled bidirectional ring all-reduce
(``repro.kernels.ring_allreduce``) with the ``REPRO_FED_WIRE`` quantized
wire format and carried error-feedback residuals, plus the host-loop wire
emulation used by ``train/fed_trainer``.

``repro.dist.decode`` — the decode step for seq-sharded caches: per-shard
flash-decode (m, l, acc) partials combined with a pmax/psum over ``model``.
"""
