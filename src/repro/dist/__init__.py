"""Distribution layer: mesh-aware partition rules + federated collectives.

``repro.dist.sharding`` — partition-spec tables for params (Megatron-style
tensor parallelism over ``model``), optimizer state (ZeRO-1 widening over
``data``/``pod``), KV/SSM caches (flash-decode seq-sharding or
head-sharding), and input batches (data parallelism with replication
fallback).

``repro.dist.fed`` — FedTime's Algorithm 1 aggregation mapped onto mesh
collectives: cluster aggregation is a psum over ``data``, cross-site
aggregation crosses ``pod``.

``repro.dist.decode`` — the decode step for seq-sharded caches: per-shard
flash-decode (m, l, acc) partials combined with a pmax/psum over ``model``.
"""
