"""Mesh-aware partition rules for every pytree the launch stack moves
around: parameters, optimizer state, KV/SSM caches, and input batches
(DESIGN.md §5).

All rules are pure functions of (path, shape, mesh shape), so they work on
``jax.ShapeDtypeStruct`` trees (the dry-run's abstract params) exactly as on
real arrays, and they never touch jax device state.  Every rule enforces
divisibility: a dim that does not divide its mesh axis falls back to
replication rather than erroring, which is what lets one table cover every
architecture family in the repo (dense, MoE, VLM, encoder-decoder, xLSTM,
Zamba2).

Layout summary
  params      — Megatron tensor parallelism over ``model``: column-parallel
                sites shard the output dim, row-parallel sites the input
                dim, embeddings the vocab dim.  LoRA adapters are pinned to
                replication: the federated payload must be a pure psum
                (see repro.dist.fed).
  opt state   — ZeRO-1: the base param spec widened over ``data`` (+``pod``)
                on the first still-replicated dim that divides, so the f32
                AdamW moments never cost more per device than the bf16
                params.
  caches      — ``REPRO_CACHE_SHARD=seq`` (default): batch -> data axes,
                sequence -> ``model`` (flash-decode layout).
                ``REPRO_CACHE_SHARD=heads``: batch -> data axes, KV heads ->
                ``model``, falling through to the head dim when the head
                count does not divide (GQA archs with few KV heads).
  batches     — leading batch dim over the combined (``pod``, ``data``)
                axes, falling back to ``data`` alone, then to replication
                (the long_500k batch=1 shape cannot shard).
"""

from __future__ import annotations

import os
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _div(n: int, k: int) -> bool:
    """True when an ``n``-sized dim splits evenly ``k`` ways."""
    return k > 0 and n % k == 0


def _mesh_shape(mesh) -> dict:
    """Accept a ``jax.sharding.Mesh`` (``.shape`` is used) or a plain
    ``{axis: size}`` dict — the rule tables only ever need axis sizes."""
    return dict(getattr(mesh, "shape", mesh))


def _axis_candidates(shape: dict):
    """Data-parallel axis combinations to try, widest first: the combined
    (``pod``, ``data``) axes, then ``data`` alone.  Shared by batch
    sharding and ZeRO-1 widening so the two fallback chains never
    diverge."""
    axes = [ax for ax in ("pod", "data") if shape.get(ax, 1) > 1]
    candidates = [axes] if axes else []
    if len(axes) > 1:
        candidates.append(["data"])
    return candidates


def _axis_entry(cand, shape: dict):
    """(spec entry, total ways) for one candidate axis combination."""
    prod = 1
    for ax in cand:
        prod *= shape[ax]
    return (tuple(cand) if len(cand) > 1 else cand[0]), prod


def _batch_axes(n: int, shape: dict):
    """Axis (or axis tuple) an ``n``-sized batch dim shards over: the
    combined (``pod``, ``data``) axes when their product divides, else
    ``data`` alone, else None (replicate)."""
    for cand in _axis_candidates(shape):
        entry, prod = _axis_entry(cand, shape)
        if _div(n, prod):
            return entry
    return None


def _maybe_spec(entries) -> P:
    """Full-length spec, or the canonical empty P() when fully replicated."""
    return P(*entries) if any(e is not None for e in entries) else P()


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

# Column-parallel sites (shard the output dim): the first matmul of each
# pair in the Megatron decomposition.  Covers attention q/k/v across all
# families, MLP/MoE up+gate projections, fused recurrent in-projections
# (Mamba2 in_proj, mLSTM up, sLSTM w_in), and the vocab-producing lm_head.
_COL_SITES = frozenset((
    "wq", "wk", "wv",
    "gate", "up", "gate_proj", "up_proj",
    "in_proj", "w_in", "ffn_gate", "ffn_up",
    "lm_head", "vis_proj", "frame_proj",
))

# Row-parallel sites (shard the input dim): the second matmul of each pair,
# whose output is the partial-sum that XLA all-reduces back into the
# replicated-hidden residual stream.
_ROW_SITES = frozenset((
    "wo", "down", "down_proj", "out_proj", "ffn_down",
))

# The federated payload: cluster aggregation is a pure psum (DESIGN.md §5 /
# repro.dist.fed), which requires the adapters replicated on every device.
_LORA_LEAVES = frozenset(("lora_a", "lora_b", "lora_scale"))


def _spec_for_param(path: str, leaf, model: int) -> P:
    """Partition spec for one parameter leaf.

    ``path`` is "/"-joined dict keys ("/layers/attn/wq/w"); ``leaf`` needs
    only ``.shape`` (ShapeDtypeStruct or array); ``model`` is the size of
    the ``model`` mesh axis.  Everything unmatched (norm scales, biases,
    routers, conv filters, NF4 codes, recurrent gate weights) replicates.
    """
    parts = [p for p in str(path).split("/") if p]
    tail = parts[-1]
    parent = parts[-2] if len(parts) > 1 else ""
    shape = leaf.shape
    nd = len(shape)

    if tail in _LORA_LEAVES:
        return P()
    if model <= 1 or nd < 2:
        return P()

    # linear sites carry their weight as a "w" leaf; stacked MoE expert
    # weights (gate_proj/up_proj/down_proj) are direct array leaves
    site = parent if tail in ("w",) else tail
    if site in _COL_SITES and _div(shape[-1], model):
        return P(*([None] * (nd - 1)), "model")
    if site in _ROW_SITES and _div(shape[-2], model):
        return P(*([None] * (nd - 2)), "model", None)
    if tail == "table" and nd == 2 and _div(shape[0], model):
        return P("model", None)                      # vocab-sharded embedding
    return P()


def _map_with_path(tree, fn, path: str = ""):
    if isinstance(tree, dict):
        return {k: _map_with_path(v, fn, f"{path}/{k}")
                for k, v in tree.items()}
    return fn(path, tree)


def param_specs(params, mesh):
    """Partition specs for a parameter tree: tensor parallelism over
    ``model``, everything else (incl. the LoRA payload) replicated."""
    model = _mesh_shape(mesh).get("model", 1)
    return _map_with_path(
        params, lambda path, leaf: _spec_for_param(path, leaf, model))


# ---------------------------------------------------------------------------
# Optimizer state (ZeRO-1)
# ---------------------------------------------------------------------------

def opt_state_specs(params, mesh):
    """ZeRO-1 specs for AdamW moments (and grad-accumulation carries): the
    base param spec, additionally widened over the ``data`` (+``pod``) axes
    on the first still-replicated dim that divides.  The moments are pure
    storage between steps, so scattering them over the data-parallel axes
    is free parallelism — XLA all-gathers exactly the slice each update
    needs."""
    shape = _mesh_shape(mesh)
    model = shape.get("model", 1)
    candidates = _axis_candidates(shape)

    def widen(path, leaf):
        base = _spec_for_param(path, leaf, model)
        entries = list(base) + [None] * (len(leaf.shape) - len(base))
        for cand in candidates:
            entry, prod = _axis_entry(cand, shape)
            for d, e in enumerate(entries):
                if e is None and _div(leaf.shape[d], prod):
                    entries[d] = entry
                    return _maybe_spec(entries)
        return _maybe_spec(entries)

    return _map_with_path(params, widen)


# ---------------------------------------------------------------------------
# KV / SSM caches
# ---------------------------------------------------------------------------

# Cache leaf layouts as offsets from the END of the shape — leading dims are
# layer stacks of family-dependent depth (vmap-initialized), so negative
# indexing is what stays stable across families.
_CACHE_DIMS = {
    # attention ring buffers: (..., B, S, Hk, dh)
    "k":       {"batch": -4, "seq": -3, "heads": -2, "dh": -1},
    "v":       {"batch": -4, "seq": -3, "heads": -2, "dh": -1},
    "mem_k":   {"batch": -4, "seq": -3, "heads": -2, "dh": -1},
    "mem_v":   {"batch": -4, "seq": -3, "heads": -2, "dh": -1},
    # int8-KV absmax scales: (..., B, S, Hk, 1) — trailing dim never shards
    "k_scale": {"batch": -4, "seq": -3, "heads": -2},
    "v_scale": {"batch": -4, "seq": -3, "heads": -2},
    # slot-position maps: (..., B, S)
    "kv_pos":  {"batch": -2, "seq": -1},
    "mem_pos": {"batch": -2, "seq": -1},
    # Mamba2: state (..., B, H, P, N), conv tail (..., B, W-1, channels)
    "ssm_state": {"batch": -4, "heads": -3, "dh": -2},
    "conv_buf":  {"batch": -3, "dh": -1},
    # mLSTM: C (..., B, H, dh, dh), n (..., B, H, dh), m (..., B, H)
    "C": {"batch": -4, "heads": -3, "dh": -1},
    "n": {"batch": -3, "heads": -2, "dh": -1},
    "m": {"batch": -2, "heads": -1},
}

# sLSTM scalar-memory state is (..., B, d) — its "n"/"m" leaves collide with
# mLSTM's names, so the table is selected by the enclosing subtree.
_SLSTM_CACHE_DIMS = {
    name: {"batch": -2, "dh": -1} for name in ("c", "n", "m", "h")
}


def cache_specs(cache, mesh, mode: Optional[str] = None):
    """Partition specs for a KV/SSM cache tree.

    ``mode`` (default from ``REPRO_CACHE_SHARD``, then "seq"):
      seq   — flash-decode layout: batch -> data axes, sequence -> ``model``
              (decode attention reduces over the seq-sharded cache).
      heads — batch -> data axes, KV heads -> ``model``, falling through to
              the head dim when the head count does not divide.
    Leaves without the preferred dim (recurrent states have no sequence)
    fall through the same chain; anything that cannot shard replicates.
    """
    shape = _mesh_shape(mesh)
    model = shape.get("model", 1)
    mode = mode or os.environ.get("REPRO_CACHE_SHARD", "seq")
    order = ("seq", "heads", "dh") if mode == "seq" else ("heads", "dh")

    def spec(path, leaf):
        parts = [p for p in path.split("/") if p]
        table = _SLSTM_CACHE_DIMS if "slstm" in parts else _CACHE_DIMS
        dims = table.get(parts[-1])
        nd = len(leaf.shape)
        if dims is None or nd == 0:
            return P()

        def dim_at(key):
            off = dims.get(key)
            return None if off is None or nd + off < 0 else nd + off

        entries = [None] * nd
        b = dim_at("batch")
        if b is not None:
            entries[b] = _batch_axes(leaf.shape[b], shape)
        if model > 1:
            for key in order:
                d = dim_at(key)
                if d is not None and entries[d] is None and \
                        _div(leaf.shape[d], model):
                    entries[d] = "model"
                    break
        return _maybe_spec(entries)

    return _map_with_path(cache, spec)


# ---------------------------------------------------------------------------
# Input batches
# ---------------------------------------------------------------------------

def data_specs(batch, mesh):
    """Shard the leading batch dim of every input leaf over the combined
    (``pod``, ``data``) axes, falling back to ``data`` alone, then to
    replication (scalars like ``pos``, and batch=1 long-context decodes)."""
    shape = _mesh_shape(mesh)

    def spec(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        ax = _batch_axes(leaf.shape[0], shape)
        if ax is None:
            return P()
        return P(ax, *([None] * (nd - 1)))

    return _map_with_path(batch, spec)


# ---------------------------------------------------------------------------
# Mesh plumbing
# ---------------------------------------------------------------------------

def to_shardings(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def current_mesh():
    """The ambient ``with mesh:`` context's physical mesh, or None."""
    try:
        from jax._src.mesh import thread_resources
    except ImportError:                       # pragma: no cover - older jax
        from jax.interpreters.pxla import thread_resources
    mesh = thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def residual_constraint(x, *, decode: bool = False):
    """Pin the residual stream to the Megatron activation layout
    (batch -> data axes, seq -> ``model``) when a mesh is active.

    No-op outside a mesh context, and per-dim when sizes don't divide —
    decode steps (seq == 1) keep only the batch sharding.  Models call this
    between blocks so remat checkpoints stay small (DESIGN.md §5)."""
    mesh = current_mesh()
    if mesh is None or x.ndim < 3:
        return x
    shape = _mesh_shape(mesh)
    batch_ax = _batch_axes(x.shape[0], shape)
    model = shape.get("model", 1)
    seq_ax = "model" if (not decode and model > 1 and
                         _div(x.shape[1], model)) else None
    if batch_ax is None and seq_ax is None:
        return x
    spec = P(batch_ax, seq_ax, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
