"""Adam / AdamW from scratch (no optax).

Functional API:
  state = adamw_init(params)
  params, state = adamw_update(params, grads, state, step, lr=..., ...)

Supports masked updates (``mask`` pytree of bools) so the federated client
can train LoRA leaves only while the quantized base stays frozen — the
paper's PEFT setup (C2).

ZeRO-1 scatter update (``adamw_update_zero1``): on a mesh whose data
axes are live, ``repro.dist.sharding.opt_state_specs`` shards the f32
moments over ``data`` (+``pod``).  The gather formulation (plain
``adamw_update`` under jit) leaves the layout to XLA, which reshards the
replicated grads onto the moment layout with a swarm of
all-to-all/collective-permutes before the final param all-gather.  The
scatter formulation makes the intended schedule explicit in ONE shard_map:
slice params+grads to the local moment shard (free — both are replicated
over the data axes there), update the shard, and all-gather ONLY the
updated param shard.  Same arithmetic on the same f32 values — bit-exact
against the gather form — with a strictly smaller collective term
(``benchmarks/collectives`` measures both via the dry-run HLO cost model).
``REPRO_ZERO1_SCATTER=0`` restores the gather formulation.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params)}


def zero1_scatter_enabled() -> bool:
    """Scatter-update is the default on a mesh; ``REPRO_ZERO1_SCATTER=0``
    restores the gather formulation (A/B baseline)."""
    return os.environ.get("REPRO_ZERO1_SCATTER", "1") != "0"


def _leaf_update(p, g, mu, nu, c1, c2, *, lr, b1, b2, eps, weight_decay):
    """One AdamW leaf update — shared by the gather and scatter paths so
    the two formulations stay bit-identical."""
    g32 = g.astype(jnp.float32)
    mu2 = b1 * mu + (1 - b1) * g32
    nu2 = b2 * nu + (1 - b2) * jnp.square(g32)
    mhat = mu2 / c1
    nhat = nu2 / c2
    delta = mhat / (jnp.sqrt(nhat) + eps)
    if weight_decay > 0:
        delta = delta + weight_decay * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2


def adamw_update(params, grads, state, step, *, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0, mask=None):
    """step: 1-based int or traced scalar."""
    step = jnp.asarray(step, jnp.float32)
    c1 = 1.0 - b1 ** step
    c2 = 1.0 - b2 ** step

    if mask is None:
        mask = jax.tree.map(lambda _: True, params)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_m = jax.tree.leaves(mask)
    out_p, out_mu, out_nu = [], [], []
    for p, g, mu, nu, m in zip(flat_p, flat_g, flat_mu, flat_nu, flat_m):
        if m is False:
            p2, mu2, nu2 = p, mu, nu
        else:
            p2, mu2, nu2 = _leaf_update(p, g, mu, nu, c1, c2, lr=lr, b1=b1,
                                        b2=b2, eps=eps,
                                        weight_decay=weight_decay)
        out_p.append(p2)
        out_mu.append(mu2)
        out_nu.append(nu2)
    return (jax.tree.unflatten(tdef, out_p),
            {"mu": jax.tree.unflatten(tdef, out_mu),
             "nu": jax.tree.unflatten(tdef, out_nu)})


def _widen_info(pspec, ospec):
    """Per-leaf (dim, axis entry) where ``opt_state_specs`` widened the
    param spec over the data axes, or None (moments replicated — nothing
    to scatter)."""
    from jax.sharding import PartitionSpec as P

    def info(ps, os_):
        pe = list(ps)
        for d, e in enumerate(list(os_)):
            if e is not None and (d >= len(pe) or pe[d] is None):
                return (d, e)
        return None

    return jax.tree.map(info, pspec, ospec,
                        is_leaf=lambda x: isinstance(x, P))


def adamw_update_zero1(params, grads, state, step, *, mesh, lr=1e-3, b1=0.9,
                       b2=0.999, eps=1e-8, weight_decay=0.0, mask=None):
    """AdamW with the ZeRO-1 scatter-update schedule (see module
    docstring).  Falls back to ``adamw_update`` when the mesh has no live
    data axes, when disabled, or for fully-replicated moment leaves inside
    the shard_map body.  Bit-exact against the gather formulation."""
    from repro.dist.sharding import (_axis_candidates, _mesh_shape,
                                     opt_state_specs, param_specs)
    if mesh is None or not zero1_scatter_enabled():
        return adamw_update(params, grads, state, step, lr=lr, b1=b1, b2=b2,
                            eps=eps, weight_decay=weight_decay, mask=mask)
    shape = _mesh_shape(mesh)
    if not _axis_candidates(shape):
        return adamw_update(params, grads, state, step, lr=lr, b1=b1, b2=b2,
                            eps=eps, weight_decay=weight_decay, mask=mask)

    from jax.experimental.shard_map import shard_map

    pspec = param_specs(params, mesh)
    ospec = opt_state_specs(params, mesh)
    winfo = jax.tree.leaves(_widen_info(pspec, ospec),
                            is_leaf=lambda x: x is None or
                            isinstance(x, tuple))
    if mask is None:
        mask = jax.tree.map(lambda _: True, params)
    flat_mask = jax.tree.leaves(mask)
    step_c = jnp.asarray(step, jnp.float32)

    def body(p, g, mu, nu, c1, c2):
        fp, tdef = jax.tree.flatten(p)
        fg = jax.tree.leaves(g)
        fmu = jax.tree.leaves(mu)
        fnu = jax.tree.leaves(nu)
        out_p, out_mu, out_nu = [], [], []
        for pl, gl, mul, nul, wi, m in zip(fp, fg, fmu, fnu, winfo,
                                           flat_mask):
            if m is False:
                out_p.append(pl)
                out_mu.append(mul)
                out_nu.append(nul)
                continue
            if wi is None:
                p2, mu2, nu2 = _leaf_update(pl, gl, mul, nul, c1, c2, lr=lr,
                                            b1=b1, b2=b2, eps=eps,
                                            weight_decay=weight_decay)
            else:
                d, entry = wi
                axes = (entry,) if isinstance(entry, str) else tuple(entry)
                idx = jnp.int32(0)
                for ax in axes:
                    idx = idx * shape[ax] + jax.lax.axis_index(ax)
                nshard = mul.shape[d]
                # grads enter on the MOMENT spec (already shard-shaped:
                # replicated grads reshard by a free local slice, and an
                # accum carry pinned to the ZeRO layout passes through
                # untouched); only the replicated param needs slicing here
                ps = jax.lax.dynamic_slice_in_dim(pl, idx * nshard, nshard, d)
                p2, mu2, nu2 = _leaf_update(ps, gl, mul, nul, c1, c2, lr=lr,
                                            b1=b1, b2=b2, eps=eps,
                                            weight_decay=weight_decay)
                # the ONLY collective of the update: gather the updated
                # param shard (param dtype) — moments stay put
                p2 = jax.lax.all_gather(p2, axes, axis=d, tiled=True)
            out_p.append(p2)
            out_mu.append(mu2)
            out_nu.append(nu2)
        return (jax.tree.unflatten(tdef, out_p),
                jax.tree.unflatten(tdef, out_mu),
                jax.tree.unflatten(tdef, out_nu))

    from jax.sharding import PartitionSpec as P
    p2, mu2, nu2 = shard_map(
        body, mesh=mesh,
        in_specs=(pspec, ospec, ospec, ospec, P(), P()),
        out_specs=(pspec, ospec, ospec),
        check_rep=False)(params, grads, state["mu"], state["nu"],
                         1.0 - b1 ** step_c, 1.0 - b2 ** step_c)
    return p2, {"mu": mu2, "nu": nu2}


def sgd_update(params, grads, *, lr=1e-2):
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) -
                      lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
