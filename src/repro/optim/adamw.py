"""Adam / AdamW from scratch (no optax).

Functional API:
  state = adamw_init(params)
  params, state = adamw_update(params, grads, state, step, lr=..., ...)

Supports masked updates (``mask`` pytree of bools) so the federated client
can train LoRA leaves only while the quantized base stays frozen — the
paper's PEFT setup (C2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params)}


def adamw_update(params, grads, state, step, *, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0, mask=None):
    """step: 1-based int or traced scalar."""
    step = jnp.asarray(step, jnp.float32)
    c1 = 1.0 - b1 ** step
    c2 = 1.0 - b2 ** step

    def upd(p, g, mu, nu, m):
        if m is False:
            return p, mu, nu
        g32 = g.astype(jnp.float32)
        mu2 = b1 * mu + (1 - b1) * g32
        nu2 = b2 * nu + (1 - b2) * jnp.square(g32)
        mhat = mu2 / c1
        nhat = nu2 / c2
        delta = mhat / (jnp.sqrt(nhat) + eps)
        if weight_decay > 0:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2

    if mask is None:
        mask = jax.tree.map(lambda _: True, params)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_m = jax.tree.leaves(mask)
    out_p, out_mu, out_nu = [], [], []
    for p, g, mu, nu, m in zip(flat_p, flat_g, flat_mu, flat_nu, flat_m):
        p2, mu2, nu2 = upd(p, g, mu, nu, m)
        out_p.append(p2)
        out_mu.append(mu2)
        out_nu.append(nu2)
    return (jax.tree.unflatten(tdef, out_p),
            {"mu": jax.tree.unflatten(tdef, out_mu),
             "nu": jax.tree.unflatten(tdef, out_nu)})


def sgd_update(params, grads, *, lr=1e-2):
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) -
                      lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
