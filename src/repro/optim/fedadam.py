"""FedAdam — adaptive *server-side* federated optimization (Reddi et al.,
ICLR 2021), as used by the paper to update QLoRA parameters.

The server treats the (weighted) average client delta as a pseudo-gradient
and applies Adam to the global model:

    Δ_t   = Σ_s w_s (θ_s - θ_global) / Σ_s w_s
    m_t   = β1 m_{t-1} + (1-β1) Δ_t
    v_t   = β2 v_{t-1} + (1-β2) Δ_t²
    θ_t+1 = θ_t + η m_t / (√v_t + τ)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fedadam_init(global_tree):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"m": jax.tree.map(zeros, global_tree),
            "v": jax.tree.map(zeros, global_tree)}


def fedadam_update(global_tree, avg_delta, state, *, lr=1e-2, b1=0.9,
                   b2=0.99, tau=1e-3):
    m = jax.tree.map(lambda m_, d: b1 * m_ + (1 - b1) * d.astype(jnp.float32),
                     state["m"], avg_delta)
    v = jax.tree.map(lambda v_, d: b2 * v_ + (1 - b2) *
                     jnp.square(d.astype(jnp.float32)),
                     state["v"], avg_delta)
    new = jax.tree.map(
        lambda p, m_, v_: (p.astype(jnp.float32) +
                           lr * m_ / (jnp.sqrt(v_) + tau)).astype(p.dtype),
        global_tree, m, v)
    return new, {"m": m, "v": v}


def fedavg(client_trees, weights):
    """Plain weighted averaging (McMahan et al.). weights: (S,) array."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / w.sum()

    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        out = jnp.tensordot(w, stacked, axes=1)
        return out.astype(leaves[0].dtype)

    return jax.tree.map(avg, *client_trees)
