"""LoRA / QLoRA plumbing over arbitrary parameter pytrees (paper C2).

A "linear site" is any sub-dict carrying a weight leaf ``w`` whose path tail
matches the family's target set.  ``attach_lora`` adds (lora_a, lora_b,
lora_scale) in place; ``quantize_base`` replaces ``w`` by NF4 codes;
``lora_tree``/``merge_lora`` extract and re-insert only the adapter leaves —
the federated payload (what crosses the network each round, paper C3/C5).

Handles stacked (vmap-initialized) layers transparently: a weight of shape
(L, in, out) gets adapters (L, in, r) / (L, r, out).
"""

from __future__ import annotations

from typing import Iterable, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant import nf4_quantize

# per-family LoRA placement (DESIGN.md §4)
DEFAULT_TARGETS = ("wq", "wk", "wv", "wo")
FAMILY_TARGETS = {
    "dense": DEFAULT_TARGETS,
    "moe": DEFAULT_TARGETS + ("router",),
    "vlm": DEFAULT_TARGETS + ("vis_proj",),
    "encdec": DEFAULT_TARGETS,
    "ssm": DEFAULT_TARGETS + ("up", "down"),          # xLSTM block projections
    "hybrid": DEFAULT_TARGETS + ("in_proj", "out_proj"),
}

# sites that stay un-quantized even under QLoRA (small / numerically touchy)
NO_QUANT = ("router", "embed", "lm_head", "vis_proj", "frame_proj")


def _walk(tree, fn, path=()):
    """Depth-first walk; fn(path, subdict) may mutate dict nodes in place."""
    if isinstance(tree, dict):
        fn(path, tree)
        for k, v in list(tree.items()):
            _walk(v, fn, path + (k,))


def _is_linear_site(node) -> bool:
    return isinstance(node, dict) and ("w" in node or "w_nf4" in node) and \
        not isinstance(node.get("w", node.get("w_nf4")), dict)


def _matches(path: Tuple[str, ...], targets: Iterable[str]) -> bool:
    return len(path) > 0 and path[-1] in targets


def attach_lora(params, key, *, rank: int, alpha: float,
                targets: Iterable[str] = DEFAULT_TARGETS):
    """Returns a copy of ``params`` with adapters attached to target sites."""
    params = jax.tree.map(lambda x: x, params)  # shallow-ish copy of leaves
    counter = [0]
    keys = {}

    def collect(path, node):
        if _is_linear_site(node) and _matches(path, targets):
            keys[path] = counter[0]
            counter[0] += 1

    _walk(params, collect)
    subkeys = jax.random.split(key, max(counter[0], 1))

    def attach(path, node):
        if not (_is_linear_site(node) and _matches(path, targets)):
            return
        w = node.get("w")
        if w is None:
            return
        *lead, din, dout = w.shape
        k = subkeys[keys[path]]
        # LoRA init: A ~ N(0, 1/r), B = 0 (adapter starts as identity delta)
        node["lora_a"] = (jax.random.normal(k, (*lead, din, rank)) *
                          (rank ** -0.5)).astype(jnp.float32)
        node["lora_b"] = jnp.zeros((*lead, rank, dout), jnp.float32)
        # shaped (*lead,) so stacked-layer trees stay scannable
        node["lora_scale"] = jnp.full(tuple(lead), alpha / rank, jnp.float32)

    _walk(params, attach)
    return params


def quantize_base(params, *, qblock: int = 64,
                  targets: Iterable[str] = DEFAULT_TARGETS):
    """NF4-quantize the frozen base weights at LoRA sites (QLoRA)."""
    params = jax.tree.map(lambda x: x, params)

    def quant(path, node):
        if not (_is_linear_site(node) and _matches(path, targets)):
            return
        if any(nq in path for nq in NO_QUANT):
            return
        w = node.pop("w", None)
        if w is None:
            return
        n = 1
        for s in w.shape[-2:]:
            n *= s
        qb = qblock if n % qblock == 0 else _best_block(n, qblock)
        node["w_nf4"], node["absmax"] = nf4_quantize(w, qb)

    _walk(params, quant)
    return params


def _best_block(n: int, target: int) -> int:
    for qb in range(target, 1, -1):
        if n % qb == 0:
            return qb
    return 1


# ---------------------------------------------------------------------------
# Adapter extraction / merging — the federated payload
# ---------------------------------------------------------------------------

def lora_tree(params):
    """Subtree containing ONLY adapter leaves (lora_a / lora_b)."""
    if isinstance(params, dict):
        out = {}
        for k, v in params.items():
            if k in ("lora_a", "lora_b"):
                out[k] = v
            elif isinstance(v, dict):
                sub = lora_tree(v)
                if sub:
                    out[k] = sub
        return out
    return {}


def merge_lora(params, adapters):
    """Re-insert adapter leaves into a full parameter tree (returns copy)."""
    if not isinstance(params, dict):
        return params
    out = {}
    for k, v in params.items():
        if k in ("lora_a", "lora_b") and isinstance(adapters, dict) \
                and k in adapters:
            out[k] = adapters[k]
        elif isinstance(v, dict):
            out[k] = merge_lora(v, adapters.get(k, {})
                                if isinstance(adapters, dict) else {})
        else:
            out[k] = v
    return out


def lora_mask(params):
    """Boolean pytree: True exactly on adapter leaves (for masked optim)."""
    def mk(path, node):
        pass
    def rec(tree, key=None):
        if isinstance(tree, dict):
            return {k: rec(v, k) for k, v in tree.items()}
        return key in ("lora_a", "lora_b")
    return rec(params)


def materialize_lora(params):
    """Fold adapters into base weights: W' = W + s·A·B (paper's deploy
    path after federation finishes). Quantized sites stay quantized with
    adapters kept (they cannot be folded into NF4 codes losslessly)."""
    if not isinstance(params, dict):
        return params
    if _is_linear_site(params) and "lora_a" in params and "w" in params:
        w = params["w"]
        delta = (params["lora_a"] @ params["lora_b"] *
                 params["lora_scale"]).astype(w.dtype)
        return {"w": w + delta}
    return {k: materialize_lora(v) if isinstance(v, dict) else v
            for k, v in params.items()}


def tree_nbytes(tree) -> int:
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(tree))


def count_params(tree) -> int:
    return sum(leaf.size for leaf in jax.tree.leaves(tree))


def trainable_fraction(params) -> float:
    """Paper's 'only 1.2% of parameters are trainable' metric."""
    total = count_params(params)
    lora = count_params(lora_tree(params))
    return lora / max(total, 1)
