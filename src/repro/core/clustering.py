"""K-means clustering of edge devices (paper §3.1: pre-learning step).

Clients are embedded by their local-data statistics (mean/std/trend of the
load curve, dataset size, and a device-capability proxy) and clustered so
each cluster trains its own global model — the paper's mechanism for
reducing biased predictions and localizing aggregation (C3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def client_features(series_list, capabilities=None) -> jnp.ndarray:
    """series_list: list of (L_s, M) arrays (heterogeneous lengths allowed).
    Returns (S, F) feature matrix, standardized per feature."""
    feats = []
    for i, s in enumerate(series_list):
        s = jnp.asarray(s, jnp.float32).reshape(s.shape[0], -1)
        L = s.shape[0]
        t = jnp.arange(L, dtype=jnp.float32)
        tc = t - t.mean()
        trend = (tc[:, None] * (s - s.mean(0))).sum(0) / \
            jnp.maximum((tc ** 2).sum(), 1e-9)
        cap = 1.0 if capabilities is None else float(capabilities[i])
        feats.append(jnp.concatenate([
            s.mean(0).mean()[None], s.std(0).mean()[None],
            trend.mean()[None], jnp.asarray([jnp.log1p(L)]),
            jnp.asarray([cap])]))
    X = jnp.stack(feats)
    mu, sd = X.mean(0), X.std(0) + 1e-9
    return (X - mu) / sd


def kmeans(X: jnp.ndarray, k: int, *, iters: int = 50, key=None):
    """Lloyd's algorithm in pure JAX. Returns (assignments (S,), centers
    (k, F), inertia)."""
    S, F = X.shape
    k = min(k, S)
    if key is None:
        key = jax.random.PRNGKey(0)
    # k-means++ style: greedy farthest-point init (deterministic given key)
    first = jax.random.randint(key, (), 0, S)
    centers0 = jnp.zeros((k, F)).at[0].set(X[first])

    def init_step(i, centers):
        d = jnp.min(jnp.sum((X[:, None, :] - centers[None]) ** 2, -1)
                    + jnp.where(jnp.arange(k)[None] >= i, jnp.inf, 0.0),
                    axis=1)
        nxt = jnp.argmax(d)
        return centers.at[i].set(X[nxt])

    centers = jax.lax.fori_loop(1, k, init_step, centers0)

    def lloyd(_, carry):
        centers, _ = carry
        d = jnp.sum((X[:, None, :] - centers[None]) ** 2, -1)   # (S,k)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)   # (S,k)
        counts = onehot.sum(0)                                   # (k,)
        sums = onehot.T @ X                                      # (k,F)
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts[:, None], 1), centers)
        return new, assign

    centers, assign = jax.lax.fori_loop(
        0, iters, lloyd, (centers, jnp.zeros((S,), jnp.int32)))
    d = jnp.sum((X - centers[assign]) ** 2, -1)
    return assign, centers, d.sum()


def cluster_clients(series_list, k: int, *, capabilities=None, key=None):
    X = client_features(series_list, capabilities)
    assign, centers, inertia = kmeans(X, k, key=key)
    return assign, centers, inertia
