"""NF4 (4-bit NormalFloat) blockwise quantization — QLoRA's weight format
(Dettmers et al. 2023), pure-jnp reference implementation.

TPU adaptation (DESIGN.md §3): codes are packed two-per-byte into uint8 and
stored with shape (..., in_dim, out_dim // 2); per-block absmax scales are
float32 with block size ``qblock`` over the row-major flattened weight.
``repro.kernels.qlora_matmul`` is the fused VMEM-tiled Pallas version of
``dequant + matmul (+ LoRA)``; this module is its oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# bitsandbytes NF4 code book (quantiles of N(0,1), normalized to [-1, 1])
NF4_CODE = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0], dtype=np.float32)


def nf4_quantize(w: jnp.ndarray, qblock: int = 64):
    """w: (..., in, out) float -> (w_nf4 uint8 (..., in, out//2),
    absmax f32 (..., n_blocks))."""
    *lead, din, dout = w.shape
    assert dout % 2 == 0, dout
    n = din * dout
    assert n % qblock == 0, (n, qblock)
    nb = n // qblock
    flat = w.astype(jnp.float32).reshape(*lead, nb, qblock)
    absmax = jnp.max(jnp.abs(flat), axis=-1)
    scaled = flat / jnp.maximum(absmax[..., None], 1e-12)
    code = jnp.asarray(NF4_CODE)
    idx = jnp.argmin(jnp.abs(scaled[..., None] - code), axis=-1)  # (...,nb,qb)
    idx = idx.astype(jnp.uint8).reshape(*lead, din, dout)
    hi, lo = idx[..., 0::2], idx[..., 1::2]
    packed = (hi << 4) | lo
    return packed, absmax


def nf4_dequant(w_nf4: jnp.ndarray, absmax: jnp.ndarray) -> jnp.ndarray:
    """Inverse of nf4_quantize -> float32 (..., in, out)."""
    *lead, din, half = w_nf4.shape
    dout = half * 2
    nb = absmax.shape[-1]
    qblock = (din * dout) // nb
    hi = (w_nf4 >> 4).astype(jnp.int32)
    lo = (w_nf4 & 0xF).astype(jnp.int32)
    idx = jnp.stack([hi, lo], axis=-1).reshape(*lead, din, dout)
    code = jnp.asarray(NF4_CODE)
    vals = code[idx]
    vals = vals.reshape(*lead, nb, qblock) * absmax[..., None]
    return vals.reshape(*lead, din, dout)


def quant_error(w: jnp.ndarray, qblock: int = 64) -> float:
    """Relative L2 round-trip error (used by tests/benchmarks)."""
    q, a = nf4_quantize(w, qblock)
    wd = nf4_dequant(q, a)
    return float(jnp.linalg.norm(wd - w) / jnp.maximum(jnp.linalg.norm(w),
                                                       1e-12))


def nbytes_nf4(w_shape, qblock: int = 64) -> int:
    n = int(np.prod(w_shape))
    return n // 2 + (n // qblock) * 4
