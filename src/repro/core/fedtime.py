"""The FedTime forecasting model (paper C1): RevIN/instance-norm ->
channel independence -> patching -> patch+position embedding -> LLM
backbone (LLaMA-style decoder blocks) -> flatten -> linear forecast head ->
de-normalization.

The backbone reuses the dense-transformer block stack, so C2 (LoRA/QLoRA via
``repro.core.lora``) and C3 (federated aggregation of adapters) apply to this
model exactly as to the assigned LM architectures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.patching import (channel_merge, channel_split,
                                 init_patch_embed, make_patches, num_patches,
                                 patch_embed)
from repro.core.revin import init_revin, instance_norm, revin_denorm, revin_norm
from repro.models.layers.linear import dense, init_dense
from repro.models.losses import mse
from repro.models.transformer import _init_block, forward_hidden


def init(cfg: ModelConfig, key, *, num_channels: int = 1) -> dict:
    ft = cfg.fedtime
    dtype = jnp.dtype(cfg.param_dtype)
    N = num_patches(ft.lookback, ft.patch_len, ft.patch_stride)
    kp, kl, kh = jax.random.split(key, 3)
    keys = jax.random.split(kl, cfg.num_layers)
    return {
        "patch": init_patch_embed(kp, ft.patch_len, N, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys),
        "final_norm": {"scale": jnp.ones((cfg.d_model,), jnp.float32)},
        "head": init_dense(kh, N * cfg.d_model, ft.horizon, dtype),
        "revin": init_revin(num_channels),
    }


def forward(params, cfg: ModelConfig, x: jnp.ndarray, *,
            phase: str = "forecast", remat: bool = True) -> jnp.ndarray:
    """x: (B, L, M) history -> (B, T, M) forecast.

    phase='sft'      : plain instance norm (paper phase 1)
    phase='forecast' : RevIN with learnable affine (paper phase 2)
    """
    ft = cfg.fedtime
    B, L, M = x.shape
    x = x.astype(jnp.float32)
    if phase == "sft":
        xn, stats = instance_norm(x)
    else:
        xn, stats = revin_norm(params["revin"], x)

    u = channel_split(xn.astype(jnp.dtype(cfg.compute_dtype)))   # (B*M, L)
    p = make_patches(u, ft.patch_len, ft.patch_stride)           # (B*M, N, P)
    h = patch_embed(params["patch"], p)                          # (B*M, N, D)
    N = h.shape[1]
    positions = jnp.arange(N, dtype=jnp.int32)
    h = forward_hidden({"layers": params["layers"],
                        "final_norm": params["final_norm"]},
                       cfg, h, positions=positions, remat=remat)
    flat = h.reshape(B * M, N * cfg.d_model)
    y = dense(params["head"], flat)                              # (B*M, T)
    y = channel_merge(y.astype(jnp.float32), B, M)               # (B, T, M)
    if phase == "sft":
        return y * stats["sd"] + stats["mu"]
    return revin_denorm(params["revin"], y, stats)


def loss(params, cfg: ModelConfig, batch, *, phase: str = "forecast"):
    """Paper Eq. (5): MSE over channels and horizon."""
    pred = forward(params, cfg, batch["x"], phase=phase)
    return mse(pred, batch["y"])
