"""Channel independence + patching + patch/position embeddings (paper §3.2,
adopted from PatchTST [18])."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def num_patches(lookback: int, patch_len: int, stride: int) -> int:
    assert (lookback - patch_len) % stride == 0, \
        f"lookback={lookback} patch_len={patch_len} stride={stride}"
    return (lookback - patch_len) // stride + 1


def channel_split(x: jnp.ndarray) -> jnp.ndarray:
    """Channel independence: (B, L, M) -> (B*M, L) — each univariate series
    goes through the shared backbone independently (paper Fig. 1b)."""
    B, L, M = x.shape
    return x.transpose(0, 2, 1).reshape(B * M, L)


def channel_merge(y: jnp.ndarray, batch: int, channels: int) -> jnp.ndarray:
    """(B*M, T) -> (B, T, M)."""
    T = y.shape[-1]
    return y.reshape(batch, channels, T).transpose(0, 2, 1)


def make_patches(x: jnp.ndarray, patch_len: int, stride: int) -> jnp.ndarray:
    """(B*, L) -> (B*, N, P) overlapping patches."""
    L = x.shape[-1]
    N = num_patches(L, patch_len, stride)
    idx = (jnp.arange(N)[:, None] * stride +
           jnp.arange(patch_len)[None, :])                 # (N, P)
    return x[..., idx]                                     # gather


def init_patch_embed(key, patch_len: int, n_patches: int, d_model: int,
                     dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "w_p": (jax.random.normal(k1, (patch_len, d_model)) *
                patch_len ** -0.5).astype(dtype),          # Eq. (1) W_p
        "w_pos": (jax.random.normal(k2, (n_patches, d_model)) *
                  0.02).astype(dtype),                     # Eq. (1) W_pos
    }


def patch_embed(params, patches: jnp.ndarray) -> jnp.ndarray:
    """Eq. (1): X_d = X_p W_p + W_pos.  (B*, N, P) -> (B*, N, D)."""
    x = patches @ params["w_p"].astype(patches.dtype)
    return x + params["w_pos"][None].astype(patches.dtype)
