"""Client-side local training (paper Algorithm 1, UpdateDevice).

A client receives the global adapter tree, merges it into its frozen
(optionally NF4-quantized) base, runs ``local_steps`` of Adam on the
adapter leaves only, and returns the updated adapters — the only thing
that ever leaves the device (C2 + C3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lora import lora_tree, merge_lora
from repro.optim.adamw import adamw_init, adamw_update


@functools.partial(jax.jit, static_argnames=("loss_fn", "steps", "lr"))
def local_update(loss_fn, base_params, adapters, batches, *, steps: int,
                 lr: float = 1e-3):
    """Run ``steps`` local steps.

    loss_fn: (params, batch) -> scalar, closed over cfg.
    batches: pytree whose leaves have leading dim >= steps (batch per step).
    Returns (new_adapters, mean loss).
    """

    def adapter_loss(ad, batch):
        return loss_fn(merge_lora(base_params, ad), batch)

    grad_fn = jax.value_and_grad(adapter_loss)
    opt0 = adamw_init(adapters)

    def step(carry, i):
        ad, opt = carry
        batch = jax.tree.map(lambda b: b[i % b.shape[0]], batches)
        l, g = grad_fn(ad, batch)
        ad, opt = adamw_update(ad, g, opt, i + 1, lr=lr)
        return (ad, opt), l

    (ad, _), losses = jax.lax.scan(step, (adapters, opt0),
                                   jnp.arange(steps))
    return ad, losses.mean()


def client_payload(params) -> dict:
    """What the client transmits: adapters only."""
    return lora_tree(params)
