"""Secure aggregation (SecAgg-lite): pairwise additive masking.

The paper's privacy claim rests on data never leaving the device; adapter
*updates* still leak gradients. Classic mitigation (Bonawitz et al. 2017):
every client pair (i, j) derives a shared mask m_ij from a common seed;
client i adds +m_ij, client j adds −m_ij — masks cancel exactly in the
cluster sum, so the server only ever sees the aggregate.

This is the single-round, no-dropout-recovery variant (dropout recovery
needs the full secret-sharing protocol; out of scope — the fed_trainer
handles stragglers by exclusion *before* masking instead).
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp


def _pair_seed(round_idx: int, i: int, j: int) -> jax.Array:
    a, b = (i, j) if i < j else (j, i)
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(round_idx), a), b)


def _mask_tree(tree, seed, sign: float, scale: float = 1e-2):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(seed, len(leaves))
    masked = [l + sign * scale * jax.random.normal(k, l.shape, jnp.float32)
              .astype(l.dtype) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, masked)


def mask_update(update, *, client_id: int, participants: Sequence[int],
                round_idx: int, scale: float = 1e-2):
    """Client-side: add pairwise masks against every other participant."""
    out = update
    for other in participants:
        if other == client_id:
            continue
        sign = 1.0 if client_id < other else -1.0
        out = _mask_tree(out, _pair_seed(round_idx, client_id, other),
                         sign, scale)
    return out


def aggregate_masked(masked_updates: List, weights=None):
    """Server-side: plain (weighted) sum — masks cancel pairwise.

    NOTE: mask cancellation is exact only for the UNWEIGHTED sum; with
    weighted FedAvg the clients pre-scale their updates by w_s/Σw before
    masking (standard SecAgg practice), so the server just sums."""
    n = len(masked_updates)
    total = masked_updates[0]
    for u in masked_updates[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, u)
    if weights is None:
        return jax.tree.map(lambda a: a / n, total)
    return total
