"""Secure aggregation (SecAgg-lite): pairwise additive masking, with
dropout recovery over an integer (int8-range, EF-quantized) wire.

The paper's privacy claim rests on data never leaving the device; adapter
*updates* still leak gradients.  Classic mitigation (Bonawitz et al.
2017): every client pair (i, j) derives a shared mask m_ij from a common
seed; client i adds +m_ij, client j adds −m_ij — masks cancel exactly in
the cluster sum, so the server only ever sees the aggregate.

Two wire domains:

  * **float domain** (``mask_update`` / ``aggregate_masked`` /
    ``float_recovery_mask``) — Gaussian masks added to f32 trees.  The
    original single-round variant; cancellation is exact only up to f32
    rounding, and dropout recovery (re-adding the uncancelled masks of
    dropped partners) is likewise approximate.
  * **integer domain** (``secure_encode`` / ``mask_codes`` /
    ``unmask_sum`` / ``recovery_mask``) — the fault-tolerant path.  Each
    client quantizes its delta onto a *shared* step grid (int8-range
    codes, error-feedback residual carried per client, same EF semantics
    as the ``repro.dist.fedcomm`` wire), then masks the codes with
    pairwise uint32 streams; all arithmetic is mod 2³², where pairwise
    cancellation and dropout recovery are EXACT — bit for bit, for every
    surviving subset.  The shared grid also clips every upload to
    ±127·step, which bounds a byzantine client's influence for free (and
    makes NaN/Inf structurally impossible on this wire).

Dropout recovery: when clients commit masks against a participant set P
but only S ⊆ P actually upload, the survivor sum carries the uncancelled
masks ±m_ij for i ∈ S, j ∈ P∖S.  ``recovery_mask(S, P∖S, ...)``
regenerates exactly that residue (in the real protocol the survivors
reveal their pairwise seeds with the dropped via secret sharing; this
simulation regenerates them directly) and ``unmask_sum`` subtracts it —
the result equals the unmasked survivor code sum exactly.
"""

from __future__ import annotations

import os
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["mask_update", "aggregate_masked", "float_recovery_mask",
           "default_step", "secure_encode", "secure_decode_sum",
           "mask_codes", "recovery_mask", "unmask_sum", "pair_mask_u32"]


# ---------------------------------------------------------------------------
# Float domain (legacy single-round variant)
# ---------------------------------------------------------------------------

def _pair_seed(round_idx: int, i: int, j: int) -> jax.Array:
    a, b = (i, j) if i < j else (j, i)
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(round_idx), a), b)


def _mask_tree(tree, seed, sign: float, scale: float = 1e-2):
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(seed, len(leaves))
    masked = [l + sign * scale * jax.random.normal(k, l.shape, jnp.float32)
              .astype(l.dtype) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, masked)


def mask_update(update, *, client_id: int, participants: Sequence[int],
                round_idx: int, scale: float = 1e-2):
    """Client-side: add pairwise masks against every other participant."""
    out = update
    for other in participants:
        if other == client_id:
            continue
        sign = 1.0 if client_id < other else -1.0
        out = _mask_tree(out, _pair_seed(round_idx, client_id, other),
                         sign, scale)
    return out


def aggregate_masked(masked_updates: List, weights=None):
    """Server-side: plain (weighted) sum — masks cancel pairwise.

    NOTE: mask cancellation is exact only for the UNWEIGHTED sum; with
    weighted FedAvg the clients pre-scale their updates by w_s/Σw before
    masking (standard SecAgg practice), so the server just sums."""
    n = len(masked_updates)
    total = masked_updates[0]
    for u in masked_updates[1:]:
        total = jax.tree.map(lambda a, b: a + b, total, u)
    if weights is None:
        return jax.tree.map(lambda a: a / n, total)
    return total


def float_recovery_mask(survivors: Sequence[int], dropped: Sequence[int],
                        *, round_idx: int, like, scale: float = 1e-2):
    """Σ over (i ∈ survivors, j ∈ dropped) of the uncancelled mask
    survivor i added for dropped partner j — subtract this from the
    survivor sum to recover the unmasked aggregate (up to f32 rounding;
    the integer-domain path below is the exact one)."""
    total = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), like)
    for i in survivors:
        for j in dropped:
            sign = 1.0 if i < j else -1.0
            total = _mask_tree(total, _pair_seed(round_idx, i, j),
                               sign, scale)
    return total


# ---------------------------------------------------------------------------
# Integer domain (fault-tolerant path): shared-grid EF quantization
# ---------------------------------------------------------------------------

def default_step() -> float:
    """Shared quantization step of the secure integer wire
    (``REPRO_SECAGG_STEP``).  2⁻¹⁰ covers adapter deltas to ±0.124 at
    int8 range; clipping error lands in the per-client EF residual."""
    return float(os.environ.get("REPRO_SECAGG_STEP", str(2.0 ** -10)))


def secure_encode(flat: np.ndarray, residual: Optional[np.ndarray] = None,
                  *, step: Optional[float] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize a flat f32 payload onto the shared grid with error
    feedback: ``t = flat + residual``; codes = clip(round(t/step), ±127);
    new residual = t − codes·step (carried to this client's next round —
    clipping and rounding error are both fed back, so repeated rounds
    stay unbiased).  Returns ``(codes int32, new_residual f32)``."""
    step = step or default_step()
    flat = np.asarray(flat, np.float32)
    t = flat + (np.zeros_like(flat) if residual is None
                else np.asarray(residual, np.float32))
    codes = np.clip(np.rint(t / step), -127, 127).astype(np.int32)
    new_res = t - codes.astype(np.float32) * np.float32(step)
    return codes, new_res


def secure_decode_sum(code_sum: np.ndarray, *,
                      step: Optional[float] = None) -> np.ndarray:
    """Dequantize an exact integer code sum: one f32 multiply per
    element, so equal code sums give bit-identical floats."""
    step = step or default_step()
    return code_sum.astype(np.float32) * np.float32(step)


def pair_mask_u32(round_idx: int, i: int, j: int, n: int) -> np.ndarray:
    """The (order-independent) pairwise mask stream for clients (i, j) in
    round ``round_idx``: ``n`` uint32 values, deterministic from the pair
    seed.  Both endpoints generate the identical stream."""
    a, b = (i, j) if i < j else (j, i)
    rng = np.random.default_rng(
        np.random.SeedSequence(entropy=round_idx, spawn_key=(a, b)))
    return rng.integers(0, 2 ** 32, size=n, dtype=np.uint32)


def mask_codes(codes: np.ndarray, *, client_id: int,
               participants: Sequence[int],
               round_idx: int) -> np.ndarray:
    """Client-side: codes + Σ ±m_ij mod 2³².  Sign convention: the
    lower-id endpoint adds, the higher-id subtracts — so each pair's
    masks cancel exactly in modular arithmetic."""
    out = codes.astype(np.int64).astype(np.uint32)   # two's complement
    for other in participants:
        if other == client_id:
            continue
        m = pair_mask_u32(round_idx, client_id, other, codes.size)
        out = (out + m) if client_id < other else (out - m)
    return out


def recovery_mask(survivors: Sequence[int], dropped: Sequence[int], *,
                  round_idx: int, n: int) -> np.ndarray:
    """The mod-2³² residue the dropped clients leave in the survivor sum:
    Σ over (i ∈ survivors, j ∈ dropped) of ±m_ij with i's sign.  Subtract
    from the masked survivor sum to unmask it exactly."""
    total = np.zeros(n, np.uint32)
    for i in survivors:
        for j in dropped:
            m = pair_mask_u32(round_idx, i, j, n)
            total = (total + m) if i < j else (total - m)
    return total


def unmask_sum(masked: Sequence[np.ndarray], survivors: Sequence[int],
               *, participants: Sequence[int],
               round_idx: int) -> np.ndarray:
    """Server-side: sum the survivors' masked codes, subtract the
    recovery residue for every dropped participant, and center back to
    signed integers.  EXACT for every surviving subset: the result
    equals Σ (unmasked codes) over survivors, provided that true sum
    fits in int32 (|codes| ≤ 127 ⇒ up to ~16.9M clients)."""
    if not masked:
        raise ValueError("unmask_sum needs at least one survivor upload")
    if len(masked) != len(survivors):
        raise ValueError(f"{len(masked)} uploads for {len(survivors)} "
                         "survivors")
    dropped = [p for p in participants if p not in set(survivors)]
    total = np.zeros(masked[0].size, np.uint32)
    for u in masked:
        total = total + np.asarray(u, np.uint32)
    total = total - recovery_mask(survivors, dropped,
                                  round_idx=round_idx, n=total.size)
    return total.astype(np.int32)                    # exact recentring
