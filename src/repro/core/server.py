"""Server-side aggregation (paper Algorithm 1, lines 12-14).

Per cluster: weighted FedAvg of client adapter trees, then FedAdam on the
cluster's global adapters (the paper uses FedAdam to update the QLoRA
parameters, §4.1 Implementation Details).

Fault tolerance additions:

  * :meth:`ClusterServer.apply_deltas` — the delta-domain entry point the
    resilient round loop uses.  Under partial participation the cohort is
    whatever survived the deadline plus whatever drained from the
    staleness buffer; weights are renormalized to sum to 1 over exactly
    that cohort before the FedAdam step, so a half-empty round moves the
    server by a correctly-weighted average, not a half-scaled one.
  * :class:`StalenessBuffer` — server-side accumulation of late client
    deltas ("async" aggregation on the virtual clock).  Deltas arriving
    after a round's deadline buffer until the cluster's next aggregation;
    a drained delta ``s`` rounds old is down-weighted by ``decay**s`` and
    rejected outright at or beyond ``limit`` rounds — bounded staleness, so the
    round clock is set by the deadline rather than by the slowest of
    millions of clients.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.optim.fedadam import fedadam_init, fedadam_update, fedavg


class ClusterServer:
    """Holds one cluster's global adapter state + FedAdam moments."""

    def __init__(self, adapters, *, lr: float = 1e-2):
        self.adapters = adapters
        self.opt = fedadam_init(adapters)
        self.lr = lr
        self.round = 0

    def aggregate(self, client_adapters, weights):
        """client_adapters: list of adapter trees; weights: per-device w_s
        (paper: w_{s,c}, e.g. local dataset sizes)."""
        deltas = [jax.tree.map(
            lambda a, g: a.astype(jnp.float32) - g.astype(jnp.float32),
            ad, self.adapters) for ad in client_adapters]
        return self.apply_deltas(deltas, weights)

    def apply_deltas(self, deltas, weights):
        """FedAdam step from client adapter DELTAS (vs each client's
        pull-time global — under async staleness these differ from the
        current global, which is exactly why the delta is the unit that
        buffers).  ``weights`` are renormalized to sum to 1 over this
        cohort; a partial cohort therefore yields an unbiased weighted
        average, not a scaled-down one."""
        if not deltas:
            raise ValueError("apply_deltas needs a non-empty cohort")
        w = jnp.asarray(weights, jnp.float32)
        if w.shape != (len(deltas),):
            raise ValueError(
                f"weights shape {w.shape} != cohort size {len(deltas)}")
        if float(w.sum()) <= 0.0:
            raise ValueError("cohort weights must sum to a positive value")
        avg_delta = fedavg(deltas, w)        # normalizes: Σ w_k = 1
        self.adapters, self.opt = fedadam_update(
            self.adapters, avg_delta, self.opt, lr=self.lr)
        self.round += 1
        return self.adapters


# ---------------------------------------------------------------------------
# Staleness-bounded async buffering
# ---------------------------------------------------------------------------

@dataclass
class BufferedDelta:
    """One late client delta parked server-side until its cluster's next
    aggregation window."""

    client: int
    cluster: int
    origin_round: int          # the round whose global the delta is against
    ready_at: float            # virtual arrival time
    weight: float              # raw client weight (pre-decay)
    loss: float
    delta: Any                 # adapter-delta pytree (post-wire view)


class StalenessBuffer:
    """Bounded-staleness accumulation of late deltas; see module
    docstring.  ``drain`` returns ``(apply, reject)``: entries whose
    arrival fell inside the closing window, split by the staleness bound,
    with each applied entry's weight pre-multiplied by ``decay**s``.

    Boundary semantics: ``limit`` is EXCLUSIVE — an entry whose staleness
    equals ``limit`` is rejected, on this path and on the trainer's apply
    path alike (both call :meth:`is_stale`, one predicate for both sides;
    the old ``> limit`` drain test accepted the boundary while the apply
    side's documentation promised rejection).  ``limit`` must therefore be
    >= 2 for any buffered delta to ever apply, since :meth:`staleness_of`
    floors staleness at 1."""

    def __init__(self, limit: int = 2, decay: float = 0.5):
        if limit < 0 or not (0.0 < decay <= 1.0):
            raise ValueError(f"bad staleness bound limit={limit} "
                             f"decay={decay}")
        self.limit = limit
        self.decay = decay
        self.entries: List[BufferedDelta] = []

    @staticmethod
    def staleness_of(round_idx: int, origin_round: int) -> int:
        """Rounds a buffered delta has aged: floored at 1 (a delta drained
        in the round after its origin is 1 round stale).  The single
        definition both the drain and the trainer's apply path use."""
        return max(round_idx - origin_round, 1)

    def is_stale(self, staleness: int) -> bool:
        """True when ``staleness`` is at or beyond ``limit`` — the one
        boundary predicate shared by ``drain`` and the apply path."""
        return staleness >= self.limit

    def add(self, entry: BufferedDelta) -> None:
        if not math.isfinite(entry.ready_at):
            raise ValueError("non-arriving (hung) uploads never buffer")
        self.entries.append(entry)

    def __len__(self) -> int:
        return len(self.entries)

    def drain(self, cluster: int, round_idx: int, window_end: float
              ) -> Tuple[List[Tuple[BufferedDelta, float]],
                         List[Tuple[BufferedDelta, int]]]:
        """Pull this cluster's entries that arrived by ``window_end``.
        Returns ``(apply, reject)`` where ``apply`` pairs each entry with
        its decayed weight and ``reject`` pairs each with its (too-large)
        staleness."""
        ready = [e for e in self.entries
                 if e.cluster == cluster and e.ready_at <= window_end]
        taken = {id(e) for e in ready}
        self.entries = [e for e in self.entries if id(e) not in taken]
        apply, reject = [], []
        for e in ready:
            staleness = self.staleness_of(round_idx, e.origin_round)
            if self.is_stale(staleness):
                reject.append((e, staleness))
            else:
                apply.append((e, e.weight * self.decay ** staleness))
        return apply, reject
