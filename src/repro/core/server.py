"""Server-side aggregation (paper Algorithm 1, lines 12-14).

Per cluster: weighted FedAvg of client adapter trees, then FedAdam on the
cluster's global adapters (the paper uses FedAdam to update the QLoRA
parameters, §4.1 Implementation Details).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.fedadam import fedadam_init, fedadam_update, fedavg


class ClusterServer:
    """Holds one cluster's global adapter state + FedAdam moments."""

    def __init__(self, adapters, *, lr: float = 1e-2):
        self.adapters = adapters
        self.opt = fedadam_init(adapters)
        self.lr = lr
        self.round = 0

    def aggregate(self, client_adapters, weights):
        """client_adapters: list of adapter trees; weights: per-device w_s
        (paper: w_{s,c}, e.g. local dataset sizes)."""
        avg = fedavg(client_adapters, jnp.asarray(weights, jnp.float32))
        delta = jax.tree.map(
            lambda a, g: a.astype(jnp.float32) - g.astype(jnp.float32),
            avg, self.adapters)
        self.adapters, self.opt = fedadam_update(
            self.adapters, delta, self.opt, lr=self.lr)
        self.round += 1
        return self.adapters
