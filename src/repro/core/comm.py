"""Communication-overhead accounting (paper C5 / Figure 5).

Counts exact bytes and messages per federated round and models wall time
from configurable link characteristics.  Three strategies are compared,
matching the paper's Figure 5 baselines:

  * fedtime      — LoRA adapters only (the paper's method)
  * fed_full     — full model weights each way (naive FedAvg)
  * centralized  — raw windowed data shipped to the server once per epoch

Mesh mapping (DESIGN.md §3): on the dry-run mesh, intra-cluster aggregation
is a psum over the ``data`` axis and cross-site aggregation crosses ``pod``;
``collective_bytes_per_round`` reports what each axis carries so the §Roofline
collective term and the paper's comm metric are the same quantity measured
two ways.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.lora import lora_tree, tree_nbytes


@dataclass(frozen=True)
class LinkModel:
    """Edge federation link characteristics (paper's EV-charging setting)."""
    uplink_bps: float = 100e6          # 100 Mbit/s edge uplink
    downlink_bps: float = 300e6
    latency_s: float = 0.030           # per message
    # dry-run mesh analogue (v5e ICI), for the roofline cross-check
    ici_bps: float = 50e9 * 8


@dataclass
class RoundStats:
    bytes_up: int
    bytes_down: int
    messages: int
    time_s: float

    @property
    def megabytes(self) -> float:
        return (self.bytes_up + self.bytes_down) / 1e6


def fedtime_round(params, *, clients_per_round: int, num_clusters: int,
                  link: LinkModel = LinkModel()) -> RoundStats:
    """LoRA-only payload: each participating client uploads its adapter
    delta; each cluster broadcasts one aggregated adapter back."""
    payload = tree_nbytes(lora_tree(params))
    up = payload * clients_per_round
    down = payload * clients_per_round        # broadcast back to participants
    msgs = 2 * clients_per_round + num_clusters   # +cluster->server merges
    t = (up / link.uplink_bps * 8 + down / link.downlink_bps * 8 +
         msgs * link.latency_s)
    return RoundStats(up, down, msgs, t)


def fed_full_round(params, *, clients_per_round: int, num_clusters: int,
                   link: LinkModel = LinkModel()) -> RoundStats:
    payload = tree_nbytes(params)
    up = payload * clients_per_round
    down = payload * clients_per_round
    msgs = 2 * clients_per_round + num_clusters
    t = (up / link.uplink_bps * 8 + down / link.downlink_bps * 8 +
         msgs * link.latency_s)
    return RoundStats(up, down, msgs, t)


def centralized_epoch(num_samples: int, lookback: int, horizon: int,
                      channels: int, *, num_clients: int,
                      link: LinkModel = LinkModel()) -> RoundStats:
    """Raw data shipped to the server (the centralized baseline's cost)."""
    sample_bytes = (lookback + horizon) * channels * 4
    up = num_samples * sample_bytes
    msgs = num_clients
    t = up / link.uplink_bps * 8 + msgs * link.latency_s
    return RoundStats(up, 0, msgs, t)


def collective_bytes_per_round(params, mesh_shape) -> dict:
    """Bytes crossing each mesh axis for one aggregation round when the
    federation is mapped onto the dry-run mesh (clients -> data axis,
    sites -> pod axis). An all-reduce of payload P over an n-way axis moves
    2·P·(n-1)/n per device (ring).

    ``mesh_shape`` may be a ``jax.sharding.Mesh`` (its ``.shape`` is used)
    or a plain ``{axis: size}`` dict.  ``repro.dist.fed`` derives the same
    quantity from its psum axis mapping; ``tests/test_dist_fed_mapping.py``
    keeps the two in agreement."""
    shape = dict(getattr(mesh_shape, "shape", mesh_shape))
    payload = tree_nbytes(lora_tree(params))
    out = {}
    for axis in ("data", "pod"):
        n = shape.get(axis, 1)
        out[axis] = 0 if n <= 1 else int(2 * payload * (n - 1) / n)
    return out
