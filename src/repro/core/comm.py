"""Communication-overhead accounting (paper C5 / Figure 5).

Counts exact bytes and messages per federated round and models wall time
from configurable link characteristics.  Three strategies are compared,
matching the paper's Figure 5 baselines:

  * fedtime      — LoRA adapters only (the paper's method)
  * fed_full     — full model weights each way (naive FedAvg)
  * centralized  — raw windowed data shipped to the server once per epoch

Mesh mapping (DESIGN.md §3): on the dry-run mesh, intra-cluster aggregation
is a ring all-reduce over the ``data`` axis and cross-site aggregation
crosses ``pod``; ``collective_bytes_per_round`` reports what each axis
carries so the §Roofline collective term and the paper's comm metric are the
same quantity measured two ways.

Wire formats (``REPRO_FED_WIRE``): the federated payload can cross the wire
as f32, bf16, or int8 codes with per-``qblock`` f32 absmax scales
(``REPRO_FED_QBLOCK``, default 128).  ``ring_wire_plan`` is the single
source of truth for the chunk geometry and per-hop transfer sizes of the
hand-rolled bidirectional ring (``repro.kernels.ring_allreduce``); the
kernel sizes its wire buffers from this plan, ``repro.dist.fed
.expected_collective_bytes`` and ``collective_bytes_per_round`` recompute
the same totals, and ``tests/test_ring_collective.py`` keeps all three in
agreement — one number measured three ways.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass

from repro.core.lora import count_params, lora_tree, tree_nbytes

# ---------------------------------------------------------------------------
# Wire formats
# ---------------------------------------------------------------------------

WIRE_FORMATS = ("f32", "bf16", "int8")
_WIRE_CODE_BYTES = {"f32": 4, "bf16": 2, "int8": 1}


def _check_wire(wire: str) -> str:
    if wire not in WIRE_FORMATS:
        raise ValueError(f"wire format {wire!r}: choose from {WIRE_FORMATS}")
    return wire


def wire_format(default: str = "f32") -> str:
    """Effective federated wire format (``REPRO_FED_WIRE``, read per call
    like every REPRO_ flag)."""
    return _check_wire(os.environ.get("REPRO_FED_WIRE", default))


def wire_qblock() -> int:
    """Absmax-scale block size for the int8 wire (``REPRO_FED_QBLOCK``).
    128 keeps blocks lane-aligned on TPU and the scale overhead at
    4/128 bytes per element."""
    return int(os.environ.get("REPRO_FED_QBLOCK", "128"))


@dataclass(frozen=True)
class RingWirePlan:
    """Chunk geometry of one n-way bidirectional ring all-reduce.

    The payload (``elems`` f32 values) is carved into ``n_chunks = 2·n``
    chunks — n rotating clockwise, n counter-clockwise, using both ICI
    directions.  ``chunk_elems`` is ceil(elems / 2n), rounded up to a
    ``qblock`` multiple for the quantized wires (int8 scales cover full
    blocks; bf16 shares the alignment so the fused hop kernel tiles
    (rows, qblock)); the padding is real wire bytes and is counted.  Per
    round every device sends each direction's chunk once per reduce-scatter
    hop and once per all-gather hop: ``sends = 2 phases · (n-1) hops ·
    2 directions``.  For the f32 wire on a divisible payload this reduces
    exactly to the classic 2·P·(n-1)/n.
    """
    wire: str
    n: int
    qblock: int
    elems: int
    chunk_elems: int
    n_chunks: int
    code_bytes: int      # per chunk
    scale_bytes: int     # per chunk (int8 wire only)
    sends: int           # chunk transfers per device per round

    @property
    def chunk_bytes(self) -> int:
        return self.code_bytes + self.scale_bytes

    @property
    def per_device_bytes(self) -> int:
        return self.sends * self.chunk_bytes


def ring_wire_plan(n_elems: int, n: int, wire: str = None,
                   qblock: int = None) -> RingWirePlan:
    """The ring chunking ``repro.kernels.ring_allreduce`` actually uses —
    byte accounting and wire-buffer sizing share this one function."""
    wire = _check_wire(wire) if wire else wire_format()
    qblock = qblock or wire_qblock()
    if n <= 1:
        return RingWirePlan(wire, n, qblock, n_elems, n_elems, 1, 0, 0, 0)
    c = math.ceil(n_elems / (2 * n))
    if wire in ("int8", "bf16"):
        c = math.ceil(c / qblock) * qblock
    code = c * _WIRE_CODE_BYTES[wire]
    scale = 4 * (c // qblock) if wire == "int8" else 0
    return RingWirePlan(wire, n, qblock, n_elems, c, 2 * n, code, scale,
                        sends=4 * (n - 1))


def ring_wire_bytes(n_elems: int, n: int, wire: str = None,
                    qblock: int = None) -> int:
    """Per-device bytes one n-way bidirectional ring all-reduce moves."""
    return ring_wire_plan(n_elems, n, wire, qblock).per_device_bytes


def wire_payload_bytes(n_elems: int, wire: str = None,
                       qblock: int = None) -> int:
    """Point-to-point upload size of an ``n_elems`` f32 payload on the
    given wire (client -> server, no ring): codes + absmax scales."""
    wire = _check_wire(wire) if wire else wire_format()
    qblock = qblock or wire_qblock()
    bytes_ = n_elems * _WIRE_CODE_BYTES[wire]
    if wire == "int8":
        bytes_ += 4 * math.ceil(n_elems / qblock)
    return bytes_


@dataclass(frozen=True)
class LinkModel:
    """Edge federation link characteristics (paper's EV-charging setting)."""
    uplink_bps: float = 100e6          # 100 Mbit/s edge uplink
    downlink_bps: float = 300e6
    latency_s: float = 0.030           # per message
    # dry-run mesh analogue (v5e ICI), for the roofline cross-check
    ici_bps: float = 50e9 * 8


@dataclass
class RoundStats:
    bytes_up: int
    bytes_down: int
    messages: int
    time_s: float

    @property
    def megabytes(self) -> float:
        return (self.bytes_up + self.bytes_down) / 1e6


def fedtime_round(params, *, clients_per_round: int, num_clusters: int,
                  link: LinkModel = LinkModel(),
                  wire: str = None) -> RoundStats:
    """LoRA-only payload: each participating client uploads its adapter
    delta; each cluster broadcasts one aggregated adapter back.  ``wire``
    (default ``REPRO_FED_WIRE``) prices the payload in its wire encoding —
    int8 codes + per-qblock absmax scales cut the round to ~26% of f32."""
    payload = wire_payload_bytes(count_params(lora_tree(params)), wire)
    up = payload * clients_per_round
    down = payload * clients_per_round        # broadcast back to participants
    msgs = 2 * clients_per_round + num_clusters   # +cluster->server merges
    t = (up / link.uplink_bps * 8 + down / link.downlink_bps * 8 +
         msgs * link.latency_s)
    return RoundStats(up, down, msgs, t)


def fed_full_round(params, *, clients_per_round: int, num_clusters: int,
                   link: LinkModel = LinkModel()) -> RoundStats:
    payload = tree_nbytes(params)
    up = payload * clients_per_round
    down = payload * clients_per_round
    msgs = 2 * clients_per_round + num_clusters
    t = (up / link.uplink_bps * 8 + down / link.downlink_bps * 8 +
         msgs * link.latency_s)
    return RoundStats(up, down, msgs, t)


def centralized_epoch(num_samples: int, lookback: int, horizon: int,
                      channels: int, *, num_clients: int,
                      link: LinkModel = LinkModel()) -> RoundStats:
    """Raw data shipped to the server (the centralized baseline's cost)."""
    sample_bytes = (lookback + horizon) * channels * 4
    up = num_samples * sample_bytes
    msgs = num_clients
    t = up / link.uplink_bps * 8 + msgs * link.latency_s
    return RoundStats(up, 0, msgs, t)


def collective_bytes_per_round(params, mesh_shape, wire: str = None) -> dict:
    """Bytes crossing each mesh axis for one aggregation round when the
    federation is mapped onto the dry-run mesh (clients -> data axis,
    sites -> pod axis), in the ``wire`` encoding (default
    ``REPRO_FED_WIRE``).  The count is the exact bidirectional-ring plan of
    ``ring_wire_plan`` — for the f32 wire on a divisible payload it reduces
    to the classic 2·P·(n-1)/n per device.

    ``mesh_shape`` may be a ``jax.sharding.Mesh`` (its ``.shape`` is used)
    or a plain ``{axis: size}`` dict.  ``repro.dist.fed`` derives the same
    quantity from its ring axis mapping and the kernel's byte ledger
    measures it from the actual ppermute buffers;
    ``tests/test_dist_fed_mapping.py`` / ``tests/test_ring_collective.py``
    keep the three in agreement."""
    shape = dict(getattr(mesh_shape, "shape", mesh_shape))
    elems = count_params(lora_tree(params))
    return {axis: ring_wire_bytes(elems, shape.get(axis, 1), wire)
            for axis in ("data", "pod")}
