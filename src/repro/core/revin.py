"""RevIN — Reversible Instance Normalization (Kim et al., ICLR 2022).

Used in the paper's forecasting fine-tuning phase; plain instance norm
(non-learnable) is used in the supervised fine-tuning phase (§3.2
Normalization).
"""

from __future__ import annotations

import jax.numpy as jnp


def init_revin(num_channels: int):
    return {"gamma": jnp.ones((num_channels,), jnp.float32),
            "beta": jnp.zeros((num_channels,), jnp.float32)}


def revin_norm(params, x, eps: float = 1e-5):
    """x: (B, L, M) -> (normalized x, stats). Affine if params given."""
    if params is not None:
        assert x.shape[-1] == params["gamma"].shape[0],             (x.shape, params["gamma"].shape)
    mu = x.mean(axis=1, keepdims=True)
    sd = jnp.sqrt(x.var(axis=1, keepdims=True) + eps)
    xn = (x - mu) / sd
    if params is not None:
        xn = xn * params["gamma"][None, None, :] + params["beta"][None, None, :]
    return xn, {"mu": mu, "sd": sd}


def revin_denorm(params, y, stats, eps: float = 1e-5):
    """y: (B, T, M) model output -> de-normalized forecast."""
    if params is not None:
        g = params["gamma"][None, None, :]
        g_safe = jnp.where(jnp.abs(g) < 1e-12, 1e-12, g)
        y = (y - params["beta"][None, None, :]) / g_safe
    return y * stats["sd"] + stats["mu"]


def instance_norm(x, eps: float = 1e-5):
    """Phase-1 normalization: zero-mean unit-std per instance (non-learnable)."""
    return revin_norm(None, x, eps)
