"""Direct Preference Optimization for time-series alignment (paper C4).

The paper applies DPO post-SFT "to capture any change of variables,
ensuring a more effective adaptation to the intricacies of time series
forecasting" using 10K comparison pairs.  Adaptation (DESIGN.md §6): a
preference pair is (history x, preferred forecast y_w, dispreferred
forecast y_l); the policy "log-likelihood" of a forecast is the Gaussian
log-density -||y - f(x)||²/2, which turns DPO's logit into a difference of
squared errors — the regression analogue of token log-probs.

    L = -log σ( β [ (log π(y_w|x) - log π_ref(y_w|x))
                  - (log π(y_l|x) - log π_ref(y_l|x)) ] )
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import fedtime


def _logp(pred, y):
    """Per-sample Gaussian log-density (up to a constant)."""
    d = (pred - y).astype(jnp.float32)
    return -0.5 * jnp.sum(jnp.square(d), axis=(1, 2))        # (B,)


def dpo_loss(params, ref_params, cfg, batch, *, beta: float = 0.1,
             phase: str = "sft"):
    """batch: {"x": (B,L,M), "y_w": (B,T,M), "y_l": (B,T,M)}."""
    pred = fedtime.forward(params, cfg, batch["x"], phase=phase)
    ref_pred = fedtime.forward(ref_params, cfg, batch["x"], phase=phase)
    ref_pred = jax.lax.stop_gradient(ref_pred)
    logit = ((_logp(pred, batch["y_w"]) - _logp(ref_pred, batch["y_w"])) -
             (_logp(pred, batch["y_l"]) - _logp(ref_pred, batch["y_l"])))
    return -jnp.mean(jax.nn.log_sigmoid(beta * logit))


def make_preference_pairs(key, x, y, *, noise_lo=0.05, noise_hi=0.5):
    """Synthesize (y_w, y_l) from ground truth: y_w = light perturbation,
    y_l = heavy perturbation — mirrors 'better vs worse forecast' feedback
    (UltraFeedback substitute, DESIGN.md §6)."""
    k1, k2 = jax.random.split(key)
    scale = jnp.std(y, axis=1, keepdims=True) + 1e-6
    y_w = y + noise_lo * scale * jax.random.normal(k1, y.shape)
    y_l = y + noise_hi * scale * jax.random.normal(k2, y.shape)
    return {"x": x, "y_w": y_w, "y_l": y_l}
