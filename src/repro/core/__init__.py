"""The paper's primary contribution: FedTime's federated LLM fine-tuning
system — clustering (C3), LoRA/QLoRA (C2), the TS model (C1), DPO (C4),
and communication accounting (C5)."""

from repro.core import (client, clustering, comm, dpo, fedtime, lora,
                        patching, quant, revin, server)

__all__ = ["client", "clustering", "comm", "dpo", "fedtime", "lora",
           "patching", "quant", "revin", "server"]
