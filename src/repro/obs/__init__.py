"""``repro.obs`` — the unified observability layer.

One process-global structured tracer (``repro.obs.trace``) threads through
the serving engine, the federated trainer, and the launchers; mergeable
quantile sketches live in ``repro.obs.sketch``, the per-client federated
round ledger in ``repro.obs.fleet``, device-memory / HLO-cost attribution
in ``repro.obs.devmem``, the crash-dump flight recorder in
``repro.obs.flight``, and bench provenance + regression gates in
``repro.obs.bench_gate``.  Import this package, not the submodules, from
instrumented code::

    from repro import obs

    with obs.span("engine.decode_step", device=True, step=i):
        ...
    obs.counter("ring.wire_bytes.data", nbytes)
    obs.hist("fed.fit_wall_s", dt, sketch=True)   # mergeable percentiles
    obs.dump("trace.json")        # -> chrome://tracing / Perfetto UI

``REPRO_TRACE=0`` turns every call into a no-op; ``REPRO_TRACE_OUT=f.json``
dumps the trace at exit.  Even with the tracer off, the flight recorder
keeps the last ``REPRO_FLIGHT_CAP`` events and ``REPRO_FLIGHT_OUT=f.json``
arms post-mortem dumps (atexit / unhandled exception / engine distress);
``REPRO_FLIGHT=0`` disables that last layer too.
"""

from repro.obs import devmem, fleet
from repro.obs.devmem import memory_snapshot, scope_costs, watermark
from repro.obs.fleet import ClientRecord, FleetLedger
from repro.obs.flight import (FlightRecorder, flight_enabled, get_flight,
                              maybe_dump as flight_maybe_dump)
from repro.obs.sketch import QuantileSketch, merge_all
from repro.obs.trace import (Histogram, Tracer, add_span, counter,
                             counter_track, dump, gauge, get_tracer, hist,
                             instant, reset, span, span_count, step_span,
                             trace_enabled)

enabled = trace_enabled

__all__ = [
    "ClientRecord", "FleetLedger", "FlightRecorder", "Histogram",
    "QuantileSketch", "Tracer", "add_span", "counter", "counter_track",
    "devmem", "dump", "enabled", "fleet", "flight_enabled",
    "flight_maybe_dump", "gauge", "get_flight", "get_tracer", "hist",
    "instant", "memory_snapshot", "merge_all", "reset", "scope_costs",
    "span", "span_count", "step_span", "trace_enabled", "watermark",
]
