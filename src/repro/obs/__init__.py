"""``repro.obs`` — the unified observability layer.

One process-global structured tracer (``repro.obs.trace``) threads through
the serving engine, the federated trainer, and the launchers; bench
provenance + regression gates live in ``repro.obs.bench_gate``.  Import
this package, not the submodules, from instrumented code::

    from repro import obs

    with obs.span("engine.decode_step", device=True, step=i):
        ...
    obs.counter("ring.wire_bytes.data", nbytes)
    obs.dump("trace.json")        # -> chrome://tracing / Perfetto UI

``REPRO_TRACE=0`` turns every call into a no-op; ``REPRO_TRACE_OUT=f.json``
dumps the trace at exit.
"""

from repro.obs.trace import (Histogram, Tracer, add_span, counter,
                             counter_track, dump, gauge, get_tracer, hist,
                             instant, reset, span, span_count, step_span,
                             trace_enabled)

enabled = trace_enabled

__all__ = [
    "Histogram", "Tracer", "add_span", "counter", "counter_track", "dump",
    "enabled", "gauge", "get_tracer", "hist", "instant", "reset", "span",
    "span_count", "step_span", "trace_enabled",
]
