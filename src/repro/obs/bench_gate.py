"""Bench provenance + regression gates over the committed ``BENCH_*.json``.

The ``BENCH_*`` files are the repo's per-PR perf trajectory: every claim in
the ROADMAP (flash-decode speedup, paged concurrency, int8 wire fraction)
lives in one of them.  This module makes them load-bearing:

  * :func:`provenance` — what produced a bench run: git SHA, jax/jaxlib
    versions, backend + device kind, and every ``REPRO_*`` env knob.
    ``benchmarks/run.py`` stamps it into each file it writes, so a number
    can always be traced back to the toolchain that measured it.
  * :func:`merge_rows` — row-level merge keyed on row identity, so
    ``benchmarks/run.py --only kernels`` refreshes exactly the rows it
    re-measured and leaves the rest of the file intact (no more
    whole-file clobbering on partial runs).
  * :data:`GATES` / :func:`check_suite` — the regression gate.  Each gated
    metric compares a fresh measurement against the committed baseline
    with a per-metric relative tolerance (generous for wall-clock-derived
    ratios, zero for deterministic byte/count invariants) plus an optional
    absolute floor/ceiling that must hold regardless of the baseline.
    ``benchmarks/run.py --gate`` fails CI when any gate trips.
"""

from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

# the suites whose rows persist to BENCH_<suite>.json
BENCH_SUITES = ("kernels", "serving", "collectives")

# fields identifying a row across runs (subset present per suite)
_ROW_KEY_FIELDS = ("row", "name", "case", "wire")


def bench_path(suite: str, root: str = ".") -> str:
    return os.path.join(root, f"BENCH_{suite}.json")


def row_key(row: dict) -> tuple:
    return tuple(row.get(k) for k in _ROW_KEY_FIELDS)


def provenance() -> dict:
    """Environment stamp for a bench run.  Never raises: every field
    degrades to ``"unknown"`` so the stamp works in stripped containers."""
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    try:
        import jax
        import jaxlib
        jax_v, jaxlib_v = jax.__version__, jaxlib.__version__
        backend = jax.default_backend()
        device_kind = jax.devices()[0].device_kind
    except Exception:                           # pragma: no cover
        jax_v = jaxlib_v = backend = device_kind = "unknown"
    try:
        from repro.obs import devmem
        peak = devmem.peak_bytes()
    except Exception:                           # pragma: no cover
        peak = 0
    return {
        "git_sha": sha,
        "jax": jax_v,
        "jaxlib": jaxlib_v,
        "backend": backend,
        "device_kind": device_kind,
        # allocator peak where the backend tracks it, live-buffer footprint
        # otherwise — BENCH speedups carry their memory watermark
        "device_peak_bytes": peak,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "env": {k: v for k, v in sorted(os.environ.items())
                if k.startswith("REPRO_") or k == "XLA_FLAGS"},
    }


# fields whose baseline/current mismatch makes gate comparisons bogus
_DRIFT_FIELDS = ("backend", "device_kind")


def load_provenance(suite: str, root: str = ".") -> Optional[dict]:
    path = bench_path(suite, root)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f).get("provenance")
    except (OSError, ValueError):
        return None


def provenance_drift(baseline: Optional[dict],
                     current: Optional[dict] = None) -> List[str]:
    """Warnings (NOT failures) when a committed baseline was measured on a
    different backend/device than the current run — a CPU baseline gated
    against a GPU run produces bogus "regressions", and vice versa.  The
    gate still runs (absolute bounds stay meaningful); the warnings tell
    the reader which relative comparisons to distrust."""
    if not baseline:
        return []
    current = current or provenance()
    out = []
    for f in _DRIFT_FIELDS:
        b, c = baseline.get(f, "unknown"), current.get(f, "unknown")
        if b != c and "unknown" not in (b, c):
            out.append(f"provenance drift: baseline {f}={b!r} but this "
                       f"run has {f}={c!r} — relative gates are "
                       f"cross-{f} and may be bogus")
    return out


def merge_rows(old_rows: Sequence[dict],
               new_rows: Sequence[dict]) -> List[dict]:
    """Fresh rows replace same-identity old rows in place (stable order);
    old rows the run didn't re-measure survive; genuinely new rows
    append."""
    fresh = {row_key(r): r for r in new_rows}
    out: List[dict] = []
    for r in old_rows:
        out.append(fresh.pop(row_key(r), r))
    out.extend(fresh.values())
    return out


def write_bench(suite: str, rows: Sequence[dict], *, full: bool,
                root: str = ".") -> str:
    """Merge ``rows`` into ``BENCH_<suite>.json`` (provenance-stamped)."""
    path = bench_path(suite, root)
    old: List[dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f).get("rows", [])
        except (OSError, ValueError):
            old = []
    with open(path, "w") as f:
        json.dump({"full": full, "rows": merge_rows(old, rows),
                   "provenance": provenance()}, f, indent=2)
    return path


def load_bench(suite: str, root: str = ".") -> Optional[List[dict]]:
    path = bench_path(suite, root)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f).get("rows", [])


# ---------------------------------------------------------------------------
# Gates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GateSpec:
    """One gated metric.

    ``direction``:
      * ``"higher"`` — bigger is better; fail if current <
        baseline·(1−rel_tol) or current < ``bound``.
      * ``"lower"``  — smaller is better; fail if current >
        baseline·(1+rel_tol) or current > ``bound``.
      * ``"exact"``  — must equal the baseline exactly (determinism
        invariants: greedy mismatches, compiled-signature counts).

    ``rel_tol`` absorbs machine-to-machine wall-clock noise; byte ratios
    and counts are deterministic and gate at 0.  ``bound`` is the absolute
    floor (higher) / ceiling (lower) that holds even against a degraded
    baseline."""
    match: Dict[str, object]
    key: str
    direction: str
    rel_tol: float = 0.0
    bound: Optional[float] = None

    def describe(self) -> str:
        sel = ",".join(f"{k}={v}" for k, v in self.match.items())
        return f"[{sel}].{self.key}"


GATES: Dict[str, List[GateSpec]] = {
    "kernels": [
        # fused decode must stay ahead of the naive full-dequant sdpa at
        # both cache lengths; wall-clock ratio, so tolerance is generous
        GateSpec({"name": "flash_decode_4k"}, "speedup", "higher",
                 rel_tol=0.40, bound=1.0),
        GateSpec({"name": "flash_decode_32k"}, "speedup", "higher",
                 rel_tol=0.40, bound=1.0),
    ],
    "serving": [
        GateSpec({"name": "serving_engine_vs_sequential"}, "speedup",
                 "higher", rel_tol=0.60, bound=2.0),
        GateSpec({"name": "serving_engine_vs_sequential"},
                 "greedy_mismatches", "exact"),
        GateSpec({"name": "serving_engine_vs_sequential"},
                 "serve_step_signatures", "exact"),
        # the paged pool's headline: strictly more requests in flight at
        # equal pool bytes — scheduling-deterministic, zero tolerance
        GateSpec({"name": "serving_paged_vs_contiguous"},
                 "concurrency_ratio", "higher", rel_tol=0.0, bound=1.5),
        GateSpec({"name": "serving_paged_vs_contiguous"},
                 "greedy_mismatches", "exact"),
        # CoW prefix sharing: cluster-skewed traffic must sustain at least
        # 2x the non-shared paged pool's peak concurrency at equal pool
        # bytes, bit-identically — scheduling-deterministic, zero tolerance
        GateSpec({"name": "serving_shared_prefix"},
                 "concurrency_ratio", "higher", rel_tol=0.0, bound=2.0),
        GateSpec({"name": "serving_shared_prefix"},
                 "greedy_mismatches", "exact"),
        GateSpec({"name": "serving_shared_prefix"},
                 "serve_step_signatures", "exact"),
        # Zipf fleet trace: admission outcomes are scheduling-deterministic
        # — the head cluster's replays must keep sharing, every request
        # must finish
        GateSpec({"name": "serving_zipf_trace"},
                 "share_hit_rate", "higher", rel_tol=0.0, bound=0.5),
        GateSpec({"name": "serving_zipf_trace"}, "unfinished", "exact"),
        # Serving chaos: under injected request faults (malformed prompts,
        # poisoned logits, unmeetable deadlines, arrival bursts) every
        # survivor must stay bit-identical to its solo greedy reference,
        # the journal must replay to zero unfinished requests, and the
        # fault paths must not add jit signatures. Absolute zero bounds —
        # "exact" would only compare against a (possibly wrong) baseline.
        GateSpec({"name": "serving_chaos"}, "greedy_mismatches", "lower",
                 rel_tol=0.0, bound=0.0),
        GateSpec({"name": "serving_chaos"}, "unfinished", "lower",
                 rel_tol=0.0, bound=0.0),
        GateSpec({"name": "serving_chaos"}, "unaccounted", "lower",
                 rel_tol=0.0, bound=0.0),
        GateSpec({"name": "serving_chaos"}, "serve_step_signatures",
                 "exact"),
        # load shedding must actually engage under the burst (observed
        # shed_rate 0.625 at seed 26; generous floor)
        GateSpec({"name": "serving_chaos"}, "shed_rate", "higher",
                 rel_tol=0.0, bound=0.25),
    ],
    "collectives": [
        # wire-byte fractions are exact chunk-plan arithmetic: zero tol
        GateSpec({"case": "ring", "wire": "int8"}, "bytes_vs_f32_psum",
                 "lower", rel_tol=0.0, bound=0.27),
        GateSpec({"case": "ring", "wire": "bf16"}, "bytes_vs_f32_psum",
                 "lower", rel_tol=0.0, bound=0.51),
        GateSpec({"row": "collectives_summary"}, "int8_under_027", "exact"),
        GateSpec({"row": "collectives_summary"}, "zero1_scatter_smaller",
                 "exact"),
    ],
}


def _find_row(rows: Sequence[dict], match: Dict[str, object]) -> Optional[dict]:
    for r in rows:
        if all(r.get(k) == v for k, v in match.items()):
            return r
    return None


def check_suite(suite: str, current_rows: Sequence[dict],
                baseline_rows: Optional[Sequence[dict]]) -> List[str]:
    """Gate ``current_rows`` against ``baseline_rows``; returns failure
    strings (empty == pass).  A missing baseline file/row only enforces the
    absolute bounds (first run of a new metric)."""
    failures: List[str] = []
    for g in GATES.get(suite, ()):
        row = _find_row(current_rows, g.match)
        if row is None or g.key not in row:
            failures.append(f"{suite}:{g.describe()}: metric missing "
                            f"from current run")
            continue
        cur = row[g.key]
        base_row = (_find_row(baseline_rows, g.match)
                    if baseline_rows is not None else None)
        base = base_row.get(g.key) if base_row else None
        if g.direction == "exact":
            if base is not None and cur != base:
                failures.append(f"{suite}:{g.describe()}: {cur!r} != "
                                f"baseline {base!r}")
            continue
        cur = float(cur)
        if g.direction == "higher":
            if g.bound is not None and cur < g.bound:
                failures.append(f"{suite}:{g.describe()}: {cur:.4g} below "
                                f"absolute floor {g.bound:.4g}")
            elif base is not None and cur < float(base) * (1 - g.rel_tol):
                failures.append(
                    f"{suite}:{g.describe()}: {cur:.4g} regressed vs "
                    f"baseline {float(base):.4g} (tol {g.rel_tol:.0%})")
        elif g.direction == "lower":
            if g.bound is not None and cur > g.bound:
                failures.append(f"{suite}:{g.describe()}: {cur:.4g} above "
                                f"absolute ceiling {g.bound:.4g}")
            elif base is not None and cur > float(base) * (1 + g.rel_tol):
                failures.append(
                    f"{suite}:{g.describe()}: {cur:.4g} regressed vs "
                    f"baseline {float(base):.4g} (tol {g.rel_tol:.0%})")
        else:
            raise ValueError(f"unknown gate direction {g.direction!r}")
    return failures


def gate_report(results: Dict[str, List[str]]) -> str:
    """Human-readable gate outcome (printed by ``benchmarks/run.py``)."""
    lines = []
    for suite in sorted(results):
        fails = results[suite]
        n = len(GATES.get(suite, ()))
        if fails:
            lines.append(f"# GATE {suite}: FAIL ({len(fails)}/{n} metrics)")
            lines.extend(f"#   {f}" for f in fails)
        else:
            lines.append(f"# GATE {suite}: ok ({n} metrics)")
    return "\n".join(lines)
