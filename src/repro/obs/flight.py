"""Crash-dump flight recorder: the last N events, always, for ~nothing.

Production serving debugging has a chicken-and-egg problem: the full
tracer is off (``REPRO_TRACE=0``) precisely in the long-running deployments
where a park-storm, an eviction cascade, or a crash most needs a timeline.
The flight recorder closes it: a fixed-size ring buffer that passively
retains the most recent span/instant/counter events *even when the tracer
is disabled*, at the cost of one tuple append per event (no dict build, no
lock, no JSON until a dump is actually requested — measured alongside the
no-op path in ``tests/test_obs.py``).

Dump triggers (all no-ops unless ``REPRO_FLIGHT_OUT=<path>.json`` names a
destination):

  * **atexit** — the tail of every run survives as a post-mortem.
  * **unhandled exception** — a chaining ``sys.excepthook`` writes the
    dump *before* the traceback prints, with the exception in
    ``metadata.reason``.
  * **engine distress** — ``serve/engine.py`` calls :func:`maybe_dump` on
    livelock-breaking displacement (park-storm victim selection) and on
    recompute eviction, so the steps leading up to pool pressure are on
    disk the moment it happens.

The dump is ordinary Chrome trace-event JSON (same schema as
``Tracer.dump`` — Perfetto opens it directly) with
``metadata.flight_recorder`` describing capacity/retained/dropped counts.

Knobs: ``REPRO_FLIGHT=0`` disables recording entirely (restores the pure
no-op disabled-tracer path); ``REPRO_FLIGHT_CAP`` sizes the ring (default
4096 events); ``REPRO_FLIGHT_OUT`` arms the auto-dump triggers.
"""

from __future__ import annotations

import atexit
import json
import os
import sys
import threading
import time
from collections import deque
from typing import Optional

__all__ = ["FlightRecorder", "get_flight", "flight_enabled", "maybe_dump"]


def flight_enabled() -> bool:
    """Recording is on by default (read per call like every REPRO_ flag);
    ``REPRO_FLIGHT=0`` disables it."""
    return os.environ.get("REPRO_FLIGHT", "1") != "0"


def _flight_cap() -> int:
    return int(os.environ.get("REPRO_FLIGHT_CAP", "4096"))


class FlightRecorder:
    """Fixed-size ring of compact event tuples; see module docstring.

    Events are ``(ph, name, cat, t0, dur, track, args)`` with ``t0`` a raw
    ``time.perf_counter()`` stamp — conversion to Chrome-trace microseconds
    and track→tid allocation happen only at dump time, so steady-state cost
    is one deque append (appends are GIL-atomic; no lock taken)."""

    def __init__(self, capacity: int = 0):
        self.capacity = capacity or _flight_cap()
        self._buf: deque = deque(maxlen=self.capacity)
        self._epoch = time.perf_counter()
        self.recorded = 0                     # total ever, incl. overwritten

    def record(self, ph: str, name: str, cat: str, t0: float,
               dur: float = 0.0, track: Optional[str] = None,
               args: Optional[dict] = None) -> None:
        self._buf.append((ph, name, cat, t0, dur, track, args))
        self.recorded += 1

    def __len__(self) -> int:
        return len(self._buf)

    def reset(self) -> None:
        self._buf.clear()
        self.recorded = 0
        self._epoch = time.perf_counter()

    # -- export --------------------------------------------------------------

    def to_chrome_trace(self, reason: str = "") -> dict:
        """Build the Chrome trace-event document from the retained tail.
        Thread names come from the recorded virtual tracks (``None`` events
        land on tid 1, "flight")."""
        events = list(self._buf)              # snapshot (GIL-atomic copy)
        tids = {None: 1}
        out = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": 1,
                "args": {"name": "flight"}}]
        for ph, name, cat, t0, dur, track, args in events:
            tid = tids.get(track)
            if tid is None:
                tid = tids[track] = len(tids) + 1
                out.append({"name": "thread_name", "ph": "M", "pid": 0,
                            "tid": tid, "args": {"name": track}})
            ev = {"name": name, "cat": cat or "repro", "ph": ph,
                  "ts": (t0 - self._epoch) * 1e6, "pid": 0, "tid": tid,
                  "args": args or {}}
            if ph == "X":
                ev["dur"] = max(dur * 1e6, 0.0)
            elif ph == "i":
                ev["s"] = "t"
            out.append(ev)
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "metadata": {
                "tool": "repro.obs.flight",
                "flight_recorder": {
                    "capacity": self.capacity,
                    "retained": len(events),
                    "recorded": self.recorded,
                    "dropped": max(self.recorded - len(events), 0),
                },
                **({"reason": reason} if reason else {}),
            },
        }

    def dump(self, path: str, reason: str = "") -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(reason), f)
        return path


_FLIGHT = FlightRecorder()
_dump_lock = threading.Lock()


def get_flight() -> FlightRecorder:
    return _FLIGHT


def maybe_dump(reason: str) -> Optional[str]:
    """Write the post-mortem dump if ``REPRO_FLIGHT_OUT`` is armed (no-op
    otherwise — the engine calls this on every distress event).  Later
    dumps overwrite earlier ones: the file is always the view at the most
    recent trigger."""
    out = os.environ.get("REPRO_FLIGHT_OUT")
    if not out or not len(_FLIGHT):
        return None
    with _dump_lock:
        try:
            return _FLIGHT.dump(out, reason)
        except OSError:                        # pragma: no cover - disk full
            return None


@atexit.register
def _dump_at_exit() -> None:                   # pragma: no cover - atexit
    maybe_dump("atexit")


_prev_excepthook = sys.excepthook


def _flight_excepthook(exc_type, exc, tb):     # pragma: no cover - crash path
    maybe_dump(f"exception: {exc_type.__name__}: {exc}")
    _prev_excepthook(exc_type, exc, tb)


sys.excepthook = _flight_excepthook
