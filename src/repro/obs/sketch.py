"""Mergeable streaming quantile sketches (DDSketch-style log buckets).

The reservoir :class:`~repro.obs.trace.Histogram` is the right tool for one
process watching one stream, but it cannot AGGREGATE: merging two
reservoirs re-biases the sample, so fleet-scale questions ("p99 client fit
time across 10k simulated clients, per cluster and overall") were
unanswerable.  :class:`QuantileSketch` fixes that with the DDSketch
construction [Masson et al., VLDB'19]:

  * **Log-bucketed counts.**  A positive value ``v`` lands in bucket
    ``ceil(log_gamma(v))`` with ``gamma = (1 + a) / (1 - a)`` for relative
    accuracy ``a``; the bucket midpoint ``2·gamma^i / (gamma + 1)``
    reconstructs any quantile with *value-relative* error ≤ ``a``
    (documented guarantee: ``|q_est - q_true| <= a * |q_true|`` for
    nonzero quantiles, exact rank resolution at bucket granularity).
    Negative values mirror into their own bucket map; zeros count
    separately — the full real line is covered.
  * **Exact-small fallback.**  Up to ``exact_threshold`` samples the
    sketch keeps every value and quantiles match
    ``numpy.percentile(..., method="linear")`` bitwise — tiny streams
    (per-cluster ledgers with a handful of clients) pay no bucket error
    at all.  Crossing the threshold spills every retained value into the
    buckets, so the spill is order-independent.
  * **Associative, commutative ``merge()``.**  Bucket maps add counts;
    exact stores concatenate (spilling if the union crosses the
    threshold).  Because the spill quantizes each value independently,
    ``merge(a, b)`` has *identical* bucket content to a single sketch fed
    the concatenated stream — merged quantiles equal concatenated-stream
    quantiles exactly, which is what makes per-cluster → fleet roll-ups
    trustworthy (``tests/test_sketch.py`` holds the property).
  * **O(1) memory.**  Bucket count is bounded by ``max_buckets``; on
    overflow the lowest-magnitude buckets collapse into their neighbour
    (the DDSketch collapse rule), preserving the accuracy of the upper
    quantiles that matter for straggler detection.

``add_many(np.ndarray)`` ingests a vector in one numpy pass (1M samples in
~ms), and ``to_dict``/``from_dict`` round-trip the sketch through JSON so
``fleet.json`` ledgers can be merged across processes.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional

__all__ = ["QuantileSketch", "merge_all"]


class QuantileSketch:
    """Bounded, mergeable streaming quantile sketch; see module docstring.

    ``rel_acc`` is the value-relative accuracy ``a`` of bucket-mode
    quantiles; ``exact_threshold`` the sample count below which quantiles
    are exact; ``max_buckets`` bounds memory (per sign)."""

    __slots__ = ("rel_acc", "exact_threshold", "max_buckets", "count",
                 "total", "min", "max", "_gamma", "_lg", "_exact", "_pos",
                 "_neg", "_zero")

    def __init__(self, rel_acc: float = 0.01, exact_threshold: int = 128,
                 max_buckets: int = 2048):
        if not 0.0 < rel_acc < 1.0:
            raise ValueError(f"rel_acc must be in (0, 1): {rel_acc}")
        self.rel_acc = rel_acc
        self.exact_threshold = exact_threshold
        self.max_buckets = max_buckets
        self._gamma = (1.0 + rel_acc) / (1.0 - rel_acc)
        self._lg = math.log(self._gamma)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._exact: Optional[List[float]] = []   # None once spilled
        self._pos: Dict[int, int] = {}
        self._neg: Dict[int, int] = {}
        self._zero = 0

    # -- ingest --------------------------------------------------------------

    def _bucket(self, mag: float) -> int:
        return int(math.ceil(math.log(mag) / self._lg))

    def _bucket_value(self, idx: int) -> float:
        # bucket i covers (gamma^(i-1), gamma^i]; the midpoint reconstructs
        # any member within rel_acc
        return 2.0 * self._gamma ** idx / (self._gamma + 1.0)

    def _spill(self) -> None:
        """Move the exact store into buckets (order-independent: each value
        quantizes alone, so spilling now or at stream position k yields the
        same bucket content)."""
        vals, self._exact = self._exact, None
        for v in vals:
            self._bucket_add(v, 1)

    def _bucket_add(self, v: float, n: int) -> None:
        if v == 0.0:
            self._zero += n
        elif v > 0.0:
            i = self._bucket(v)
            self._pos[i] = self._pos.get(i, 0) + n
        else:
            i = self._bucket(-v)
            self._neg[i] = self._neg.get(i, 0) + n
        if len(self._pos) > self.max_buckets:
            self._collapse(self._pos)
        if len(self._neg) > self.max_buckets:
            self._collapse(self._neg)

    @staticmethod
    def _collapse(buckets: Dict[int, int]) -> None:
        """DDSketch collapse: fold the lowest bucket into its neighbour so
        upper quantiles (the straggler end) keep full accuracy."""
        lo = min(buckets)
        n = buckets.pop(lo)
        nxt = min(buckets)
        buckets[nxt] = buckets.get(nxt, 0) + n

    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if self._exact is not None:
            self._exact.append(v)
            if len(self._exact) > self.exact_threshold:
                self._spill()
        else:
            self._bucket_add(v, 1)

    def add_many(self, values) -> None:
        """Vectorized ingest of a 1-D array-like (one numpy pass for the
        bucket assignment — million-sample streams in milliseconds)."""
        import numpy as np
        vals = np.asarray(values, np.float64).reshape(-1)
        if vals.size == 0:
            return
        self.count += int(vals.size)
        self.total += float(vals.sum())
        self.min = min(self.min, float(vals.min()))
        self.max = max(self.max, float(vals.max()))
        if self._exact is not None:
            if len(self._exact) + vals.size <= self.exact_threshold:
                self._exact.extend(float(v) for v in vals)
                return
            self._spill()
        self._zero += int((vals == 0.0).sum())
        for sign, store in ((1.0, self._pos), (-1.0, self._neg)):
            part = vals[sign * vals > 0.0] * sign
            if part.size == 0:
                continue
            idx = np.ceil(np.log(part) / self._lg).astype(np.int64)
            uniq, cnt = np.unique(idx, return_counts=True)
            for i, n in zip(uniq, cnt):
                store[int(i)] = store.get(int(i), 0) + int(n)
            while len(store) > self.max_buckets:
                self._collapse(store)

    # -- merge ---------------------------------------------------------------

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other`` into ``self`` (in place; returns self).  Requires
        matching ``rel_acc`` — merging sketches of different resolutions
        would silently void the accuracy guarantee."""
        if abs(other.rel_acc - self.rel_acc) > 1e-12:
            raise ValueError(
                f"cannot merge sketches with different rel_acc: "
                f"{self.rel_acc} vs {other.rel_acc}")
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        if self._exact is not None and other._exact is not None and \
                len(self._exact) + len(other._exact) <= self.exact_threshold:
            self._exact.extend(other._exact)
            return self
        if self._exact is not None:
            self._spill()
        if other._exact is not None:
            for v in other._exact:
                self._bucket_add(v, 1)
        else:
            self._zero += other._zero
            for i, n in other._pos.items():
                self._pos[i] = self._pos.get(i, 0) + n
            for i, n in other._neg.items():
                self._neg[i] = self._neg.get(i, 0) + n
            while len(self._pos) > self.max_buckets:
                self._collapse(self._pos)
            while len(self._neg) > self.max_buckets:
                self._collapse(self._neg)
        return self

    def copy(self) -> "QuantileSketch":
        out = QuantileSketch(self.rel_acc, self.exact_threshold,
                             self.max_buckets)
        out.count, out.total = self.count, self.total
        out.min, out.max = self.min, self.max
        out._exact = None if self._exact is None else list(self._exact)
        out._pos, out._neg = dict(self._pos), dict(self._neg)
        out._zero = self._zero
        return out

    # -- quantiles -----------------------------------------------------------

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def is_exact(self) -> bool:
        return self._exact is not None

    @property
    def num_buckets(self) -> int:
        return len(self._pos) + len(self._neg)

    def quantile(self, q: float) -> float:
        """q in [0, 100].  Exact mode: numpy's linear interpolation.
        Bucket mode: the midpoint of the bucket holding rank
        ``q/100·(count−1)`` (value-relative error ≤ ``rel_acc``)."""
        if self.count == 0:
            return 0.0
        if self._exact is not None:
            xs = sorted(self._exact)
            pos = (q / 100.0) * (len(xs) - 1)
            lo = int(pos)
            hi = min(lo + 1, len(xs) - 1)
            frac = pos - lo
            return xs[lo] * (1.0 - frac) + xs[hi] * frac
        rank = (q / 100.0) * (self.count - 1)
        seen = 0
        # negatives descend from the most-negative value: iterate magnitude
        # buckets high -> low
        for i in sorted(self._neg, reverse=True):
            seen += self._neg[i]
            if seen > rank:
                return -self._bucket_value(i)
        seen += self._zero
        if seen > rank:
            return 0.0
        for i in sorted(self._pos):
            seen += self._pos[i]
            if seen > rank:
                return self._bucket_value(i)
        return self._bucket_value(max(self._pos)) if self._pos else 0.0

    # Histogram-compatible alias: Tracer.hist consumers call percentile()
    percentile = quantile

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(50),
            "p95": self.quantile(95),
            "p99": self.quantile(99),
            "rel_acc": self.rel_acc,
            "exact": self._exact is not None,
        }

    # -- serialization -------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "rel_acc": self.rel_acc,
            "exact_threshold": self.exact_threshold,
            "max_buckets": self.max_buckets,
            "count": self.count,
            "total": self.total,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "exact": self._exact,
            "pos": {str(k): v for k, v in self._pos.items()},
            "neg": {str(k): v for k, v in self._neg.items()},
            "zero": self._zero,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "QuantileSketch":
        out = cls(d["rel_acc"], d["exact_threshold"], d["max_buckets"])
        out.count = d["count"]
        out.total = d["total"]
        out.min = d["min"] if d["min"] is not None else float("inf")
        out.max = d["max"] if d["max"] is not None else float("-inf")
        out._exact = list(d["exact"]) if d["exact"] is not None else None
        out._pos = {int(k): v for k, v in d["pos"].items()}
        out._neg = {int(k): v for k, v in d["neg"].items()}
        out._zero = d["zero"]
        return out


def merge_all(sketches: Iterable[QuantileSketch]) -> QuantileSketch:
    """Merge an iterable of sketches into a fresh one (the per-cluster ->
    fleet roll-up).  Raises on an empty iterable only implicitly via the
    first sketch's parameters — pass at least one."""
    it = iter(sketches)
    try:
        first = next(it)
    except StopIteration:
        raise ValueError("merge_all needs at least one sketch") from None
    out = first.copy()
    for s in it:
        out.merge(s)
    return out
