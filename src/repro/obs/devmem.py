"""Device-memory watermarks and per-scope HLO cost attribution.

Two attribution gaps closed here:

  * **Where did the memory go?**  :func:`memory_snapshot` reads
    ``device.memory_stats()`` (bytes in use / peak / limit) where the
    backend exposes it, and falls back to live-buffer accounting
    (``jax.live_arrays()`` nbytes summed per device) on backends that
    don't (CPU).  :func:`watermark` samples a snapshot onto the tracer as
    a ``devmem`` counter track + gauges, and the trainer/engine call it at
    round and step boundaries.  :func:`peak_bytes` feeds
    ``bench_gate.provenance`` so committed BENCH rows carry the memory
    watermark alongside the speedups they claim.
  * **Which scope is the cost?**  PR 6 stamps ``jax.named_scope("obs.*")``
    around every kernel dispatch and ring hop; XLA threads those through
    compilation as ``metadata={op_name="jit(f)/.../obs.qlora_matmul/..."}``
    on each HLO op.  :func:`scope_costs` re-parses compiled HLO text with
    the scan-aware walk from ``launch/hlo_cost.py`` (trip-count-aware
    multiplicities, fusion-boundary byte semantics) and buckets FLOPs and
    bytes by the innermost ``obs.*`` path segment — so "what fraction of
    step FLOPs is flash attention vs the qLoRA matmul" is one dict lookup
    instead of an HLO spelunking session.

Everything here degrades gracefully: no device stats → live-buffer
fallback; no ``obs.*`` metadata in the module → costs land under
``"(unscoped)"``.
"""

from __future__ import annotations

import re
from typing import Dict, Optional

__all__ = ["memory_snapshot", "peak_bytes", "watermark", "scope_costs",
           "compiled_scope_costs"]

_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')
_SCOPE_RE = re.compile(r"(obs\.[\w\-]+)")

UNSCOPED = "(unscoped)"


# -- device memory watermarks -------------------------------------------------

def memory_snapshot(device=None) -> Dict[str, int]:
    """Best-effort memory stats for one device (default: first device).

    Returns ``{"bytes_in_use", "peak_bytes_in_use", "bytes_limit",
    "live_buffer_bytes", "live_buffers"}`` — zeros where the backend keeps
    quiet.  ``memory_stats()`` is authoritative when present (GPU/TPU);
    ``live_buffer_bytes`` is the fallback accounting (and a useful
    cross-check even when stats exist: stats include allocator slack,
    live buffers don't)."""
    import jax

    if device is None:
        device = jax.devices()[0]
    out = {"bytes_in_use": 0, "peak_bytes_in_use": 0, "bytes_limit": 0,
           "live_buffer_bytes": 0, "live_buffers": 0}
    stats = None
    try:
        stats = device.memory_stats()
    except Exception:       # backend without stats support
        stats = None
    if stats:
        out["bytes_in_use"] = int(stats.get("bytes_in_use", 0))
        out["peak_bytes_in_use"] = int(stats.get("peak_bytes_in_use", 0))
        out["bytes_limit"] = int(stats.get("bytes_limit", 0))
    try:
        for arr in jax.live_arrays():
            devs = getattr(arr, "devices", None)
            if devs is not None and device not in devs():
                continue
            out["live_buffer_bytes"] += int(arr.nbytes)
            out["live_buffers"] += 1
    except Exception:       # pragma: no cover - deleted-array races
        pass
    return out


def peak_bytes(device=None) -> int:
    """The provenance number: allocator peak when the backend tracks it,
    else the current live-buffer footprint (a lower bound, clearly labelled
    by ``bench_gate.provenance`` carrying the backend name alongside)."""
    snap = memory_snapshot(device)
    return snap["peak_bytes_in_use"] or snap["live_buffer_bytes"]


def watermark(tag: str, device=None) -> Dict[str, int]:
    """Sample a snapshot onto the tracer: one ``devmem`` counter-track
    point plus ``devmem.<tag>.*`` gauges (gauges keep the per-tag peak via
    the tracer's max semantics).  Returns the snapshot so call sites can
    also log it."""
    from repro import obs

    snap = memory_snapshot(device)
    in_use = snap["bytes_in_use"] or snap["live_buffer_bytes"]
    obs.counter_track("devmem", bytes_in_use=in_use,
                      live_buffers=snap["live_buffers"])
    obs.gauge(f"devmem.{tag}.bytes_in_use", float(in_use))
    if snap["peak_bytes_in_use"]:
        obs.gauge(f"devmem.{tag}.peak_bytes", float(snap["peak_bytes_in_use"]))
    return snap


# -- per-scope HLO cost attribution -------------------------------------------

def scope_costs(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Bucket trip-count-aware FLOPs/bytes by ``obs.*`` named scope.

    Reuses the ``launch/hlo_cost`` parser: same multiplicity walk (a scan
    body's ops count trip_count times), same byte semantics (fusion bodies
    contribute at their call boundary — a fusion op inherits the scope of
    its own ``op_name``).  Ops whose metadata carries no ``obs.*`` segment
    aggregate under ``"(unscoped)"``.  Scope key is the innermost ``obs.*``
    segment of the op_name path, so nested scopes attribute to the nearest
    annotation — the one a reader of the source would expect."""
    from collections import defaultdict

    from repro.launch import hlo_cost as hc

    comps, entry, types = hc.parse_module(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    fusion_bodies = set()
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                for cname, _ in op.callees:
                    fusion_bodies.add(cname)

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for cname in hc._topo_order(comps, entry):
        m = mult[cname]
        if m == 0 or cname not in comps:
            continue
        for op in comps[cname].ops:
            for callee, k in op.callees:
                if callee in comps:
                    mult[callee] += m * k

    out: Dict[str, Dict[str, float]] = {}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        in_fusion = cname in fusion_bodies
        for op in comp.ops:
            scope = _op_scope(op.raw)
            bucket = out.setdefault(
                scope, {"flops": 0.0, "bytes": 0.0, "ops": 0.0})
            bucket["ops"] += m
            if op.kind in ("dot", "dot-general"):
                bucket["flops"] += m * hc._dot_flops(op, types)
            if not in_fusion and op.kind not in hc._SKIP_BYTES_OPS:
                b = hc._type_bytes(op.result_type)
                for o in op.operands:
                    t = types.get(o)
                    if t:
                        b += hc._type_bytes(t)
                bucket["bytes"] += m * b
    return out


def _op_scope(raw_line: str) -> str:
    m = _OP_NAME_RE.search(raw_line)
    if not m:
        return UNSCOPED
    scopes = _SCOPE_RE.findall(m.group(1))
    return scopes[-1] if scopes else UNSCOPED


def compiled_scope_costs(compiled) -> Optional[Dict[str, Dict[str, float]]]:
    """Scope costs straight from a lowered-and-compiled function (the
    object ``jax.jit(f).lower(...).compile()`` returns).  ``None`` when the
    runtime won't hand back HLO text."""
    try:
        hlo = compiled.as_text()
    except Exception:
        return None
    return scope_costs(hlo)
