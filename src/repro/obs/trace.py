"""Host-side structured span tracer — the repo's one observability spine.

Everything the serving engine, the federated trainer, and the launchers
report flows through one process-global :class:`Tracer`:

  * **Spans** — nested, thread-safe wall-clock intervals.  ``span()`` is a
    context manager; ``add_span()`` records a retroactive interval (the
    engine stamps request-lifecycle phases from timestamps it already
    keeps).  Spans land as Chrome trace-event ``"X"`` (complete) events, so
    the dump opens directly in ``chrome://tracing`` or the Perfetto UI.
  * **Instants / counter tracks** — point events (``"i"``) and ``"C"``
    counter series (block-pool utilization, active lanes) that Perfetto
    renders as step charts above the span tracks.
  * **Counters / gauges / histograms** — host-side aggregates.  Histograms
    keep a bounded reservoir so p50/p95/p99 stay O(1) memory over
    million-token runs; below the reservoir capacity the percentiles are
    EXACT (same linear interpolation as ``numpy.percentile``).
  * **Device alignment** — ``span(..., device=True)`` additionally enters a
    ``jax.profiler.TraceAnnotation`` and ``step_span`` a
    ``StepTraceAnnotation``, so when a JAX profiler trace is captured the
    host spans line up with the XLA device timeline.  jax is imported
    lazily and optionally: this module itself is dependency-free.

``REPRO_TRACE=0`` turns every entry point into a no-op (one dict lookup +
an early return — sub-microsecond, measured by ``tests/test_obs.py``), so
instrumentation can stay in hot paths unconditionally.  ``REPRO_TRACE_OUT=
path.json`` dumps the default tracer's Chrome trace at interpreter exit;
launchers expose the same via ``--trace-out``.

Even with the tracer off, the **flight recorder** (``repro.obs.flight``)
passively retains the last N span/instant/counter events in a fixed ring —
one tuple append per event — so a crash or an engine distress signal can
still dump a post-mortem timeline.  ``REPRO_FLIGHT=0`` disables that too,
restoring the pure no-op path.

Unbounded streams that must AGGREGATE across processes/clients use
``hist(name, v, sketch=True)``: the sample lands in a mergeable
``repro.obs.sketch.QuantileSketch`` instead of the reservoir ``Histogram``
(reservoirs cannot merge without re-biasing; sketches merge associatively
— the fleet ledger's per-cluster -> fleet roll-up depends on it).

Virtual tracks: pass ``track="req:r0"`` to pin events to a named Perfetto
track (one per request, one per federated cluster, ...) instead of the
calling thread's track.
"""

from __future__ import annotations

import atexit
import json
import os
import random
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs import flight as _flight
from repro.obs.sketch import QuantileSketch

__all__ = [
    "Tracer", "Histogram", "get_tracer", "trace_enabled", "span",
    "add_span", "instant", "counter", "gauge", "hist", "counter_track",
    "step_span", "dump", "reset", "span_count",
]


def trace_enabled() -> bool:
    """Tracing is on by default; ``REPRO_TRACE=0`` compiles the whole
    subsystem down to no-ops (read per call like every REPRO_ flag)."""
    return os.environ.get("REPRO_TRACE", "1") != "0"


def _jax_profiler():
    """Optional jax.profiler handle — None when jax is unavailable, so the
    tracer itself stays zero-dependency."""
    try:
        from jax import profiler
        return profiler
    except Exception:                           # pragma: no cover
        return None


# ---------------------------------------------------------------------------
# Histogram with reservoir percentiles
# ---------------------------------------------------------------------------

class Histogram:
    """Streaming histogram: exact count/sum/min/max plus a bounded
    reservoir (Vitter's algorithm R, deterministic seed) for percentiles.

    Up to ``capacity`` samples the reservoir holds EVERY value, so
    ``percentile`` matches ``numpy.percentile(..., method="linear")``
    bitwise; past it the estimate is unbiased with O(1/sqrt(capacity))
    error.  Thread-safe under the owning tracer's lock (standalone use is
    single-thread)."""

    __slots__ = ("count", "total", "min", "max", "_res", "_cap", "_rng")

    def __init__(self, capacity: int = 4096, seed: int = 0x5EED):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._res: List[float] = []
        self._cap = capacity
        self._rng = random.Random(seed)

    def add(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._res) < self._cap:
            self._res.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self._cap:
                self._res[j] = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """q in [0, 100], linear interpolation over the reservoir (numpy's
        default method)."""
        if not self._res:
            return 0.0
        xs = sorted(self._res)
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared no-op context manager — the entire cost of a disabled span."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _FlightSpan:
    """Span surrogate for the disabled-tracer path: records nothing in the
    tracer, but stamps the interval into the flight recorder's ring (one
    tuple append) so post-mortem dumps have a timeline even under
    ``REPRO_TRACE=0``."""
    __slots__ = ("name", "cat", "track", "args", "t0")

    def __init__(self, name: str, cat: str, track: Optional[str],
                 args: Dict[str, Any]):
        self.name = name
        self.cat = cat
        self.track = track
        self.args = args

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t0 = self.t0
        _flight.get_flight().record("X", self.name, self.cat, t0,
                                    time.perf_counter() - t0, self.track,
                                    self.args)
        return False


class _Span:
    __slots__ = ("_tr", "name", "cat", "args", "device", "track", "t0",
                 "_ann")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 device: bool, track: Optional[str],
                 args: Dict[str, Any]):
        self._tr = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.device = device
        self.track = track
        self._ann = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        if self.device:
            prof = _jax_profiler()
            if prof is not None:
                self._ann = prof.TraceAnnotation(self.name)
                self._ann.__enter__()
        return self

    def __exit__(self, *exc):
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tr._complete(self.name, self.cat, self.t0,
                           time.perf_counter(), self.track, self.args)
        return False


class _StepSpan(_Span):
    """Span + ``jax.profiler.StepTraceAnnotation`` — marks one training /
    engine step so XLA device traces group work per step."""
    __slots__ = ("step",)

    def __init__(self, tracer, name, step: int, args):
        super().__init__(tracer, name, "step", False, None, args)
        self.step = step

    def __enter__(self):
        self.t0 = time.perf_counter()
        prof = _jax_profiler()
        if prof is not None:
            self._ann = prof.StepTraceAnnotation(self.name,
                                                 step_num=self.step)
            self._ann.__enter__()
        return self


class Tracer:
    """Thread-safe structured tracer; see module docstring.

    One event buffer, bounded by ``max_events`` (overflow counted in
    ``dropped_events``, never raises).  Chrome-trace timestamps are
    microseconds relative to the tracer's epoch."""

    def __init__(self, max_events: int = 1 << 20):
        self._lock = threading.Lock()
        self._max_events = max_events
        self.reset()

    # -- lifecycle -----------------------------------------------------------

    def reset(self) -> None:
        with getattr(self, "_lock", threading.Lock()):
            self._epoch = time.perf_counter()
            self._events: List[dict] = []
            self._tracks: Dict[str, int] = {}   # virtual track name -> tid
            self._thread_tids: Dict[int, int] = {}
            self._next_tid = 1
            self.dropped_events = 0
            self.counters: Dict[str, float] = {}
            self.gauges: Dict[str, float] = {}
            self.hists: Dict[str, Histogram] = {}

    @property
    def enabled(self) -> bool:
        return trace_enabled()

    # -- track / tid plumbing ------------------------------------------------

    def _tid(self, track: Optional[str]) -> int:
        """tid for a virtual track name (allocating + emitting the
        thread_name metadata event on first use) or the calling thread."""
        if track is not None:
            tid = self._tracks.get(track)
            if tid is None:
                tid = self._next_tid = self._next_tid + 1
                self._tracks[track] = tid
                self._push({"name": "thread_name", "ph": "M", "pid": 0,
                            "tid": tid, "args": {"name": track}})
            return tid
        ident = threading.get_ident()
        tid = self._thread_tids.get(ident)
        if tid is None:
            tid = self._next_tid = self._next_tid + 1
            self._thread_tids[ident] = tid
            name = threading.current_thread().name
            self._push({"name": "thread_name", "ph": "M", "pid": 0,
                        "tid": tid, "args": {"name": name}})
        return tid

    def _push(self, ev: dict) -> None:
        if len(self._events) >= self._max_events:
            self.dropped_events += 1
            return
        self._events.append(ev)

    def _us(self, t: float) -> float:
        return (t - self._epoch) * 1e6

    def _complete(self, name: str, cat: str, t0: float, t1: float,
                  track: Optional[str], args: Dict[str, Any]) -> None:
        with self._lock:
            self._push({"name": name, "cat": cat or "repro", "ph": "X",
                        "ts": self._us(t0),
                        "dur": max(self._us(t1) - self._us(t0), 0.0),
                        "pid": 0, "tid": self._tid(track),
                        "args": args or {}})
        if _flight.flight_enabled():
            _flight.get_flight().record("X", name, cat, t0, t1 - t0,
                                        track, args)

    # -- spans / events ------------------------------------------------------

    def span(self, name: str, cat: str = "", device: bool = False,
             track: Optional[str] = None, **args):
        """Context manager timing a live region.  ``device=True`` also
        enters a ``jax.profiler.TraceAnnotation`` so the host span lines up
        with the XLA device trace under the JAX profiler; ``track`` pins
        the span to a named virtual track instead of the calling thread."""
        if not trace_enabled():
            if _flight.flight_enabled():
                return _FlightSpan(name, cat, track, args)
            return _NULL_SPAN
        return _Span(self, name, cat, device, track, args)

    def step_span(self, name: str, step: int, **args):
        """``span`` + ``jax.profiler.StepTraceAnnotation(step_num=step)``."""
        if not trace_enabled():
            if _flight.flight_enabled():
                return _FlightSpan(name, "step", None, args)
            return _NULL_SPAN
        args.setdefault("step", step)
        return _StepSpan(self, name, step, args)

    def add_span(self, name: str, t0: float, t1: float, cat: str = "",
                 track: Optional[str] = None, **args) -> None:
        """Retroactive span from ``time.perf_counter()`` stamps already in
        hand (request lifecycle phases the engine times anyway)."""
        if not trace_enabled():
            if _flight.flight_enabled():
                _flight.get_flight().record("X", name, cat, t0, t1 - t0,
                                            track, args)
            return
        self._complete(name, cat, t0, t1, track, args)

    def instant(self, name: str, cat: str = "", track: Optional[str] = None,
                **args) -> None:
        if not trace_enabled():
            if _flight.flight_enabled():
                _flight.get_flight().record("i", name, cat,
                                            time.perf_counter(),
                                            track=track, args=args)
            return
        with self._lock:
            self._push({"name": name, "cat": cat or "repro", "ph": "i",
                        "ts": self._us(time.perf_counter()), "s": "t",
                        "pid": 0, "tid": self._tid(track),
                        "args": args or {}})
        if _flight.flight_enabled():
            _flight.get_flight().record("i", name, cat, time.perf_counter(),
                                        track=track, args=args)

    def counter_track(self, name: str, **series: float) -> None:
        """One ``"C"`` sample on the named counter track (Perfetto renders
        the series as a stacked step chart)."""
        traced = trace_enabled()
        if not traced and not _flight.flight_enabled():
            return
        series_f = {k: float(v) for k, v in series.items()}
        if _flight.flight_enabled():
            _flight.get_flight().record("C", name, "repro",
                                        time.perf_counter(), args=series_f)
        if not traced:
            return
        with self._lock:
            self._push({"name": name, "cat": "repro", "ph": "C",
                        "ts": self._us(time.perf_counter()), "pid": 0,
                        "args": series_f})

    # -- aggregates ----------------------------------------------------------

    def counter(self, name: str, value: float = 1.0) -> None:
        """Monotonic accumulator (wire bytes, events)."""
        if not trace_enabled():
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + float(value)

    def gauge(self, name: str, value: float) -> None:
        """Last-value-wins sample (residual norms, losses)."""
        if not trace_enabled():
            return
        with self._lock:
            self.gauges[name] = float(value)

    def hist(self, name: str, value: float, *, sketch: bool = False) -> None:
        """Histogram sample (latencies); percentiles via ``summary()``.

        ``sketch=True`` binds the name to a mergeable
        :class:`~repro.obs.sketch.QuantileSketch` instead of the reservoir
        ``Histogram`` — use it for unbounded streams that must aggregate
        across clients/processes (the first call for a name picks the
        representation; both expose ``add``/``percentile``/``summary``)."""
        if not trace_enabled():
            return
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = (QuantileSketch() if sketch
                                        else Histogram())
            h.add(value)

    def sketch(self, name: str) -> Optional[QuantileSketch]:
        """The sketch bound to ``name`` by ``hist(..., sketch=True)``, or
        None (absent, or reservoir-bound)."""
        h = self.hists.get(name)
        return h if isinstance(h, QuantileSketch) else None

    # -- inspection / export -------------------------------------------------

    def events(self, name: Optional[str] = None) -> List[dict]:
        with self._lock:
            evs = list(self._events)
        if name is None:
            return evs
        return [e for e in evs if e["name"] == name]

    def span_count(self, name: str) -> int:
        """Number of completed ``"X"`` spans with this name — the
        trace-validity checks key off this (one ``req.lifecycle`` span per
        finished request, and so on)."""
        return sum(1 for e in self.events(name) if e["ph"] == "X")

    def summary(self) -> dict:
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "hists": {k: h.summary() for k, h in self.hists.items()},
                "events": len(self._events),
                "dropped_events": self.dropped_events,
            }

    def to_chrome_trace(self, provenance: Optional[dict] = None) -> dict:
        """The Chrome trace-event JSON object (``chrome://tracing`` /
        Perfetto UI both open it).  Aggregates ride in ``metadata`` so one
        artifact carries the whole observability picture."""
        with self._lock:
            events = list(self._events)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "metadata": {
                "tool": "repro.obs",
                "summary": self.summary(),
                **({"provenance": provenance} if provenance else {}),
            },
        }

    def dump(self, path: str, provenance: Optional[dict] = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(provenance), f)
        return path


# ---------------------------------------------------------------------------
# Process-global default tracer + module-level conveniences
# ---------------------------------------------------------------------------

_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def span(name: str, cat: str = "", device: bool = False, **args):
    return _TRACER.span(name, cat, device=device, **args)


def step_span(name: str, step: int, **args):
    return _TRACER.step_span(name, step, **args)


def add_span(name: str, t0: float, t1: float, **kw) -> None:
    _TRACER.add_span(name, t0, t1, **kw)


def instant(name: str, **kw) -> None:
    _TRACER.instant(name, **kw)


def counter(name: str, value: float = 1.0) -> None:
    _TRACER.counter(name, value)


def gauge(name: str, value: float) -> None:
    _TRACER.gauge(name, value)


def hist(name: str, value: float, *, sketch: bool = False) -> None:
    _TRACER.hist(name, value, sketch=sketch)


def counter_track(name: str, **series: float) -> None:
    _TRACER.counter_track(name, **series)


def span_count(name: str) -> int:
    return _TRACER.span_count(name)


def dump(path: str, provenance: Optional[dict] = None) -> str:
    return _TRACER.dump(path, provenance)


def reset() -> None:
    _TRACER.reset()


@atexit.register
def _dump_at_exit() -> None:                   # pragma: no cover - atexit
    out = os.environ.get("REPRO_TRACE_OUT")
    if out and trace_enabled() and _TRACER.events():
        try:
            _TRACER.dump(out)
        except OSError:
            pass
