"""Per-client federated round ledger with straggler detection.

PR 5/6 made the federated wire bytes "one number, four ways" (analytic
plan = kernel ledger = comm pricing = obs counters), but every one of
those views is an *aggregate*.  FedTime's efficiency claim (PAPER.md) is a
fleet-scale claim: it needs per-client accounting — who uploaded how many
bytes, how long each fit took, who is stale, who is slow — and the
ROADMAP's staleness-bounded async-aggregation tentpole is unbuildable
without exactly that telemetry.  :class:`FleetLedger` provides it:

  * ``fed_trainer`` emits one compact :class:`ClientRecord` per client fit
    (client id, cluster id, fit wall seconds, wire bytes, EF-residual
    norm, adapter-delta norm, round staleness = rounds since the client
    last participated).
  * Cluster-level aggregation rolls records up through mergeable
    :class:`~repro.obs.sketch.QuantileSketch` objects, so the per-cluster
    → fleet reduction is associative (the same property federated
    aggregation itself relies on).
  * Straggler flagging is two-rule: **p99-relative** (a fit at or above
    the cluster's p99 that is also ≥ ``p99_rel`` × the cluster median) and
    **MAD-based** (more than ``mad_k`` median-absolute-deviations above
    the cluster median — robust to the stragglers themselves skewing the
    scale).  Either rule flags; the reason string says which fired.
  * Export: ``to_trace()`` lays every fit out as per-cluster Perfetto
    tracks (``fleet:cluster{c}``) on the live tracer; ``dump()`` writes a
    standalone ``fleet.json`` (schema ``repro.fleet/v1``) whose
    per-cluster summed wire bytes are asserted in tests to equal
    ``comm.fedtime_round(...).bytes_up`` exactly — the "one number"
    invariant, now five ways.

The ledger is deliberately generic: ``extra`` metrics ride along on each
record, which is how the Zipf serving-trace benchmark reuses it for
share-hit / swap-rate accounting without a second ledger type.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.obs.sketch import QuantileSketch, merge_all

__all__ = ["ClientRecord", "FleetLedger"]

SCHEMA = "repro.fleet/v1"


@dataclass
class ClientRecord:
    """One client's participation in one federated round (compact: this is
    emitted once per client fit, potentially millions of times)."""

    round: int
    cluster: int
    client: int
    wall_s: float = 0.0
    wire_bytes: int = 0
    ef_norm: float = 0.0
    delta_norm: float = 0.0
    staleness: int = 0
    participated: bool = True
    t0: Optional[float] = None        # perf_counter at fit start (for trace)
    extra: Optional[dict] = None

    def to_dict(self) -> dict:
        d = {
            "round": self.round,
            "cluster": self.cluster,
            "client": self.client,
            "wall_s": self.wall_s,
            "wire_bytes": self.wire_bytes,
            "ef_norm": self.ef_norm,
            "delta_norm": self.delta_norm,
            "staleness": self.staleness,
            "participated": self.participated,
        }
        if self.extra:
            d["extra"] = self.extra
        return d


@dataclass
class FleetLedger:
    """Append-only ledger of :class:`ClientRecord` with sketch roll-ups and
    straggler flagging; see module docstring."""

    rel_acc: float = 0.01
    records: List[ClientRecord] = field(default_factory=list)
    _last_round: Dict[int, int] = field(default_factory=dict)

    def record(self, round: int, cluster: int, client: int, *,
               wall_s: float = 0.0, wire_bytes: int = 0,
               ef_norm: float = 0.0, delta_norm: float = 0.0,
               participated: bool = True, t0: Optional[float] = None,
               **extra) -> ClientRecord:
        """Append one record.  Staleness is derived here: rounds elapsed
        since this client last *participated* (0 on first sighting), and
        the participation clock only advances for participating fits —
        an excluded straggler keeps aging."""
        prev = self._last_round.get(client)
        staleness = 0 if prev is None else max(round - prev, 0)
        if participated:
            self._last_round[client] = round
        rec = ClientRecord(round, cluster, client, wall_s=wall_s,
                           wire_bytes=wire_bytes, ef_norm=ef_norm,
                           delta_norm=delta_norm, staleness=staleness,
                           participated=participated, t0=t0,
                           extra=extra or None)
        self.records.append(rec)
        return rec

    def __len__(self) -> int:
        return len(self.records)

    # -- aggregation ---------------------------------------------------------

    @property
    def clusters(self) -> List[int]:
        return sorted({r.cluster for r in self.records})

    def _values(self, cluster: Optional[int], name: str) -> List[float]:
        return [float(getattr(r, name)) for r in self.records
                if r.participated and (cluster is None or r.cluster == cluster)]

    def cluster_sketch(self, cluster: int, name: str = "wall_s"
                       ) -> QuantileSketch:
        """Quantile sketch of one field over one cluster's participating
        fits (the unit the fleet roll-up merges)."""
        s = QuantileSketch(rel_acc=self.rel_acc)
        s.add_many(self._values(cluster, name))
        return s

    def fleet_sketch(self, name: str = "wall_s") -> QuantileSketch:
        """Fleet-wide sketch = merge of the per-cluster sketches — the
        associativity of :meth:`QuantileSketch.merge` is what makes this
        equal a sketch of the concatenated stream."""
        cs = [self.cluster_sketch(c, name) for c in self.clusters]
        if not cs:
            return QuantileSketch(rel_acc=self.rel_acc)
        return merge_all(cs)

    def wire_bytes_by_cluster(self, round: Optional[int] = None
                              ) -> Dict[int, int]:
        """Summed uploaded wire bytes per cluster (optionally one round).
        This is the number tests pin against ``comm.fedtime_round``."""
        out: Dict[int, int] = {}
        for r in self.records:
            if not r.participated or (round is not None and r.round != round):
                continue
            out[r.cluster] = out.get(r.cluster, 0) + r.wire_bytes
        return out

    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes_by_cluster().values())

    # -- straggler / outlier flagging ---------------------------------------

    def stragglers(self, name: str = "wall_s", *, p99_rel: float = 2.0,
                   mad_k: float = 5.0) -> List[Tuple[ClientRecord, str]]:
        """Flag outlier fits per cluster.  Two rules, either fires:

        * ``p99``: value ≥ cluster p99 **and** ≥ ``p99_rel`` × cluster
          median (the second clause stops homogeneous clusters from
          flagging their own fastest tail).
        * ``mad``: value > median + ``mad_k`` × MAD (median absolute
          deviation — robust: the stragglers being flagged cannot inflate
          the scale estimate the way they would a stddev).

        Returns ``(record, reason)`` pairs; reason is ``"p99"``, ``"mad"``
        or ``"p99+mad"``."""
        flagged: List[Tuple[ClientRecord, str]] = []
        for c in self.clusters:
            vals = sorted(self._values(c, name))
            if len(vals) < 4:          # too few fits to call anything an outlier
                continue
            mid = vals[len(vals) // 2]
            mad = sorted(abs(v - mid) for v in vals)[len(vals) // 2]
            p99 = self.cluster_sketch(c, name).quantile(99)
            for r in self.records:
                if r.cluster != c or not r.participated:
                    continue
                v = float(getattr(r, name))
                reasons = []
                if v >= p99 and mid > 0 and v >= p99_rel * mid:
                    reasons.append("p99")
                if mad > 0 and v > mid + mad_k * mad:
                    reasons.append("mad")
                if reasons:
                    flagged.append((r, "+".join(reasons)))
        return flagged

    # -- export --------------------------------------------------------------

    def rejections_by_reason(self, cluster: Optional[int] = None
                             ) -> Dict[str, int]:
        """Histogram of exclusion reasons (``reason=`` extra on
        non-participating records: crash/hang/deadline/corrupt/byzantine/
        stale/...) — the audit trail of the fault-tolerant round loop."""
        out: Dict[str, int] = {}
        for r in self.records:
            if r.participated or (cluster is not None
                                  and r.cluster != cluster):
                continue
            why = (r.extra or {}).get("reason", "unknown")
            out[why] = out.get(why, 0) + 1
        return out

    def to_json(self) -> dict:
        per_cluster = {}
        for c in self.clusters:
            per_cluster[str(c)] = {
                "clients": len({r.client for r in self.records
                                if r.cluster == c}),
                "fits": sum(1 for r in self.records
                            if r.cluster == c and r.participated),
                "skipped": sum(1 for r in self.records
                               if r.cluster == c and not r.participated),
                "rejections": self.rejections_by_reason(c),
                "wire_bytes": self.wire_bytes_by_cluster().get(c, 0),
                "wall_s": self.cluster_sketch(c, "wall_s").summary(),
                "staleness": self.cluster_sketch(c, "staleness").summary(),
                "wall_s_sketch": self.cluster_sketch(c, "wall_s").to_dict(),
            }
        return {
            "schema": SCHEMA,
            "records": [r.to_dict() for r in self.records],
            "clusters": per_cluster,
            "fleet": {
                "wire_bytes": self.total_wire_bytes(),
                "wall_s": self.fleet_sketch("wall_s").summary(),
                "stragglers": [
                    {"round": r.round, "cluster": r.cluster,
                     "client": r.client, "wall_s": r.wall_s,
                     "reason": why}
                    for r, why in self.stragglers()
                ],
            },
        }

    def dump(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    def to_trace(self) -> None:
        """Lay every recorded fit out on the live tracer as per-cluster
        Perfetto tracks (``fleet:cluster{c}``) — no-op when both the tracer
        and the flight recorder are off.  Skipped (non-participating) fits
        become instants so exclusion is visible on the timeline."""
        from repro import obs
        flagged = {id(r): why for r, why in self.stragglers()}
        for r in self.records:
            track = f"fleet:cluster{r.cluster}"
            if not r.participated:
                obs.instant(f"client{r.client}.skipped", cat="fleet",
                            track=track, round=r.round,
                            staleness=r.staleness,
                            reason=(r.extra or {}).get("reason"))
                continue
            if r.t0 is None:
                continue
            args = {"round": r.round, "wire_bytes": r.wire_bytes,
                    "staleness": r.staleness, "ef_norm": r.ef_norm,
                    "delta_norm": r.delta_norm}
            why = flagged.get(id(r))
            if why:
                args["straggler"] = why
            obs.add_span(f"client{r.client}.fit", r.t0, r.t0 + r.wall_s,
                         cat="fleet", track=track, **args)
