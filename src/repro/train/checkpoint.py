"""Checkpointing: msgpack + zstd of flattened parameter pytrees (no orbax).

Arrays are stored as (dtype, shape, raw bytes); tree structure as the
key-path list — restores bit-exactly, works for any of the framework's
pytrees (params, adapters, optimizer states, caches).
"""

from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                     # optional: fall back to uncompressed
    import zstandard
except ImportError:                      # pragma: no cover - env dependent
    zstandard = None

# 4-byte magic distinguishing compressed from raw checkpoints, so files stay
# readable across environments with/without zstandard installed
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _flatten_with_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out += _flatten_with_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten_with_paths(v, f"{prefix}/[{i}]")
    else:
        out.append((prefix, tree))
    return out


def save(path: str, tree: Any) -> int:
    """Returns bytes written."""
    leaves = _flatten_with_paths(tree)
    payload = {}
    for p, leaf in leaves:
        arr = np.asarray(leaf)
        payload[p] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = (zstandard.ZstdCompressor(level=3).compress(raw)
            if zstandard is not None else raw)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(comp)
    return len(comp)


def load(path: str, like: Any = None) -> Any:
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                f"{path} is zstd-compressed but zstandard is not installed")
        raw = zstandard.ZstdDecompressor().decompress(raw)
    payload = msgpack.unpackb(raw, raw=False)
    arrays = {p: jnp.asarray(np.frombuffer(v["data"],
                                           dtype=np.dtype(v["dtype"]))
                             .reshape(v["shape"]))
              for p, v in payload.items()}
    if like is None:
        return _unflatten(arrays)
    flat = _flatten_with_paths(like)
    leaves = [arrays[p] for p, _ in flat]
    paths = [p for p, _ in flat]
    return _rebuild(like, dict(zip(paths, leaves)))


def _unflatten(arrays: dict) -> dict:
    root: dict = {}
    for path, arr in arrays.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return root


def _rebuild(like, mapping, prefix=""):
    if isinstance(like, dict):
        return {k: _rebuild(v, mapping, f"{prefix}/{k}")
                for k, v in like.items()}
    if isinstance(like, (list, tuple)):
        t = type(like)
        return t(_rebuild(v, mapping, f"{prefix}/[{i}]")
                 for i, v in enumerate(like))
    return mapping[prefix]
