"""Checkpointing: msgpack + zstd of flattened parameter pytrees (no orbax).

Arrays are stored as (dtype, shape, raw bytes); tree structure as the
key-path list — restores bit-exactly, works for any of the framework's
pytrees (params, adapters, optimizer states, caches).

Crash safety: ``save`` writes to a same-directory temp file, flushes +
fsyncs it, then atomically renames over the destination — a kill-9 at any
instant leaves either the previous complete checkpoint or the new one,
never a torn file (this is what the federated round-state snapshots in
``repro.fault.snapshot`` rely on).  Every new checkpoint carries a
20-byte header (magic + payload length + CRC32); ``load`` verifies both
and refuses truncated or corrupt files with a clear error instead of
handing back a silently wrong tree.  Headerless files from older
checkpoints (zstd- or raw-msgpack-first) still load.
"""

from __future__ import annotations

import os
import struct
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                     # optional: fall back to uncompressed
    import zstandard
except ImportError:                      # pragma: no cover - env dependent
    zstandard = None

# 4-byte magic distinguishing compressed from raw checkpoints, so files stay
# readable across environments with/without zstandard installed
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

# integrity header: magic + u64 payload length + u32 CRC32 of the payload
_HEADER_MAGIC = b"RPCKPT01"
_HEADER_FMT = "<8sQI"
_HEADER_LEN = struct.calcsize(_HEADER_FMT)


def _flatten_with_paths(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out += _flatten_with_paths(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten_with_paths(v, f"{prefix}/[{i}]")
    else:
        out.append((prefix, tree))
    return out


def save(path: str, tree: Any) -> int:
    """Atomically write ``tree`` to ``path``.  Returns bytes written."""
    leaves = _flatten_with_paths(tree)
    payload = {}
    for p, leaf in leaves:
        arr = np.asarray(leaf)
        payload[p] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    raw = msgpack.packb(payload, use_bin_type=True)
    comp = (zstandard.ZstdCompressor(level=3).compress(raw)
            if zstandard is not None else raw)
    header = struct.pack(_HEADER_FMT, _HEADER_MAGIC, len(comp),
                         zlib.crc32(comp))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # temp file in the SAME directory (os.replace must not cross devices),
    # fsync'd before the atomic rename so the data is durable when the new
    # name appears; best-effort directory fsync pins the rename itself
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(header)
            f.write(comp)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    try:                                  # pragma: no cover - fs dependent
        dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:
        pass
    return _HEADER_LEN + len(comp)


def load(path: str, like: Any = None) -> Any:
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:8] == _HEADER_MAGIC:
        if len(raw) < _HEADER_LEN:
            raise ValueError(
                f"truncated checkpoint {path}: {len(raw)} bytes is shorter "
                f"than the {_HEADER_LEN}-byte header — the file was cut off "
                "mid-write")
        _, length, crc = struct.unpack(_HEADER_FMT, raw[:_HEADER_LEN])
        body = raw[_HEADER_LEN:]
        if len(body) != length:
            raise ValueError(
                f"truncated checkpoint {path}: header promises {length} "
                f"payload bytes, file has {len(body)} — the write was "
                "interrupted; restore from the previous snapshot")
        if zlib.crc32(body) != crc:
            raise ValueError(
                f"corrupt checkpoint {path}: payload CRC mismatch — the "
                "file was damaged after writing")
        raw = body
    if raw[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ImportError(
                f"{path} is zstd-compressed but zstandard is not installed")
        raw = zstandard.ZstdDecompressor().decompress(raw)
    try:
        payload = msgpack.unpackb(raw, raw=False)
    except Exception as e:
        raise ValueError(
            f"corrupt checkpoint {path}: not a msgpack payload ({e})") from e
    arrays = {p: jnp.asarray(np.frombuffer(v["data"],
                                           dtype=np.dtype(v["dtype"]))
                             .reshape(v["shape"]))
              for p, v in payload.items()}
    if like is None:
        return _unflatten(arrays)
    flat = _flatten_with_paths(like)
    leaves = [arrays[p] for p, _ in flat]
    paths = [p for p, _ in flat]
    return _rebuild(like, dict(zip(paths, leaves)))


def _unflatten(arrays: dict) -> dict:
    root: dict = {}
    for path, arr in arrays.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return root


def _rebuild(like, mapping, prefix=""):
    if isinstance(like, dict):
        return {k: _rebuild(v, mapping, f"{prefix}/{k}")
                for k, v in like.items()}
    if isinstance(like, (list, tuple)):
        t = type(like)
        return t(_rebuild(v, mapping, f"{prefix}/[{i}]")
                 for i, v in enumerate(like))
    return mapping[prefix]
