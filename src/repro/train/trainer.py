"""Centralized trainer — the paper's comparison point (Fig. 3 'centralized
LLaMA') and the generic single-host training loop used by examples."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedules import cosine_warmup


@dataclasses.dataclass
class TrainLog:
    step: int
    loss: float
    seconds: float


def fit(loss_fn: Callable, params, batch_iter, *, steps: int,
        lr: float = 1e-3, warmup: int = 10, mask=None,
        eval_fn: Optional[Callable] = None, eval_every: int = 50,
        progress: Optional[Callable[[str], None]] = None):
    """Generic jitted training loop.

    loss_fn(params, batch) -> scalar; batch_iter yields pytrees of np/jnp.
    Returns (params, List[TrainLog], eval_history).
    """
    opt = adamw_init(params)
    grad_fn = jax.value_and_grad(loss_fn)

    @jax.jit
    def step_fn(p, o, batch, i):
        l, g = grad_fn(p, batch)
        lr_i = cosine_warmup(i, base_lr=lr, warmup=warmup, total=steps)
        p, o = adamw_update(p, g, o, i + 1, lr=lr_i, mask=mask)
        return p, o, l

    logs: List[TrainLog] = []
    evals = []
    t0 = time.time()
    for i in range(steps):
        batch = next(batch_iter)
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt, l = step_fn(params, opt, batch, i)
        logs.append(TrainLog(i, float(l), time.time() - t0))
        if eval_fn is not None and (i + 1) % eval_every == 0:
            evals.append((i, eval_fn(params)))
        if progress and (i + 1) % max(steps // 10, 1) == 0:
            progress(f"step {i + 1}/{steps} loss={float(l):.4f}")
    return params, logs, evals


def evaluate_forecaster(forward_fn, params, x_test: np.ndarray,
                        y_test: np.ndarray, *, batch: int = 64):
    """MSE / MAE over a test window set (paper's Table 2/3 metrics)."""
    preds = []
    fwd = jax.jit(forward_fn)
    for i in range(0, len(x_test), batch):
        preds.append(np.asarray(fwd(params, jnp.asarray(x_test[i:i + batch]))))
    pred = np.concatenate(preds)[:len(y_test)]
    err = pred - y_test
    return {"mse": float(np.mean(err ** 2)),
            "mae": float(np.mean(np.abs(err)))}
