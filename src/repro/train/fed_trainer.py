"""Federated training orchestration — paper Algorithm 1 end-to-end.

Phases (paper §3.2):
  0. K-means clustering of clients on local-data statistics.
  1. Supervised fine-tuning (SFT), federated, instance-norm front end.
  2. DPO alignment on preference pairs (server-side, post-SFT).
  3. Forecasting fine-tuning, federated, RevIN front end.

Only LoRA adapters cross the "network"; every round's traffic is metered by
``repro.core.comm`` (C5) in the configured wire format.

Wire emulation (``REPRO_FED_WIRE``, or the ``wire=`` argument): each
client's uploaded adapter delta passes through
``repro.dist.fedcomm.quantize_update`` — the same int8/bf16 encode +
error-feedback residual the mesh ring collective uses — so Algorithm 1
aggregates exactly what the wire delivers, the residual is carried
per-client between rounds (quantization noise does not bias the paper's
aggregation), and ``comm.fedtime_round(..., wire=...)`` prices what was
actually sent.  The default f32 wire is the identity.

Fault tolerance (``repro.fault``): the round loop is deadline-bounded and
survives client churn.

  * ``fault_plan=`` injects deterministic faults (crash-before-upload,
    hang, transient-fail-then-retry with backoff, corrupt/NaN delta,
    byzantine-scaled delta, delay) on a virtual clock — no ``time.sleep``
    anywhere; the legacy ``slow_clients={id: seconds}`` kwarg is a thin
    shim over a delay-only plan.
  * ``deadline_s=`` cuts each (round, cluster) aggregation window after
    that many virtual seconds: the server aggregates the partial cohort
    with weights renormalized to sum to 1 over exactly the applied
    uploads (``ClusterServer.apply_deltas``), and a deadline-skipped
    client's EF residual carries to its next participation, so its
    quantization error is never lost.
  * Late uploads land in a server-side ``StalenessBuffer`` and apply at
    the cluster's next window down-weighted by ``staleness_decay**s``;
    at or beyond ``staleness_limit`` rounds they are rejected — bounded
    staleness, so the round clock is set by the deadline, not by the
    slowest client.
  * Every upload is validated before aggregation (``repro.fault.guard``):
    non-finite deltas reject as ``corrupt``, norm outliers as
    ``byzantine`` — zero NaN/corrupt deltas ever reach FedAdam.
  * ``secure_aggregation=True`` composes with dropout: masks are
    committed against the started cohort, and the server re-cancels the
    dropped clients' pairwise masks (``repro.core.secure_agg``) — exact,
    bit for bit, on the int8 secure wire (``wire="int8"``), approximate
    in f32.  Late uploads cannot buffer in secure mode (masks bind to
    their round's cohort); they count as dropouts.
  * ``snapshot_path=`` writes an atomic round-state snapshot after every
    (round, cluster) aggregation — adapters + FedAdam moments, EF
    residuals, staleness buffer, participation clock, RNG counters,
    virtual clock; ``resume=True`` restores it and continues the same
    round bit-identically after a kill-9 (deterministic timelines, i.e.
    ``fault_plan.base_fit_s`` set or no deadline).

Every rejection/retry/timeout/recovery emits through ``repro.obs``:
``fault.*`` / ``fed.reject`` / ``fed.deadline_miss`` instants,
``fed.rejected.<reason>`` counters, fleet-ledger ``reason`` fields, and
flight-recorder distress dumps when a round loses most of its cohort.

Per-round telemetry (``repro.obs``, ``REPRO_TRACE=0`` disables): each
(round, cluster) gets a ``fed.round`` span wrapping per-client
``fed.client_fit`` spans on a per-cluster Perfetto track; the quantized
wire's EF residual norm lands in per-client gauges + a
``fed.ef_residual_norm`` histogram (drift of carried quantization error),
the round-over-round aggregated-adapter movement in per-cluster
``fed.adapter_delta_norm.cluster<c>`` gauges + counter tracks (the
convergence signal heterogeneous-client work diagnoses stragglers
against), and the metered comm in ``fed.wire_bytes`` /
``fed.round_loss.cluster<c>``.

Fleet ledger (always on — one dataclass append per client fit): every fit
lands a :class:`repro.obs.fleet.ClientRecord` (wall time, wire bytes,
EF-residual norm, adapter-delta norm, staleness) in
``FedResult.fleet``; excluded clients are recorded with
``participated=False`` and a ``reason`` (crash/hang/deadline/corrupt/
byzantine/stale) so exclusion is auditable, and the participation clock
keeps aging them.  The ledger's per-cluster summed wire bytes equal
``comm.fedtime_round(...).bytes_up`` exactly, counting ONLY clients whose
upload actually arrived in that window — each contributes precisely
``comm.wire_payload_bytes(count_params(adapters), wire)``, the same
single source every other view of the number reads (the PR 5/6 "one
number" invariant, now five ways).  ``fleet_out=`` (or
``REPRO_FLEET_OUT``) writes the standalone ``fleet.json``.
"""

from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core import comm, dpo, fedtime
from repro.core.client import local_update
from repro.core.clustering import cluster_clients
from repro.core.lora import (FAMILY_TARGETS, attach_lora, lora_tree,
                             merge_lora, quantize_base, trainable_fraction)
from repro.core.server import BufferedDelta, ClusterServer, StalenessBuffer
from repro.data.federated import client_weights
from repro.fault import (Attempt, FaultPlan, VirtualClock, load_round_state,
                         save_round_state, validate_deltas)
from repro.optim.fedadam import fedavg


@dataclasses.dataclass
class RoundLog:
    round: int
    cluster: int
    train_loss: float
    comm: comm.RoundStats


@dataclasses.dataclass
class FedResult:
    adapters_per_cluster: list
    base_params: dict
    logs: List[RoundLog]
    assignments: np.ndarray
    trainable_frac: float
    fleet: Optional[obs.FleetLedger] = None

    def total_megabytes(self) -> float:
        return sum(l.comm.megabytes for l in self.logs)

    def params_for_cluster(self, c: int) -> dict:
        return merge_lora(self.base_params, self.adapters_per_cluster[c])


def _stack_batches(x: np.ndarray, y: np.ndarray, steps: int, batch: int,
                   seed: int) -> dict:
    rng = np.random.default_rng(seed)
    sel = rng.integers(0, len(x), (steps, batch))
    return {"x": jnp.asarray(x[sel]), "y": jnp.asarray(y[sel])}


def _tree_delta(new, old):
    return jax.tree.map(
        lambda a, g: a.astype(jnp.float32) - g.astype(jnp.float32), new, old)


def _flatten_tree(tree):
    leaves, tdef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    splits = np.cumsum([int(np.prod(s)) if s else 1 for s in shapes])[:-1]
    flat = np.concatenate([np.asarray(l, np.float32).reshape(-1)
                           for l in leaves])
    return flat, (tdef, shapes, splits)


def _unflatten_tree(flat, spec):
    tdef, shapes, splits = spec
    parts = np.split(np.asarray(flat, np.float32), splits)
    return jax.tree.unflatten(
        tdef, [jnp.asarray(p.reshape(s)) for p, s in zip(parts, shapes)])


# ---------------------------------------------------------------------------
# Round-state snapshot plumbing (repro.fault.snapshot)
# ---------------------------------------------------------------------------

def _write_snapshot(path, *, r, c, rounds, clock, rng, servers,
                    wire_residuals, ledger, logs, buffer):
    arrays = {
        "servers": {str(i): {"adapters": s.adapters,
                             "m": s.opt["m"], "v": s.opt["v"]}
                    for i, s in enumerate(servers)},
        "residuals": {str(k): v for k, v in wire_residuals.items()
                      if v is not None},
        "buffer": {str(i): e.delta for i, e in enumerate(buffer.entries)},
    }
    meta = {
        "round": r, "cluster": c, "rounds_total": rounds,
        "clock": clock.now(),
        "rng": rng.bit_generator.state,
        "server_rounds": [s.round for s in servers],
        "last_round": {str(k): v for k, v in ledger._last_round.items()},
        "records": [rec.to_dict() for rec in ledger.records],
        "logs": [[l.round, l.cluster, l.train_loss, l.comm.bytes_up,
                  l.comm.bytes_down, l.comm.messages, l.comm.time_s]
                 for l in logs],
        "buffer": [{"client": e.client, "cluster": e.cluster,
                    "origin_round": e.origin_round, "ready_at": e.ready_at,
                    "weight": e.weight, "loss": e.loss}
                   for e in buffer.entries],
    }
    save_round_state(path, arrays, meta)


def _restore_snapshot(path, *, servers, wire_residuals, ledger, logs,
                      buffer, rng, clock):
    meta, arrays = load_round_state(path)
    srv = arrays.get("servers", {})
    for i, s in enumerate(servers):
        sd = srv[str(i)]
        s.adapters = sd["adapters"]
        s.opt = {"m": sd["m"], "v": sd["v"]}
        s.round = int(meta["server_rounds"][i])
    wire_residuals.clear()
    wire_residuals.update({int(k): v
                           for k, v in arrays.get("residuals", {}).items()})
    ledger._last_round.update({int(k): int(v)
                               for k, v in meta["last_round"].items()})
    for d in meta["records"]:
        extra = d.pop("extra", None) or {}
        ledger.records.append(obs.ClientRecord(
            d["round"], d["cluster"], d["client"], wall_s=d["wall_s"],
            wire_bytes=d["wire_bytes"], ef_norm=d["ef_norm"],
            delta_norm=d["delta_norm"], staleness=d["staleness"],
            participated=d["participated"], extra=extra or None))
    for (r_, c_, loss, up, down, msgs, t) in meta["logs"]:
        logs.append(RoundLog(int(r_), int(c_), float(loss),
                             comm.RoundStats(int(up), int(down),
                                             int(msgs), float(t))))
    deltas = arrays.get("buffer", {})
    buffer.entries = [
        BufferedDelta(int(bm["client"]), int(bm["cluster"]),
                      int(bm["origin_round"]), float(bm["ready_at"]),
                      float(bm["weight"]), float(bm["loss"]),
                      deltas[str(i)])
        for i, bm in enumerate(meta["buffer"])]
    rng.bit_generator.state = meta["rng"]
    clock.advance_to(meta["clock"])
    return int(meta["round"]), int(meta["cluster"])


def federated_fit(cfg: ModelConfig, client_data, *, rounds: int = 5,
                  batch_size: int = 16, key=None, phase: str = "forecast",
                  loss_fn: Optional[Callable] = None,
                  base_params: Optional[dict] = None,
                  init_adapters: Optional[dict] = None,
                  straggler_prob: float = 0.0,
                  secure_aggregation: bool = False,
                  wire: Optional[str] = None,
                  slow_clients: Optional[Dict[int, float]] = None,
                  fault_plan: Optional[FaultPlan] = None,
                  deadline_s: Optional[float] = None,
                  staleness_limit: int = 2,
                  staleness_decay: float = 0.5,
                  byzantine_norm_k: float = 25.0,
                  snapshot_path: Optional[str] = None,
                  resume: bool = False,
                  fleet_out: Optional[str] = None,
                  progress: Optional[Callable[[str], None]] = None
                  ) -> FedResult:
    """client_data: list of (x (n,L,M), y (n,T,M)) per client."""
    from repro.core import secure_agg
    from repro.dist import fedcomm
    ft = cfg.fedtime
    wire = wire or comm.wire_format()
    key = key if key is not None else jax.random.PRNGKey(0)
    k_init, k_lora, k_cl = jax.random.split(key, 3)

    M = client_data[0][0].shape[-1]
    if base_params is None:
        base_params = fedtime.init(cfg, k_init, num_channels=M)
    targets = FAMILY_TARGETS["dense"]
    params = attach_lora(base_params, k_lora, rank=ft.lora_rank,
                         alpha=ft.lora_alpha, targets=targets)
    if ft.qlora:
        params = quantize_base(params, qblock=ft.qlora_block,
                               targets=targets)
    if init_adapters is not None:
        params = merge_lora(params, init_adapters)   # warm start (phase hand-off)
    frac = trainable_fraction(params)
    adapters0 = lora_tree(params)

    # --- step 0: K-means clustering (paper Algorithm 1, line 3) ---
    series = [np.asarray(x).reshape(-1, x.shape[-1] * x.shape[-2])[:256]
              for x, _ in client_data]
    assign, _, _ = cluster_clients(series, ft.num_clusters, key=k_cl)
    assign = np.asarray(assign)
    weights_all = client_weights(client_data)

    if loss_fn is None:
        def loss_fn(p, batch):  # noqa: F811
            return fedtime.loss(p, cfg, batch, phase=phase)

    # legacy slow_clients kwarg: a delay-only FaultPlan on the virtual
    # clock (no time.sleep — straggler tests run in milliseconds)
    plan = fault_plan
    if plan is None and slow_clients:
        plan = FaultPlan.from_slow_clients(slow_clients)

    servers = [ClusterServer(adapters0) for _ in range(ft.num_clusters)]
    logs: List[RoundLog] = []
    rng = np.random.default_rng(7)
    clock = VirtualClock()
    buffer = StalenessBuffer(limit=staleness_limit, decay=staleness_decay)
    wire_residuals: dict = {}     # client -> flat EF residual across rounds
    ledger = obs.FleetLedger()
    secure_int = secure_aggregation and wire == "int8"
    secure_step = secure_agg.default_step()
    _, flat_spec = _flatten_tree(adapters0)   # shared secure-wire layout
    # the per-client upload: same single source fedtime_round prices, so
    # the ledger's per-cluster sums match stats.bytes_up exactly
    client_wire_bytes = comm.wire_payload_bytes(
        comm.count_params(adapters0), wire)

    resume_after = None
    if resume:
        if not snapshot_path:
            raise ValueError("resume=True needs snapshot_path")
        resume_after = _restore_snapshot(
            snapshot_path, servers=servers, wire_residuals=wire_residuals,
            ledger=ledger, logs=logs, buffer=buffer, rng=rng, clock=clock)
        obs.instant("fed.resume", cat="fault", round=resume_after[0],
                    cluster=resume_after[1], clock=clock.now())

    for r in range(rounds):
        for c in range(ft.num_clusters):
            if resume_after is not None and (r, c) <= resume_after:
                continue                     # completed before the crash
            members = np.where(assign == c)[0]
            if len(members) == 0:
                continue
            take = min(ft.clients_per_round, len(members))
            sel = rng.choice(members, take, replace=False)
            # systems heterogeneity (paper §1): stragglers miss the round
            # deadline and are excluded from aggregation
            if straggler_prob > 0:
                alive = sel[rng.random(len(sel)) >= straggler_prob]
                if len(alive) == 0:
                    alive = sel[:1]               # quorum of one
            else:
                alive = sel
            alive_set = {int(s) for s in alive}
            for s in sel:
                if int(s) not in alive_set:       # missed the round deadline
                    ledger.record(r, c, int(s), participated=False,
                                  reason="sampled_out")

            t0 = clock.now()
            window_end = (t0 + deadline_s if deadline_s is not None
                          else math.inf)
            participants = [int(s) for s in alive]   # secure mask cohort
            w_alive = np.asarray([weights_all[s] for s in alive], np.float32)
            w_alive = w_alive / w_alive.sum()
            n_started = len(participants)
            round_span = obs.span("fed.round", track=f"fed:cluster{c}",
                                  round=r, cluster=c, clients=n_started,
                                  stragglers=int(take - n_started),
                                  deadline_s=deadline_s, wire=wire)
            round_span.__enter__()

            # -- client fits + wire encode (arrival on the virtual clock) --
            arrivals: List[dict] = []
            for idx, s in enumerate(alive):
                s = int(s)
                will_upload = plan.will_upload(s, r) if plan else True
                measured, ad, l_val = 0.0, None, float("nan")
                fit_t0 = time.perf_counter()
                if will_upload:
                    x, y = client_data[s]
                    batches = _stack_batches(x, y, ft.local_steps,
                                             batch_size,
                                             seed=1000 * r + s)
                    with obs.span("fed.client_fit",
                                  track=f"fed:cluster{c}", client=s,
                                  cluster=c, round=r, steps=ft.local_steps):
                        ad, l = local_update(loss_fn, params,
                                             servers[c].adapters,
                                             batches, steps=ft.local_steps)
                    measured = time.perf_counter() - fit_t0
                    l_val = float(l)
                att = (plan.attempt(s, r, measured) if plan
                       else Attempt(s, r, "ok", measured))
                for k in att.kinds:
                    obs.instant(f"fault.{k}", cat="fault",
                                track=f"fed:cluster{c}", client=s, round=r)
                if att.retries:
                    obs.counter("fed.retries", att.retries)
                if not att.uploads:       # crash-before-upload / hang
                    ledger.record(r, c, s, participated=False,
                                  reason=att.outcome)
                    continue

                delta = _tree_delta(ad, servers[c].adapters)
                ef, payload, new_res = 0.0, None, None
                if secure_int:
                    # shared-grid int8 EF encode + pairwise code masks:
                    # byzantine scale is clipped at the grid edge and
                    # NaN cannot cross an integer wire at all
                    if plan is not None:
                        delta = plan.mutate_delta(s, r, delta)
                    scale_i = n_started * float(w_alive[idx])
                    flat, _ = _flatten_tree(delta)
                    codes, new_res = secure_agg.secure_encode(
                        flat * scale_i, wire_residuals.get(s),
                        step=secure_step)
                    payload = secure_agg.mask_codes(
                        codes, client_id=s, participants=participants,
                        round_idx=r)
                    ef = float(np.linalg.norm(new_res))
                elif secure_aggregation:
                    # float-domain masks over the (optionally quantized)
                    # pre-scaled delta — the legacy secure path
                    scale_i = n_started * float(w_alive[idx])
                    scaled = jax.tree.map(lambda a: a * scale_i, delta)
                    if wire != "f32":
                        scaled, new_res = fedcomm.quantize_update(
                            scaled, wire_residuals.get(s), wire=wire)
                        ef = float(jnp.linalg.norm(new_res))
                    if plan is not None:
                        scaled = plan.mutate_delta(s, r, scaled)
                    payload = secure_agg.mask_update(
                        scaled, client_id=s, participants=participants,
                        round_idx=r)
                else:
                    dq = delta
                    if wire != "f32":
                        # the upload is the adapter DELTA through the
                        # wire: encode (+ carried residual); the server
                        # sees the dequantized view — what the network
                        # actually delivers
                        dq, new_res = fedcomm.quantize_update(
                            delta, wire_residuals.get(s), wire=wire)
                        ef = float(jnp.linalg.norm(new_res))
                    if plan is not None:
                        dq = plan.mutate_delta(s, r, dq)
                    payload = dq
                if ef and obs.enabled():
                    obs.gauge(f"fed.ef_residual_norm.client{s}", ef)
                if wire != "f32" and will_upload:
                    # carried EF residual norm: the quantization error
                    # this client drags into its next round
                    obs.hist("fed.ef_residual_norm", ef)
                arrivals.append({
                    "client": s, "arrival": t0 + att.virtual_s,
                    "virtual_s": att.virtual_s, "fit_t0": fit_t0,
                    "loss": l_val, "weight": float(weights_all[s]),
                    "payload": payload, "new_res": new_res, "ef": ef,
                })

            # -- deadline partition ---------------------------------------
            ontime = [a for a in arrivals if a["arrival"] <= window_end]
            late = [a for a in arrivals if a["arrival"] > window_end]
            for a in late:
                obs.instant("fed.deadline_miss", cat="fault",
                            track=f"fed:cluster{c}", client=a["client"],
                            round=r, arrival=a["arrival"])
                if secure_aggregation:
                    # masks bind to this round's cohort: a late masked
                    # upload is useless alone — it counts as a dropout
                    # and the recovery path below re-cancels its masks
                    ledger.record(r, c, a["client"], participated=False,
                                  reason="deadline")
                else:
                    buffer.add(BufferedDelta(
                        a["client"], c, r, a["arrival"], a["weight"],
                        a["loss"], a["payload"]))
                    obs.counter("fed.buffered", 1)
                    ledger.record(r, c, a["client"], participated=False,
                                  reason="deadline")
            # commit EF residuals for uploads that completed in-window
            # (a late non-secure upload still delivered its encoded
            # payload — its residual carries too; crash/hang never
            # encoded, so their residual is untouched, not lost)
            for a in (arrivals if not secure_aggregation else ontime):
                if a["new_res"] is not None:
                    wire_residuals[a["client"]] = a["new_res"]

            # -- aggregate: partial cohort + drained buffer ---------------
            applied_deltas, applied_w, applied_losses = [], [], []
            n_uploads = n_metered = 0
            if secure_aggregation:
                survivors = [a["client"] for a in ontime]
                dropped = [p for p in participants if p not in survivors]
                n_uploads = len(survivors)
                if dropped and survivors:
                    obs.instant("secureagg.recover", cat="fault", round=r,
                                cluster=c, dropped=len(dropped))
                if survivors:
                    if secure_int:
                        code_sum = secure_agg.unmask_sum(
                            [a["payload"] for a in ontime], survivors,
                            participants=participants, round_idx=r)
                        flat_sum = secure_agg.secure_decode_sum(
                            code_sum, step=secure_step)
                        total = _unflatten_tree(flat_sum, flat_spec)
                    else:
                        total = ontime[0]["payload"]
                        for a in ontime[1:]:
                            total = jax.tree.map(lambda x, y_: x + y_,
                                                 total, a["payload"])
                        if dropped:
                            rec = secure_agg.float_recovery_mask(
                                survivors, dropped, round_idx=r,
                                like=total)
                            total = jax.tree.map(lambda x, m: x - m,
                                                 total, rec)
                    denom = float(sum(
                        n_started * w_alive[participants.index(sv)]
                        for sv in survivors))
                    avg_delta = jax.tree.map(lambda x_: x_ / denom, total)
                    finite = all(bool(jnp.all(jnp.isfinite(l)))
                                 for l in jax.tree.leaves(avg_delta))
                    for a in ontime:
                        ledger.record(
                            r, c, a["client"],
                            participated=finite,
                            wall_s=a["virtual_s"],
                            wire_bytes=client_wire_bytes,
                            ef_norm=a["ef"], t0=a["fit_t0"],
                            **({} if finite
                               else {"reason": "corrupt_aggregate"}))
                    if finite:
                        applied_deltas, applied_w = [avg_delta], [1.0]
                        applied_losses = [a["loss"] for a in ontime]
                        n_metered = len(survivors)
                    else:
                        # only the float-masked wire can carry NaN; the
                        # int8 secure wire rejects this structurally
                        obs.instant("fed.reject", cat="fault", round=r,
                                    cluster=c, reason="corrupt_aggregate")
                        obs.counter("fed.rejected.corrupt_aggregate", 1)
            else:
                drained, stale_rejects = buffer.drain(c, r, window_end)
                for e, staleness in stale_rejects:
                    obs.instant("fed.reject", cat="fault",
                                track=f"fed:cluster{c}", client=e.client,
                                round=r, reason="stale",
                                staleness=staleness)
                    obs.counter("fed.rejected.stale", 1)
                    ledger.record(r, c, e.client, participated=False,
                                  wire_bytes=client_wire_bytes,
                                  reason="stale", staleness_rejected=True)
                # the apply path shares drain's boundary predicate: a
                # drained entry at staleness >= limit never reaches
                # apply_deltas, and the ledgered staleness is the same
                # floored value drain decayed by
                cohort = (
                    [(a["client"], a["payload"], a["weight"], a["loss"],
                      a["virtual_s"], a["fit_t0"], a["ef"], 0)
                     for a in ontime] +
                    [(e.client, e.delta, w, e.loss, 0.0, None, 0.0,
                      buffer.staleness_of(r, e.origin_round))
                     for e, w in drained
                     if not buffer.is_stale(
                         buffer.staleness_of(r, e.origin_round))])
                n_uploads = len(cohort) + len(stale_rejects)
                verdicts = validate_deltas([p for _, p, *_ in cohort],
                                           byz_k=byzantine_norm_k)
                for (cl, payload, w, l_val, virt, ft0, ef,
                     stale), (ok, why, nrm) in zip(cohort, verdicts):
                    if ok:
                        applied_deltas.append(payload)
                        applied_w.append(w)
                        n_metered += 1
                        if math.isfinite(l_val):
                            applied_losses.append(l_val)
                        ledger.record(r, c, cl, participated=True,
                                      wall_s=virt,
                                      wire_bytes=client_wire_bytes,
                                      ef_norm=ef, delta_norm=nrm, t0=ft0,
                                      **({"buffered_staleness": stale}
                                         if stale else {}))
                    else:
                        obs.instant("fed.reject", cat="fault",
                                    track=f"fed:cluster{c}", client=cl,
                                    round=r, reason=why, norm=nrm)
                        obs.counter(f"fed.rejected.{why}", 1)
                        ledger.record(r, c, cl, participated=False,
                                      wall_s=virt,
                                      wire_bytes=client_wire_bytes,
                                      reason=why)

            prev_adapters = (servers[c].adapters
                             if obs.enabled() and applied_deltas else None)
            if applied_deltas:
                with obs.span("fed.aggregate", track=f"fed:cluster{c}",
                              round=r, cluster=c,
                              clients=len(applied_deltas),
                              secure=secure_aggregation):
                    servers[c].apply_deltas(applied_deltas,
                                            np.asarray(applied_w,
                                                       np.float32))
            else:
                obs.instant("fed.round_empty", cat="fault", round=r,
                            cluster=c, uploads=n_uploads)
                obs.flight_maybe_dump(f"fed.round{r}.cluster{c}.empty")
            if applied_deltas and len(applied_deltas) * 2 < n_started:
                # distress: most of the cohort was lost this window
                obs.flight_maybe_dump(f"fed.round{r}.cluster{c}.partial")

            # comm is metered over the uploads whose bytes were actually
            # AGGREGATED this window — crashed/hung clients moved no
            # bytes, rejected uploads keep their per-record bytes for
            # audit but stay out of the "one number" sums, and a late
            # upload is priced in the window that applies it — so the
            # ledger's participated per-cluster sums equal Σ bytes_up
            # exactly, faults or not
            stats = comm.fedtime_round(
                params, clients_per_round=n_metered,
                num_clusters=ft.num_clusters, wire=wire)
            loss_r = (float(np.mean(applied_losses))
                      if applied_losses else float("nan"))
            if applied_deltas:
                logs.append(RoundLog(r, c, loss_r, stats))
            if obs.enabled() and prev_adapters is not None:
                # round-over-round adapter movement: ||agg_t - agg_{t-1}||
                # per cluster — flat-lining under a quantized wire with no
                # EF state is the classic correlated-bias symptom
                dn = float(jnp.sqrt(sum(
                    jnp.sum((a.astype(jnp.float32) -
                             b.astype(jnp.float32)) ** 2)
                    for a, b in zip(jax.tree.leaves(servers[c].adapters),
                                    jax.tree.leaves(prev_adapters)))))
                obs.gauge(f"fed.adapter_delta_norm.cluster{c}", dn)
                obs.hist("fed.adapter_delta_norm", dn)
                obs.gauge(f"fed.round_loss.cluster{c}", loss_r)
                obs.counter("fed.wire_bytes",
                            stats.bytes_up + stats.bytes_down)
                obs.counter_track(f"fed.cluster{c}", delta_norm=dn,
                                  loss=loss_r)
            # the deadline bounds the window even when stragglers ran
            # long; without one the slowest upload sets the pace
            finite_arrivals = [a["arrival"] for a in arrivals
                               if math.isfinite(a["arrival"])]
            clock.advance_to(window_end if deadline_s is not None
                             else max(finite_arrivals, default=t0))
            round_span.__exit__(None, None, None)
            if snapshot_path:
                _write_snapshot(snapshot_path, r=r, c=c, rounds=rounds,
                                clock=clock, rng=rng, servers=servers,
                                wire_residuals=wire_residuals,
                                ledger=ledger, logs=logs, buffer=buffer)
            if progress:
                progress(f"round {r} cluster {c}: "
                         f"loss={loss_r:.4f} "
                         f"comm={stats.megabytes:.2f}MB")
        if obs.enabled():
            # device-memory watermark at the round boundary (devmem track)
            obs.watermark(f"fed.round{r}")

    ledger.to_trace()
    fleet_out = fleet_out or os.environ.get("REPRO_FLEET_OUT")
    if fleet_out:
        ledger.dump(fleet_out)
    return FedResult([s.adapters for s in servers], params, logs,
                     assign, frac, fleet=ledger)


# ---------------------------------------------------------------------------
# Two-phase pipeline with DPO alignment (paper Fig. 1a)
# ---------------------------------------------------------------------------

def two_phase_fit(cfg: ModelConfig, client_data, *, rounds_sft: int = 2,
                  rounds_forecast: int = 3, dpo_steps: int = 20,
                  batch_size: int = 16, key=None, progress=None):
    """SFT (instance norm) -> DPO alignment -> forecasting FT (RevIN)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # Phase 1: supervised fine-tuning
    res_sft = federated_fit(cfg, client_data, rounds=rounds_sft,
                            batch_size=batch_size, key=k1, phase="sft",
                            progress=progress)

    # Global consolidation: average cluster adapters for the DPO stage
    global_ad = fedavg(res_sft.adapters_per_cluster,
                       jnp.ones(len(res_sft.adapters_per_cluster)))
    params = merge_lora(res_sft.base_params, global_ad)

    # Phase 1.5: DPO alignment (server-side, synthetic preference pairs)
    ref_params = params
    x_all = np.concatenate([x[:8] for x, _ in client_data])[:batch_size]
    y_all = np.concatenate([y[:8] for _, y in client_data])[:batch_size]
    pairs = dpo.make_preference_pairs(k2, jnp.asarray(x_all),
                                      jnp.asarray(y_all))

    def dpo_loss_fn(p, batch):
        return dpo.dpo_loss(p, ref_params, cfg, batch,
                            beta=cfg.fedtime.dpo_beta)

    pairs_stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (dpo_steps,) + a.shape), pairs)
    aligned_ad, dpo_l = local_update(dpo_loss_fn, params, global_ad,
                                     pairs_stacked, steps=dpo_steps,
                                     lr=1e-4)
    if progress:
        progress(f"DPO alignment loss={float(dpo_l):.4f}")
    params = merge_lora(params, aligned_ad)

    # Phase 2: forecasting fine-tuning (RevIN), warm-started with the
    # SFT+DPO adapters (paper: "transfer the updated weights of the
    # backbone model to the forecasting fine-tuning phase")
    res = federated_fit(cfg, client_data, rounds=rounds_forecast,
                        batch_size=batch_size, key=k3, phase="forecast",
                        base_params=res_sft.base_params,
                        init_adapters=lora_tree(params), progress=progress)
    res.logs = res_sft.logs + res.logs
    return res
