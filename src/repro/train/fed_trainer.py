"""Federated training orchestration — paper Algorithm 1 end-to-end.

Phases (paper §3.2):
  0. K-means clustering of clients on local-data statistics.
  1. Supervised fine-tuning (SFT), federated, instance-norm front end.
  2. DPO alignment on preference pairs (server-side, post-SFT).
  3. Forecasting fine-tuning, federated, RevIN front end.

Only LoRA adapters cross the "network"; every round's traffic is metered by
``repro.core.comm`` (C5) in the configured wire format.

Wire emulation (``REPRO_FED_WIRE``, or the ``wire=`` argument): each
client's uploaded adapter delta passes through
``repro.dist.fedcomm.quantize_update`` — the same int8/bf16 encode +
error-feedback residual the mesh ring collective uses — so Algorithm 1
aggregates exactly what the wire delivers, the residual is carried
per-client between rounds (quantization noise does not bias the paper's
aggregation), and ``comm.fedtime_round(..., wire=...)`` prices what was
actually sent.  The default f32 wire is the identity.

Per-round telemetry (``repro.obs``, ``REPRO_TRACE=0`` disables): each
(round, cluster) gets a ``fed.round`` span wrapping per-client
``fed.client_fit`` spans on a per-cluster Perfetto track; the quantized
wire's EF residual norm lands in per-client gauges + a
``fed.ef_residual_norm`` histogram (drift of carried quantization error),
the round-over-round aggregated-adapter movement in per-cluster
``fed.adapter_delta_norm.cluster<c>`` gauges + counter tracks (the
convergence signal heterogeneous-client work diagnoses stragglers
against), and the metered comm in ``fed.wire_bytes`` /
``fed.round_loss.cluster<c>``.

Fleet ledger (always on — one dataclass append per client fit): every fit
lands a :class:`repro.obs.fleet.ClientRecord` (wall time, wire bytes,
EF-residual norm, adapter-delta norm, staleness) in
``FedResult.fleet``; excluded stragglers are recorded with
``participated=False`` so exclusion is auditable.  The ledger's
per-cluster summed wire bytes equal ``comm.fedtime_round(...).bytes_up``
exactly — each participating client contributes precisely
``comm.wire_payload_bytes(count_params(adapters), wire)``, the same
single source every other view of the number reads (the PR 5/6 "one
number" invariant, now five ways).  ``fleet_out=`` (or
``REPRO_FLEET_OUT``) writes the standalone ``fleet.json``;
``slow_clients={id: seconds}`` injects deterministic slowdowns for
straggler-detection tests; device-memory watermarks are sampled at round
boundaries when tracing is on.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core import comm, dpo, fedtime
from repro.core.client import local_update
from repro.core.clustering import cluster_clients
from repro.core.lora import (FAMILY_TARGETS, attach_lora, lora_tree,
                             merge_lora, quantize_base, trainable_fraction)
from repro.core.server import ClusterServer
from repro.data.federated import client_weights
from repro.optim.fedadam import fedavg


@dataclasses.dataclass
class RoundLog:
    round: int
    cluster: int
    train_loss: float
    comm: comm.RoundStats


@dataclasses.dataclass
class FedResult:
    adapters_per_cluster: list
    base_params: dict
    logs: List[RoundLog]
    assignments: np.ndarray
    trainable_frac: float
    fleet: Optional[obs.FleetLedger] = None

    def total_megabytes(self) -> float:
        return sum(l.comm.megabytes for l in self.logs)

    def params_for_cluster(self, c: int) -> dict:
        return merge_lora(self.base_params, self.adapters_per_cluster[c])


def _stack_batches(x: np.ndarray, y: np.ndarray, steps: int, batch: int,
                   seed: int) -> dict:
    rng = np.random.default_rng(seed)
    sel = rng.integers(0, len(x), (steps, batch))
    return {"x": jnp.asarray(x[sel]), "y": jnp.asarray(y[sel])}


def federated_fit(cfg: ModelConfig, client_data, *, rounds: int = 5,
                  batch_size: int = 16, key=None, phase: str = "forecast",
                  loss_fn: Optional[Callable] = None,
                  base_params: Optional[dict] = None,
                  init_adapters: Optional[dict] = None,
                  straggler_prob: float = 0.0,
                  secure_aggregation: bool = False,
                  wire: Optional[str] = None,
                  slow_clients: Optional[Dict[int, float]] = None,
                  fleet_out: Optional[str] = None,
                  progress: Optional[Callable[[str], None]] = None
                  ) -> FedResult:
    """client_data: list of (x (n,L,M), y (n,T,M)) per client."""
    from repro.dist import fedcomm
    ft = cfg.fedtime
    wire = wire or comm.wire_format()
    key = key if key is not None else jax.random.PRNGKey(0)
    k_init, k_lora, k_cl = jax.random.split(key, 3)

    M = client_data[0][0].shape[-1]
    if base_params is None:
        base_params = fedtime.init(cfg, k_init, num_channels=M)
    targets = FAMILY_TARGETS["dense"]
    params = attach_lora(base_params, k_lora, rank=ft.lora_rank,
                         alpha=ft.lora_alpha, targets=targets)
    if ft.qlora:
        params = quantize_base(params, qblock=ft.qlora_block,
                               targets=targets)
    if init_adapters is not None:
        params = merge_lora(params, init_adapters)   # warm start (phase hand-off)
    frac = trainable_fraction(params)
    adapters0 = lora_tree(params)

    # --- step 0: K-means clustering (paper Algorithm 1, line 3) ---
    series = [np.asarray(x).reshape(-1, x.shape[-1] * x.shape[-2])[:256]
              for x, _ in client_data]
    assign, _, _ = cluster_clients(series, ft.num_clusters, key=k_cl)
    assign = np.asarray(assign)
    weights_all = client_weights(client_data)

    if loss_fn is None:
        def loss_fn(p, batch):  # noqa: F811
            return fedtime.loss(p, cfg, batch, phase=phase)

    servers = [ClusterServer(adapters0) for _ in range(ft.num_clusters)]
    logs: List[RoundLog] = []
    rng = np.random.default_rng(7)
    wire_residuals: dict = {}     # client -> flat EF residual across rounds
    ledger = obs.FleetLedger()
    # the per-client upload: same single source fedtime_round prices, so
    # the ledger's per-cluster sums match stats.bytes_up exactly
    client_wire_bytes = comm.wire_payload_bytes(
        comm.count_params(adapters0), wire)

    for r in range(rounds):
        for c in range(ft.num_clusters):
            members = np.where(assign == c)[0]
            if len(members) == 0:
                continue
            take = min(ft.clients_per_round, len(members))
            sel = rng.choice(members, take, replace=False)
            # systems heterogeneity (paper §1): stragglers miss the round
            # deadline and are excluded from aggregation
            if straggler_prob > 0:
                alive = sel[rng.random(len(sel)) >= straggler_prob]
                if len(alive) == 0:
                    alive = sel[:1]               # quorum of one
            else:
                alive = sel
            alive_set = {int(s) for s in alive}
            for s in sel:
                if int(s) not in alive_set:       # missed the round deadline
                    ledger.record(r, c, int(s), participated=False)
            round_span = obs.span("fed.round", track=f"fed:cluster{c}",
                                  round=r, cluster=c, clients=len(alive),
                                  stragglers=int(take - len(alive)),
                                  wire=wire)
            round_span.__enter__()
            updates, losses, ws = [], [], []
            for s in alive:
                x, y = client_data[s]
                batches = _stack_batches(x, y, ft.local_steps, batch_size,
                                         seed=1000 * r + int(s))
                fit_t0 = time.perf_counter()
                with obs.span("fed.client_fit", track=f"fed:cluster{c}",
                              client=int(s), cluster=c, round=r,
                              steps=ft.local_steps):
                    if slow_clients and int(s) in slow_clients:
                        # injected systems heterogeneity (tests pin the
                        # ledger's straggler flagging on these)
                        time.sleep(slow_clients[int(s)])
                    ad, l = local_update(loss_fn, params,
                                         servers[c].adapters,
                                         batches, steps=ft.local_steps)
                ef = 0.0
                if wire != "f32":
                    # the upload is the adapter DELTA through the wire:
                    # encode (+ carried residual), and hand the server the
                    # dequantized view — what the network actually delivers
                    delta = jax.tree.map(
                        lambda a, g: a.astype(jnp.float32) -
                        g.astype(jnp.float32), ad, servers[c].adapters)
                    dq, wire_residuals[int(s)] = fedcomm.quantize_update(
                        delta, wire_residuals.get(int(s)), wire=wire)
                    ad = jax.tree.map(
                        lambda g, d: g.astype(jnp.float32) + d,
                        servers[c].adapters, dq)
                    # carried EF residual norm: the quantization error
                    # this client drags into its next round
                    ef = float(jnp.linalg.norm(wire_residuals[int(s)]))
                    if obs.enabled():
                        obs.gauge(f"fed.ef_residual_norm.client{int(s)}",
                                  ef)
                        obs.hist("fed.ef_residual_norm", ef)
                client_dn = float(jnp.sqrt(sum(
                    jnp.sum((a.astype(jnp.float32) -
                             b.astype(jnp.float32)) ** 2)
                    for a, b in zip(jax.tree.leaves(ad),
                                    jax.tree.leaves(servers[c].adapters)))))
                ledger.record(r, c, int(s),
                              wall_s=time.perf_counter() - fit_t0,
                              wire_bytes=client_wire_bytes, ef_norm=ef,
                              delta_norm=client_dn, t0=fit_t0)
                updates.append(ad)
                losses.append(float(l))
                ws.append(weights_all[s])
            if secure_aggregation:
                # pairwise masking: server only sees the masked sum
                from repro.core.secure_agg import mask_update
                parts = [int(s) for s in alive]
                w = np.asarray(ws, np.float32)
                w = w / w.sum()
                n_alive = len(parts)
                # pre-scale by n·w_i so the server's (1/n)-normalized sum
                # recovers Σ w_i·u_i with masks cancelling exactly
                updates = [
                    mask_update(
                        jax.tree.map(lambda a, s=w[i] * n_alive: a * s, u),
                        client_id=parts[i], participants=parts, round_idx=r)
                    for i, u in enumerate(updates)]
                ws = np.ones(n_alive, np.float32)
            take = len(alive)
            prev_adapters = servers[c].adapters if obs.enabled() else None
            with obs.span("fed.aggregate", track=f"fed:cluster{c}",
                          round=r, cluster=c, clients=take,
                          secure=secure_aggregation):
                servers[c].aggregate(updates, np.asarray(ws))
            stats = comm.fedtime_round(
                params, clients_per_round=take,
                num_clusters=ft.num_clusters, wire=wire)
            loss_r = float(np.mean(losses))
            logs.append(RoundLog(r, c, loss_r, stats))
            if obs.enabled():
                # round-over-round adapter movement: ||agg_t - agg_{t-1}||
                # per cluster — flat-lining under a quantized wire with no
                # EF state is the classic correlated-bias symptom
                dn = float(jnp.sqrt(sum(
                    jnp.sum((a.astype(jnp.float32) -
                             b.astype(jnp.float32)) ** 2)
                    for a, b in zip(jax.tree.leaves(servers[c].adapters),
                                    jax.tree.leaves(prev_adapters)))))
                obs.gauge(f"fed.adapter_delta_norm.cluster{c}", dn)
                obs.hist("fed.adapter_delta_norm", dn)
                obs.gauge(f"fed.round_loss.cluster{c}", loss_r)
                obs.counter("fed.wire_bytes",
                            stats.bytes_up + stats.bytes_down)
                obs.counter_track(f"fed.cluster{c}", delta_norm=dn,
                                  loss=loss_r)
            round_span.__exit__(None, None, None)
            if progress:
                progress(f"round {r} cluster {c}: "
                         f"loss={np.mean(losses):.4f} "
                         f"comm={stats.megabytes:.2f}MB")
        if obs.enabled():
            # device-memory watermark at the round boundary (devmem track)
            obs.watermark(f"fed.round{r}")

    ledger.to_trace()
    fleet_out = fleet_out or os.environ.get("REPRO_FLEET_OUT")
    if fleet_out:
        ledger.dump(fleet_out)
    return FedResult([s.adapters for s in servers], params, logs,
                     assign, frac, fleet=ledger)


# ---------------------------------------------------------------------------
# Two-phase pipeline with DPO alignment (paper Fig. 1a)
# ---------------------------------------------------------------------------

def two_phase_fit(cfg: ModelConfig, client_data, *, rounds_sft: int = 2,
                  rounds_forecast: int = 3, dpo_steps: int = 20,
                  batch_size: int = 16, key=None, progress=None):
    """SFT (instance norm) -> DPO alignment -> forecasting FT (RevIN)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # Phase 1: supervised fine-tuning
    res_sft = federated_fit(cfg, client_data, rounds=rounds_sft,
                            batch_size=batch_size, key=k1, phase="sft",
                            progress=progress)

    # Global consolidation: average cluster adapters for the DPO stage
    global_ad = fedavg(res_sft.adapters_per_cluster,
                       jnp.ones(len(res_sft.adapters_per_cluster)))
    params = merge_lora(res_sft.base_params, global_ad)

    # Phase 1.5: DPO alignment (server-side, synthetic preference pairs)
    ref_params = params
    x_all = np.concatenate([x[:8] for x, _ in client_data])[:batch_size]
    y_all = np.concatenate([y[:8] for _, y in client_data])[:batch_size]
    pairs = dpo.make_preference_pairs(k2, jnp.asarray(x_all),
                                      jnp.asarray(y_all))

    def dpo_loss_fn(p, batch):
        return dpo.dpo_loss(p, ref_params, cfg, batch,
                            beta=cfg.fedtime.dpo_beta)

    pairs_stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (dpo_steps,) + a.shape), pairs)
    aligned_ad, dpo_l = local_update(dpo_loss_fn, params, global_ad,
                                     pairs_stacked, steps=dpo_steps,
                                     lr=1e-4)
    if progress:
        progress(f"DPO alignment loss={float(dpo_l):.4f}")
    params = merge_lora(params, aligned_ad)

    # Phase 2: forecasting fine-tuning (RevIN), warm-started with the
    # SFT+DPO adapters (paper: "transfer the updated weights of the
    # backbone model to the forecasting fine-tuning phase")
    res = federated_fit(cfg, client_data, rounds=rounds_forecast,
                        batch_size=batch_size, key=k3, phase="forecast",
                        base_params=res_sft.base_params,
                        init_adapters=lora_tree(params), progress=progress)
    res.logs = res_sft.logs + res.logs
    return res
