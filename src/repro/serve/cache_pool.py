"""Cache pools for the serving engine: contiguous per-slot lanes and the
paged block-KV pool.

``CachePool`` preallocates ``num_slots`` full-length cache lanes in one
donated pytree — a request is "placed" by writing its batch-1 prefill cache
into lane ``slot`` with a traced ``dynamic_update_slice``.  It works for
every servable family (attention rings AND SSM/hybrid state, bf16 and int8,
``REPRO_CACHE_SHARD=seq`` layouts) because it never looks inside the leaves:
``cache_batch_axes`` finds each leaf's batch axis structurally.

``PagedCachePool`` is the HBM-efficient layout for uniform attention-ring
families (dense/moe without local/global alternation): ONE donated block
pool of shape ``(L, n_blocks, block_size, Hk, dh)`` plus a host-side block
table ``(num_slots, blocks_per_slot)`` mapping each lane's logical ring
blocks to physical pool blocks.  A lane only holds the blocks its tokens
actually occupy — short requests stop reserving a full ``cache_len`` lane,
so at fixed pool bytes strictly more requests fit in flight.  Blocks are
granted on demand (`grant`) as decode crosses block boundaries and freed
wholesale at retirement; freshly granted blocks get their ``kv_pos``
invalidated on device (`reset_blocks`) so a previous owner's stale
positions can never leak through the ring-validity mask.  SSM/hybrid
families keep dense lanes behind the same engine-facing surface
(acquire/release/insert + block accounting).

Cache pytrees stack layers OUTSIDE the batch axis (``(L, B, S, Hk, dh)``
for attention rings, ``(nG, nM, B, ...)`` for SSM states), so the batch
axis sits at a different depth per family/leaf.  ``cache_batch_axes``
derives a per-leaf axis map structurally — ``jax.eval_shape`` of
``init_cache`` at two batch sizes, diffed — instead of hard-coding
per-family layouts.
"""

from __future__ import annotations

import os
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PAGED_FAMILIES = ("dense", "moe")


def cache_batch_axes(api, cfg, *, probe_len: int = 8):
    """Per-leaf batch-axis pytree for this family's cache layout.

    Abstract-evals ``init_cache`` at batch sizes 1 and 2 and locates the
    one axis that scaled — no arrays are materialized.
    """
    a1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, probe_len))
    a2 = jax.eval_shape(lambda: api.init_cache(cfg, 2, probe_len))

    def axis_of(x, y):
        diff = [i for i, (d1, d2) in enumerate(zip(x.shape, y.shape))
                if d1 != d2]
        if len(diff) != 1:
            raise ValueError(f"cannot locate batch axis: {x.shape} vs "
                             f"{y.shape}")
        return diff[0]

    return jax.tree.map(axis_of, a1, a2)


def _expand(mask, axis: int, ndim: int):
    """(B,) bool -> broadcastable shape with B at ``axis`` of an
    ``ndim``-rank leaf."""
    return mask.reshape((1,) * axis + (-1,) + (1,) * (ndim - axis - 1))


def freeze_inactive(old_cache, new_cache, active, axes):
    """Select ``new_cache`` for active lanes and ``old_cache`` for inactive
    ones, per leaf at its batch axis — retired/empty slots never drift while
    other requests decode (SSM states included; the attention ring guards
    its own writes, recurrent states rely on this select)."""
    return jax.tree.map(
        lambda o, n, ax: jnp.where(_expand(active, ax, n.ndim), n, o),
        old_cache, new_cache, axes)


class _LanePool:
    """Shared lane (slot) free-list: acquire/release bookkeeping common to
    both pool layouts.  Slot lifecycle is owned by the engine; the pools
    only track the free list."""

    def __init__(self, num_slots: int, cache_len: int):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self._free: List[int] = list(range(num_slots - 1, -1, -1))

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop()

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)


class CachePool(_LanePool):
    """``num_slots`` cache lanes carved out of one preallocated cache.

    ``insert`` is the single compiled entry point — slot index and
    request cache are traced, so admissions at any slot share one
    signature.
    """

    def __init__(self, api, cfg, num_slots: int, cache_len: int, *,
                 force_window: int = 0, dtype=None):
        super().__init__(num_slots, cache_len)
        dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
        self.cache = api.init_cache(cfg, num_slots, cache_len,
                                    force_window=force_window, dtype=dtype)
        self.axes = cache_batch_axes(api, cfg)

        def _insert(pool, req_cache, slot):
            return jax.tree.map(
                lambda p, r, ax: jax.lax.dynamic_update_slice_in_dim(
                    p, r.astype(p.dtype), slot, axis=ax),
                pool, req_cache, self.axes)

        self._insert = jax.jit(_insert, donate_argnums=(0,))

    # -- block accounting (lane granularity) ---------------------------------

    @property
    def pool_blocks(self) -> int:
        """Block accounting at lane granularity: one lane == one block (the
        paged pool refines this; metrics report both layouts uniformly)."""
        return self.num_slots

    @property
    def blocks_in_use(self) -> int:
        return self.num_slots - len(self._free)

    # -- data path ----------------------------------------------------------

    def insert(self, req_cache, slot: int) -> None:
        """Write a batch-1 prefill cache into lane ``slot`` (traced — one
        compiled signature for every slot/admission)."""
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(slot, jnp.int32))


# ---------------------------------------------------------------------------
# Paged block pool
# ---------------------------------------------------------------------------

class BlockAllocator:
    """LIFO free-list allocator over ``n_blocks`` physical pool blocks.

    Invariant (the hypothesis property in tests/test_paged_pool.py): the
    free list and the allocated set always partition ``range(n_blocks)`` —
    no block is ever in two hands, so two live requests can never scatter
    into the same pool slot."""

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._used: set = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._used)

    def alloc(self, n: int = 1) -> List[int]:
        """Pop ``n`` blocks; raises RuntimeError (allocating nothing) when
        fewer than ``n`` are free — the caller parks or evicts."""
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        return out

    def free(self, blocks: Sequence[int]) -> None:
        blocks = list(blocks)
        if len(set(blocks)) != len(blocks):
            raise ValueError(f"duplicate blocks in one free: {blocks}")
        for b in blocks:                       # validate before mutating
            if b not in self._used:
                raise ValueError(f"block {b} double-freed (or never "
                                 f"allocated)")
        for b in blocks:
            self._used.discard(b)
            self._free.append(b)


def auto_block_size(ring_len: int, target: int = 0) -> int:
    """Divisor of ``ring_len`` nearest the target block size (ties -> the
    larger).  Divisibility keeps the logical gather view exactly the ring —
    the bit-identical-greedy invariant — and makes the free-list/table
    partition exact (no half-used tail blocks).  REPRO_PAGED_BLOCK overrides
    the target (on real TPUs pick a 128-multiple)."""
    target = target or int(os.environ.get("REPRO_PAGED_BLOCK", "16"))
    divs = [d for d in range(1, ring_len + 1) if ring_len % d == 0]
    return min(divs, key=lambda d: (abs(d - target), -d))


class PagedCachePool(_LanePool):
    """Paged block-KV pool: one shared block pool + per-lane block tables.

    Engine-facing surface mirrors ``CachePool`` (free_slots / acquire /
    release / insert / cache) plus the paged extras: ``table`` (the host
    block table the engine ships into each serve step), ``grant`` /
    ``reset_blocks`` for on-demand block growth during decode, and
    block-level accounting for admission control and metrics.

    Geometry: the logical per-request ring is ``ring_len = min(cache_len,
    window)`` slots, carved into ``blocks_per_slot`` blocks of
    ``block_size`` (which must divide ``ring_len`` — ``auto_block_size``
    picks such a divisor).  The pool holds ``pool_blocks`` physical blocks
    (default: full capacity, ``num_slots * blocks_per_slot``; pass less to
    oversubscribe lanes against actual token footprints — the whole point).
    """

    def __init__(self, cfg, num_slots: int, cache_len: int, *,
                 block_size: int = 0, pool_blocks: int = 0,
                 force_window: int = 0, dtype=None):
        super().__init__(num_slots, cache_len)
        if cfg.family not in PAGED_FAMILIES or cfg.local_global_alternating:
            raise ValueError(
                f"paged KV pools need one uniform ring geometry per layer "
                f"(families {PAGED_FAMILIES}, no local/global alternation); "
                f"got {cfg.family!r}")
        from repro.models.layers.attention import init_attn_cache
        w = force_window or cfg.sliding_window
        ring_len = min(cache_len, w) if w > 0 else cache_len
        block_size = block_size or auto_block_size(ring_len)
        if ring_len % block_size:
            raise ValueError(f"block_size {block_size} must divide the ring "
                             f"length {ring_len}")
        self.ring_len = ring_len
        self.block_size = block_size
        self.blocks_per_slot = ring_len // block_size
        n_blocks = pool_blocks or num_slots * self.blocks_per_slot
        dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
        dh = cfg.resolved_head_dim()
        self.cache = jax.vmap(lambda _: init_attn_cache(
            n_blocks, block_size, cfg.num_kv_heads, dh, dtype))(
            jnp.arange(cfg.num_layers))
        self.allocator = BlockAllocator(n_blocks)
        self.table = np.full((num_slots, self.blocks_per_slot), -1, np.int32)

        T, bs = self.blocks_per_slot, self.block_size

        def _insert(pool, req_cache, row):
            # req_cache leaves: (L, 1, ring_len, ...) -> (L, T, bs, ...)
            # scattered at the physical ids in ``row`` (-1 == ungranted ->
            # out-of-bounds index, dropped)
            idx = jnp.where(row >= 0, row, n_blocks)

            def scatter(p, r):
                blocks = r[:, 0].reshape((r.shape[0], T, bs) + r.shape[3:])
                return p.at[:, idx].set(blocks.astype(p.dtype), mode="drop")

            return jax.tree.map(scatter, pool, req_cache)

        self._insert = jax.jit(_insert, donate_argnums=(0,))

        def _reset(kv_pos, idx):
            # (L, n_blocks, bs) -> granted blocks' positions invalidated
            return kv_pos.at[:, idx].set(-1, mode="drop")

        self._reset = jax.jit(_reset, donate_argnums=(0,))

    # -- slot management ----------------------------------------------------

    @property
    def pool_blocks(self) -> int:
        return self.allocator.n_blocks

    @property
    def blocks_in_use(self) -> int:
        return self.allocator.used_blocks

    @property
    def free_blocks(self) -> int:
        return self.allocator.free_blocks

    def blocks_for(self, extent: int) -> int:
        """Blocks covering ring slots [0, extent) — admission cost of a
        prefill whose occupied ring extent is ``extent`` tokens."""
        return -(-min(extent, self.ring_len) // self.block_size)

    def release(self, slot: int) -> None:
        """Retire a lane: every block in its table row returns to the free
        list (stale contents are masked on next grant via reset_blocks)."""
        super().release(slot)                  # validates double-free first
        row = self.table[slot]
        self.allocator.free([int(b) for b in row[row >= 0]])
        self.table[slot] = -1

    # -- block lifecycle -----------------------------------------------------

    def grant_prefix(self, slot: int, n: int) -> List[int]:
        """Admission grant: physical blocks for logical blocks [0, n) of
        lane ``slot`` (the prefill extent).  Raises RuntimeError without
        side effects when the pool can't cover it."""
        ids = self.allocator.alloc(n)
        self.table[slot, :n] = ids
        return ids

    def grant(self, slot: int, logical_block: int) -> int:
        """Decode-time grant of one block (the write position crossed into
        an ungranted logical block).  Raises RuntimeError when exhausted —
        the engine parks the request."""
        if self.table[slot, logical_block] >= 0:
            raise ValueError(f"slot {slot} logical block {logical_block} "
                             f"already granted")
        b = self.allocator.alloc(1)[0]
        self.table[slot, logical_block] = b
        return b

    def reset_blocks(self, blocks: Sequence[int]) -> None:
        """Invalidate kv_pos of freshly granted blocks on device (stale
        positions from a previous owner must not pass the validity mask).
        Padded to num_slots ids per call — at most one grant per lane per
        step — so every reset shares one compiled signature."""
        if not blocks:
            return
        idx = np.full((self.num_slots,), self.allocator.n_blocks, np.int32)
        idx[:len(blocks)] = blocks
        self.cache["kv_pos"] = self._reset(self.cache["kv_pos"],
                                           jnp.asarray(idx))

    # -- data path ----------------------------------------------------------

    def insert(self, req_cache, slot: int) -> None:
        """Scatter a batch-1 prefill ring into this lane's granted blocks
        (traced — one compiled signature for every slot/admission)."""
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(self.table[slot]))

    # -- invariants (tests) --------------------------------------------------

    def assert_partition(self) -> None:
        """Free list + all table rows partition the physical pool."""
        free = set(self.allocator._free)
        held = [int(b) for b in self.table.ravel() if b >= 0]
        assert len(held) == len(set(held)), "block granted to two lanes"
        assert free.isdisjoint(held), "block both free and granted"
        assert free | set(held) == set(range(self.allocator.n_blocks)), \
            "block leaked (neither free nor granted)"
        assert set(held) == self.allocator._used, \
            "allocator used-set out of sync with the table"
