"""Cache pools for the serving engine: contiguous per-slot lanes and the
paged block-KV pool.

``CachePool`` preallocates ``num_slots`` full-length cache lanes in one
donated pytree — a request is "placed" by writing its batch-1 prefill cache
into lane ``slot`` with a traced ``dynamic_update_slice``.  It works for
every servable family (attention rings AND SSM/hybrid state, bf16 and int8,
``REPRO_CACHE_SHARD=seq`` layouts) because it never looks inside the leaves:
``cache_batch_axes`` finds each leaf's batch axis structurally.

``PagedCachePool`` is the HBM-efficient layout for uniform attention-ring
families (dense/moe without local/global alternation): ONE donated block
pool of shape ``(L, n_blocks, block_size, Hk, dh)`` plus a host-side block
table ``(num_slots, blocks_per_slot)`` mapping each lane's logical ring
blocks to physical pool blocks.  A lane only holds the blocks its tokens
actually occupy — short requests stop reserving a full ``cache_len`` lane,
so at fixed pool bytes strictly more requests fit in flight.  Blocks are
granted on demand (`grant`) as decode crosses block boundaries and freed
wholesale at retirement; freshly granted blocks get their ``kv_pos``
invalidated on device (`reset_blocks`) so a previous owner's stale
positions can never leak through the ring-validity mask.  SSM/hybrid
families keep dense lanes behind the same engine-facing surface
(acquire/release/insert + block accounting).

Copy-on-write prefix sharing (this PR's tentpole): blocks are refcounted
and a prefix-hash index (``match_prefix`` / ``register_prefix``) maps
block-aligned prompt prefixes — and whole prompts, with the last-token
logits row — to live block chains.  A new lane whose prompt matches maps
the chain's blocks read-only into its table (``share_map``: refcount bump,
zero new blocks, and on a full-prompt hit zero prefill recompute); the
first write that would land in a block with refcount > 1 triggers
copy-on-write (``cow``: allocate a fresh block, device block-copy the tile
through ``repro.kernels.ops.block_copy``, remap, decref).  Chain entries
never pin blocks: when a block's refcount hits zero — or its sole owner's
ring wraps back over prefix content — every chain referencing it is
dropped.  Sharing is safe exactly because all prompts start at position 0
(RoPE'd KV at a position depends only on the tokens at/before it), decode
writes always precede reads at the same query position, and stale
future-position slots in a shared tail block are masked by the causal /
ring-validity mask.

The swap tier rides the same geometry: ``gather_lane`` snapshots a lane's
logical ring (one jitted gather, dispatch-async) so the engine can move a
cold lane's blocks to host memory and free them, then ``insert`` the saved
ring back into freshly granted blocks on resume — bit-exact, replacing
evict-and-recompute as the livelock-breaker.

Cache pytrees stack layers OUTSIDE the batch axis (``(L, B, S, Hk, dh)``
for attention rings, ``(nG, nM, B, ...)`` for SSM states), so the batch
axis sits at a different depth per family/leaf.  ``cache_batch_axes``
derives a per-leaf axis map structurally — ``jax.eval_shape`` of
``init_cache`` at two batch sizes, diffed — instead of hard-coding
per-family layouts.
"""

from __future__ import annotations

import os
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

PAGED_FAMILIES = ("dense", "moe")


def cache_batch_axes(api, cfg, *, probe_len: int = 8):
    """Per-leaf batch-axis pytree for this family's cache layout.

    Abstract-evals ``init_cache`` at batch sizes 1 and 2 and locates the
    one axis that scaled — no arrays are materialized.
    """
    a1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, probe_len))
    a2 = jax.eval_shape(lambda: api.init_cache(cfg, 2, probe_len))

    def axis_of(x, y):
        diff = [i for i, (d1, d2) in enumerate(zip(x.shape, y.shape))
                if d1 != d2]
        if len(diff) != 1:
            raise ValueError(f"cannot locate batch axis: {x.shape} vs "
                             f"{y.shape}")
        return diff[0]

    return jax.tree.map(axis_of, a1, a2)


def _expand(mask, axis: int, ndim: int):
    """(B,) bool -> broadcastable shape with B at ``axis`` of an
    ``ndim``-rank leaf."""
    return mask.reshape((1,) * axis + (-1,) + (1,) * (ndim - axis - 1))


def freeze_inactive(old_cache, new_cache, active, axes):
    """Select ``new_cache`` for active lanes and ``old_cache`` for inactive
    ones, per leaf at its batch axis — retired/empty slots never drift while
    other requests decode (SSM states included; the attention ring guards
    its own writes, recurrent states rely on this select)."""
    return jax.tree.map(
        lambda o, n, ax: jnp.where(_expand(active, ax, n.ndim), n, o),
        old_cache, new_cache, axes)


class _LanePool:
    """Shared lane (slot) free-list: acquire/release bookkeeping common to
    both pool layouts.  Slot lifecycle is owned by the engine; the pools
    only track the free list."""

    def __init__(self, num_slots: int, cache_len: int):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.cache_len = cache_len
        self._free: List[int] = list(range(num_slots - 1, -1, -1))

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop()

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)


class CachePool(_LanePool):
    """``num_slots`` cache lanes carved out of one preallocated cache.

    ``insert`` is the single compiled entry point — slot index and
    request cache are traced, so admissions at any slot share one
    signature.
    """

    def __init__(self, api, cfg, num_slots: int, cache_len: int, *,
                 force_window: int = 0, dtype=None):
        super().__init__(num_slots, cache_len)
        dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
        self.cache = api.init_cache(cfg, num_slots, cache_len,
                                    force_window=force_window, dtype=dtype)
        self.axes = cache_batch_axes(api, cfg)

        def _insert(pool, req_cache, slot):
            return jax.tree.map(
                lambda p, r, ax: jax.lax.dynamic_update_slice_in_dim(
                    p, r.astype(p.dtype), slot, axis=ax),
                pool, req_cache, self.axes)

        self._insert = jax.jit(_insert, donate_argnums=(0,))

    # -- block accounting (lane granularity) ---------------------------------

    @property
    def pool_blocks(self) -> int:
        """Block accounting at lane granularity: one lane == one block (the
        paged pool refines this; metrics report both layouts uniformly)."""
        return self.num_slots

    @property
    def blocks_in_use(self) -> int:
        return self.num_slots - len(self._free)

    @property
    def fragmentation(self) -> float:
        """Contiguous lanes can't fragment: always 0 (uniform metrics
        interface with the paged pool)."""
        return 0.0

    @property
    def free_runs(self) -> int:
        return 1 if self._free else 0

    # -- data path ----------------------------------------------------------

    def insert(self, req_cache, slot: int) -> None:
        """Write a batch-1 prefill cache into lane ``slot`` (traced — one
        compiled signature for every slot/admission)."""
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(slot, jnp.int32))


# ---------------------------------------------------------------------------
# Paged block pool
# ---------------------------------------------------------------------------

class BlockAllocator:
    """LIFO free-list allocator over ``n_blocks`` physical pool blocks,
    with per-block refcounts for copy-on-write prefix sharing.

    Invariant (the hypothesis property in tests/test_paged_pool.py and
    tests/test_prefix_share.py): the free list and the allocated set always
    partition ``range(n_blocks)``, and a block's refcount equals the number
    of lane-table rows referencing it — no block is ever in two hands
    unintentionally, and a shared block can't return to the free list while
    any lane still reads it."""

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError("n_blocks must be >= 1")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._used: set = set()
        self._ref: dict = {}                   # block -> refcount (>= 1)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return len(self._used)

    @property
    def free_runs(self) -> int:
        """Maximal runs of consecutive block ids in the free list (order
        ignored: the LIFO list is a set for adjacency purposes).  One run
        = perfectly coalesced; ``free_blocks`` runs = fully shredded."""
        if not self._free:
            return 0
        ids = sorted(self._free)
        return 1 + sum(1 for a, b in zip(ids, ids[1:]) if b != a + 1)

    @property
    def fragmentation(self) -> float:
        """Free-list shredding in [0, 1]: ``(runs - 1) / (free - 1)``.
        0 when the free space is one contiguous run (or ≤ 1 block free),
        1 when every free block is an island.  Block granularity makes
        this cosmetic for *allocation* (any free block serves any ask) but
        it tracks how interleaved lane lifetimes have scrambled the pool —
        the locality signal for the gather/scatter paths."""
        free = len(self._free)
        if free <= 1:
            return 0.0
        return (self.free_runs - 1) / (free - 1)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def alloc(self, n: int = 1) -> List[int]:
        """Pop ``n`` blocks (each at refcount 1); raises RuntimeError
        (allocating nothing) when fewer than ``n`` are free — the caller
        parks or evicts."""
        if n > len(self._free):
            raise RuntimeError(
                f"block pool exhausted: want {n}, free {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._used.update(out)
        for b in out:
            self._ref[b] = 1
        return out

    def incref(self, block: int) -> int:
        """Share an allocated block (a new lane maps it read-only)."""
        if block not in self._used:
            raise ValueError(f"cannot share free block {block}")
        self._ref[block] += 1
        return self._ref[block]

    def decref(self, block: int) -> bool:
        """Drop one reference; returns True when the block actually went
        back to the free list (last reference)."""
        if block not in self._used:
            raise ValueError(f"block {block} double-freed (or never "
                             f"allocated)")
        self._ref[block] -= 1
        if self._ref[block] > 0:
            return False
        del self._ref[block]
        self._used.discard(block)
        self._free.append(block)
        return True

    def free(self, blocks: Sequence[int]) -> None:
        """Wholesale free of exclusively-owned blocks.  Shared blocks must
        go through ``decref`` — freeing one here would yank it out from
        under the other owners, so it's rejected before any mutation."""
        blocks = list(blocks)
        if len(set(blocks)) != len(blocks):
            raise ValueError(f"duplicate blocks in one free: {blocks}")
        for b in blocks:                       # validate before mutating
            if b not in self._used:
                raise ValueError(f"block {b} double-freed (or never "
                                 f"allocated)")
            if self._ref[b] != 1:
                raise ValueError(f"block {b} still shared "
                                 f"(refcount {self._ref[b]}); decref it")
        for b in blocks:
            del self._ref[b]
            self._used.discard(b)
            self._free.append(b)


def auto_block_size(ring_len: int, target: int = 0, *,
                    min_block: int = 8) -> int:
    """Divisor of ``ring_len`` nearest the target block size (ties -> the
    larger), never below ``min(min_block, ring_len)``.  Divisibility keeps
    the logical gather view exactly the ring — the bit-identical-greedy
    invariant — and makes the free-list/table partition exact (no half-used
    tail blocks).  The minimum-tile clamp closes the degenerate prime case:
    a prime ``ring_len`` (e.g. 97) has only the divisors {1, ring_len}, and
    picking 1 exploded the block table to ``ring_len`` entries per lane and
    shredded the pool into single-token scatters — now the whole ring is
    one block instead.  REPRO_PAGED_BLOCK overrides the target (on real
    TPUs pick a 128-multiple)."""
    target = target or int(os.environ.get("REPRO_PAGED_BLOCK", "16"))
    floor = min(min_block, ring_len)
    divs = [d for d in range(1, ring_len + 1)
            if ring_len % d == 0 and d >= floor]
    return min(divs, key=lambda d: (abs(d - target), -d))


class PagedCachePool(_LanePool):
    """Paged block-KV pool: one shared block pool + per-lane block tables.

    Engine-facing surface mirrors ``CachePool`` (free_slots / acquire /
    release / insert / cache) plus the paged extras: ``table`` (the host
    block table the engine ships into each serve step), ``grant`` /
    ``reset_blocks`` for on-demand block growth during decode, and
    block-level accounting for admission control and metrics.

    Geometry: the logical per-request ring is ``ring_len = min(cache_len,
    window)`` slots, carved into ``blocks_per_slot`` blocks of
    ``block_size`` (which must divide ``ring_len`` — ``auto_block_size``
    picks such a divisor).  The pool holds ``pool_blocks`` physical blocks
    (default: full capacity, ``num_slots * blocks_per_slot``; pass less to
    oversubscribe lanes against actual token footprints — the whole point).
    """

    def __init__(self, cfg, num_slots: int, cache_len: int, *,
                 block_size: int = 0, pool_blocks: int = 0,
                 force_window: int = 0, dtype=None):
        super().__init__(num_slots, cache_len)
        if cfg.family not in PAGED_FAMILIES or cfg.local_global_alternating:
            raise ValueError(
                f"paged KV pools need one uniform ring geometry per layer "
                f"(families {PAGED_FAMILIES}, no local/global alternation); "
                f"got {cfg.family!r}")
        from repro.models.layers.attention import init_attn_cache
        w = force_window or cfg.sliding_window
        ring_len = min(cache_len, w) if w > 0 else cache_len
        block_size = block_size or auto_block_size(ring_len)
        if ring_len % block_size:
            raise ValueError(f"block_size {block_size} must divide the ring "
                             f"length {ring_len}")
        self.ring_len = ring_len
        self.block_size = block_size
        self.blocks_per_slot = ring_len // block_size
        n_blocks = pool_blocks or num_slots * self.blocks_per_slot
        dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
        dh = cfg.resolved_head_dim()
        self.cache = jax.vmap(lambda _: init_attn_cache(
            n_blocks, block_size, cfg.num_kv_heads, dh, dtype))(
            jnp.arange(cfg.num_layers))
        self.allocator = BlockAllocator(n_blocks)
        self.table = np.full((num_slots, self.blocks_per_slot), -1, np.int32)
        # prefix-hash index: key -> {"blocks": tuple, "logits": np | None}.
        # Keys are b"P" + block-aligned token-prefix bytes (share KV, still
        # prefill) or b"F" + whole-prompt bytes (skip prefill entirely: the
        # stored last-token logits row seeds the first sample).  The reverse
        # map lets a block's death (refcount -> 0, or a sole-owner ring
        # wrap overwriting prefix content) drop every chain that cites it.
        self._chains: dict = {}
        self._block_chains: dict = {}          # block -> set of chain keys

        T, bs = self.blocks_per_slot, self.block_size

        def _insert(pool, req_cache, row):
            # req_cache leaves: (L, 1, ring_len, ...) -> (L, T, bs, ...)
            # scattered at the physical ids in ``row`` (-1 == ungranted ->
            # out-of-bounds index, dropped)
            idx = jnp.where(row >= 0, row, n_blocks)

            def scatter(p, r):
                blocks = r[:, 0].reshape((r.shape[0], T, bs) + r.shape[3:])
                return p.at[:, idx].set(blocks.astype(p.dtype), mode="drop")

            return jax.tree.map(scatter, pool, req_cache)

        self._insert = jax.jit(_insert, donate_argnums=(0,))

        def _reset(kv_pos, idx):
            # (L, n_blocks, bs) -> granted blocks' positions invalidated
            return kv_pos.at[:, idx].set(-1, mode="drop")

        self._reset = jax.jit(_reset, donate_argnums=(0,))

        from repro.kernels import ops as _kops

        def _copy(pool, src, dst):
            # CoW data move: one (L, bs, ...) tile per leaf, src -> dst.
            # kv_pos rides along too, so the copy carries validity exactly.
            return jax.tree.map(lambda p: _kops.block_copy(p, src, dst),
                                pool)

        self._copy = jax.jit(_copy, donate_argnums=(0,))

        def _gather(pool, row):
            # Lane snapshot for the swap tier: physical blocks -> the
            # logical (L, 1, ring_len, ...) ring, the SAME leaf shapes a
            # batch-1 prefill cache has — so swap-in rides the one compiled
            # ``_insert`` signature.  Ungranted rows gather block 0 but
            # their kv_pos is forced to -1, so reinsertion drops nothing
            # real and revalidates nothing stale.
            safe = jnp.where(row >= 0, row, 0)

            def pick(p):
                y = p[:, safe]                 # (L, T, bs, ...)
                return y.reshape((p.shape[0], 1, T * bs) + p.shape[3:])

            out = {k: pick(p) for k, p in pool.items()}
            granted = (row >= 0)[None, :, None]
            kvp = pool["kv_pos"][:, safe]
            out["kv_pos"] = jnp.where(granted, kvp, -1).reshape(
                (pool["kv_pos"].shape[0], 1, T * bs))
            return out

        self._gather = jax.jit(_gather)

    # -- slot management ----------------------------------------------------

    @property
    def pool_blocks(self) -> int:
        return self.allocator.n_blocks

    @property
    def blocks_in_use(self) -> int:
        return self.allocator.used_blocks

    @property
    def free_blocks(self) -> int:
        return self.allocator.free_blocks

    @property
    def free_runs(self) -> int:
        return self.allocator.free_runs

    @property
    def fragmentation(self) -> float:
        return self.allocator.fragmentation

    def blocks_for(self, extent: int) -> int:
        """Blocks covering ring slots [0, extent) — admission cost of a
        prefill whose occupied ring extent is ``extent`` tokens."""
        return -(-min(extent, self.ring_len) // self.block_size)

    def lane_blocks(self, slot: int) -> int:
        """Physical blocks currently mapped by ``slot``'s table row — the
        reclamation size the engine audits when a lane is swapped out or
        cancelled (shared blocks count too: the sharer holds a reference
        even though release may not free them)."""
        return int((self.table[slot] >= 0).sum())

    @property
    def block_bytes(self) -> int:
        """HBM bytes of one physical block across every leaf (all layers) —
        the unit for share/CoW/swap byte accounting."""
        return sum(int(p.nbytes) // p.shape[1]
                   for p in jax.tree.leaves(self.cache))

    def refcount(self, block: int) -> int:
        return self.allocator.refcount(block)

    def release(self, slot: int) -> None:
        """Retire a lane: drop one reference per block in its table row;
        blocks whose last reference this was return to the free list (and
        their prefix chains die with them — stale contents are masked on
        next grant via reset_blocks)."""
        super().release(slot)                  # validates double-free first
        row = self.table[slot]
        for b in row[row >= 0]:
            if self.allocator.decref(int(b)):
                self._drop_chains_of(int(b))
        self.table[slot] = -1

    # -- block lifecycle -----------------------------------------------------

    def grant_prefix(self, slot: int, n: int) -> List[int]:
        """Admission grant: physical blocks for logical blocks [0, n) of
        lane ``slot`` (the prefill extent).  Raises RuntimeError without
        side effects when the pool can't cover it."""
        ids = self.allocator.alloc(n)
        self.table[slot, :n] = ids
        return ids

    def grant(self, slot: int, logical_block: int) -> int:
        """Decode-time grant of one block (the write position crossed into
        an ungranted logical block).  Raises RuntimeError when exhausted —
        the engine parks the request."""
        if self.table[slot, logical_block] >= 0:
            raise ValueError(f"slot {slot} logical block {logical_block} "
                             f"already granted")
        b = self.allocator.alloc(1)[0]
        self.table[slot, logical_block] = b
        return b

    def grant_tail(self, slot: int, start: int, n: int) -> List[int]:
        """Admission grant of logical blocks [start, start+n) — the private
        tail after ``start`` shared prefix blocks.  Raises RuntimeError
        without side effects when the pool can't cover it."""
        if n <= 0:
            return []
        ids = self.allocator.alloc(n)
        self.table[slot, start:start + n] = ids
        return ids

    def reset_blocks(self, blocks: Sequence[int]) -> None:
        """Invalidate kv_pos of freshly granted blocks on device (stale
        positions from a previous owner must not pass the validity mask).
        Padded to num_slots ids per call — at most one grant per lane per
        step — so every reset shares one compiled signature."""
        if not blocks:
            return
        idx = np.full((self.num_slots,), self.allocator.n_blocks, np.int32)
        idx[:len(blocks)] = blocks
        self.cache["kv_pos"] = self._reset(self.cache["kv_pos"],
                                           jnp.asarray(idx))

    # -- prefix sharing / copy-on-write --------------------------------------

    @staticmethod
    def _pkey(tokens: np.ndarray) -> bytes:
        return b"P" + np.ascontiguousarray(tokens, np.int32).tobytes()

    @staticmethod
    def _fkey(tokens: np.ndarray) -> bytes:
        return b"F" + np.ascontiguousarray(tokens, np.int32).tobytes()

    def match_prefix(self, prompt):
        """Longest live block-aligned shared prefix for ``prompt``.

        Returns ``(blocks, full_hit, logits_row)``: the physical chain to
        map read-only (possibly empty), whether the WHOLE prompt matched (a
        full hit shares every prefix block and skips prefill — the stored
        last-token ``logits_row`` seeds the first sample), else
        ``logits_row`` is None.  Prompts longer than the ring never match
        (their early positions already wrapped away)."""
        p = np.ascontiguousarray(prompt, np.int32)
        if len(p) == 0 or len(p) > self.ring_len:
            return [], False, None
        full = self._chains.get(self._fkey(p))
        if full is not None:
            return list(full["blocks"]), True, full["logits"]
        for n in range(len(p) // self.block_size, 0, -1):
            c = self._chains.get(self._pkey(p[:n * self.block_size]))
            if c is not None:
                return list(c["blocks"]), False, None
        return [], False, None

    def share_map(self, slot: int, blocks: Sequence[int]) -> None:
        """Map a matched chain read-only into logical blocks [0, len) of
        lane ``slot``: refcount bump per block, zero new allocations.  The
        lane must copy-on-write before its first write into any of them."""
        for b in blocks:
            self.allocator.incref(int(b))
        self.table[slot, :len(blocks)] = np.asarray(blocks, np.int32)

    def register_prefix(self, slot, prompt, logits_row=None) -> None:
        """Index this lane's freshly prefilled prompt: one chain entry per
        block-aligned prefix plus (when ``logits_row`` — the prompt's
        last-token logits — is given) a whole-prompt entry enabling
        zero-prefill admission of identical prompts.  Entries reference
        live blocks only and die with them; re-registration of an existing
        key keeps the incumbent."""
        p = np.ascontiguousarray(prompt, np.int32)
        if len(p) == 0 or len(p) > self.ring_len:
            return
        row = self.table[slot]
        keys = [(self._pkey(p[:n * self.block_size]), n)
                for n in range(1, len(p) // self.block_size + 1)]
        if logits_row is not None:
            keys.append((self._fkey(p), self.blocks_for(len(p))))
        for key, n in keys:
            if key in self._chains or np.any(row[:n] < 0):
                continue
            blocks = tuple(int(b) for b in row[:n])
            entry = {"blocks": blocks, "logits": None}
            if key[:1] == b"F":
                entry["logits"] = np.asarray(logits_row)
            self._chains[key] = entry
            for b in blocks:
                self._block_chains.setdefault(b, set()).add(key)

    def _drop_chains_of(self, block: int) -> None:
        for key in self._block_chains.pop(block, set()):
            entry = self._chains.pop(key, None)
            if entry is None:
                continue
            for b in entry["blocks"]:
                if b != block:
                    s = self._block_chains.get(b)
                    if s is not None:
                        s.discard(key)
                        if not s:
                            del self._block_chains[b]

    def invalidate_block(self, block: int) -> None:
        """A sole owner is about to overwrite this block's prefix content
        (ring wrap): any chain citing it no longer describes what's stored,
        so drop those entries before the write lands."""
        self._drop_chains_of(block)

    def cow(self, slot: int, logical_block: int):
        """Copy-on-write: lane ``slot`` wants to write into a shared
        physical block.  Allocate a fresh block (RuntimeError when
        exhausted — caller parks, nothing mutated), device-copy the tile,
        remap the table, drop the old reference.  Returns (old, new)."""
        old = int(self.table[slot, logical_block])
        if old < 0:
            raise ValueError(f"slot {slot} logical block {logical_block} "
                             f"not granted")
        new = self.allocator.alloc(1)[0]
        self.cache = self._copy(self.cache, jnp.asarray(old, jnp.int32),
                                jnp.asarray(new, jnp.int32))
        self.table[slot, logical_block] = new
        if self.allocator.decref(old):
            self._drop_chains_of(old)
        return old, new

    # -- swap tier ------------------------------------------------------------

    def gather_lane(self, slot: int):
        """Device snapshot of lane ``slot``'s logical ring as prefill-shaped
        leaves (``(L, 1, ring_len, ...)``) — dispatched async; the engine
        materializes it to host later and reinserts it on swap-in through
        the same compiled ``insert``."""
        return self._gather(self.cache, jnp.asarray(self.table[slot]))

    # -- data path ----------------------------------------------------------

    def insert(self, req_cache, slot: int, *, skip_blocks: int = 0) -> None:
        """Scatter a batch-1 prefill ring into this lane's granted blocks
        (traced — one compiled signature for every slot/admission).
        ``skip_blocks`` masks the first N logical blocks out of the scatter
        (shared prefix blocks are read-only: the donor's data is already
        there and bit-identical, so the write is dropped, not duplicated)."""
        row = self.table[slot]
        if skip_blocks:
            row = row.copy()
            row[:skip_blocks] = -1
        self.cache = self._insert(self.cache, req_cache, jnp.asarray(row))

    # -- invariants (tests) --------------------------------------------------

    def assert_partition(self) -> None:
        """Free list + all table rows partition the physical pool, with a
        block's refcount equal to the number of rows citing it, and every
        chain entry referencing live blocks only."""
        free = set(self.allocator._free)
        held = [int(b) for b in self.table.ravel() if b >= 0]
        counts: dict = {}
        for b in held:
            counts[b] = counts.get(b, 0) + 1
        assert free.isdisjoint(held), "block both free and granted"
        assert free | set(held) == set(range(self.allocator.n_blocks)), \
            "block leaked (neither free nor granted)"
        assert set(held) == self.allocator._used, \
            "allocator used-set out of sync with the table"
        for b, c in counts.items():
            assert self.allocator.refcount(b) == c, \
                f"block {b}: refcount {self.allocator.refcount(b)} != " \
                f"{c} table references"
        for key, entry in self._chains.items():
            for b in entry["blocks"]:
                assert b in self.allocator._used, \
                    f"chain {key[:1]} cites freed block {b}"
                assert key in self._block_chains.get(b, ()), \
                    f"reverse chain map missing {b}"
