"""Fixed pool of per-slot ring KV / SSM cache lanes.

One donated cache pytree is preallocated for ``num_slots`` lanes
(``api.init_cache(cfg, num_slots, cache_len)``); a request is "placed" by
writing its batch-1 prefill cache into lane ``slot`` with a traced
``dynamic_update_slice`` — slot assignment therefore never re-jits, and the
pool works unchanged for bf16 and int8 (``REPRO_KV_INT8``) caches and for
``REPRO_CACHE_SHARD=seq`` layouts (the slot axis of the ring cache is
untouched; only the batch axis is indexed).

Cache pytrees stack layers OUTSIDE the batch axis (``(L, B, S, Hk, dh)``
for attention rings, ``(nG, nM, B, ...)`` for SSM states), so the batch
axis sits at a different depth per family/leaf.  ``cache_batch_axes``
derives a per-leaf axis map structurally — ``jax.eval_shape`` of
``init_cache`` at two batch sizes, diffed — instead of hard-coding
per-family layouts.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp


def cache_batch_axes(api, cfg, *, probe_len: int = 8):
    """Per-leaf batch-axis pytree for this family's cache layout.

    Abstract-evals ``init_cache`` at batch sizes 1 and 2 and locates the
    one axis that scaled — no arrays are materialized.
    """
    a1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, probe_len))
    a2 = jax.eval_shape(lambda: api.init_cache(cfg, 2, probe_len))

    def axis_of(x, y):
        diff = [i for i, (d1, d2) in enumerate(zip(x.shape, y.shape))
                if d1 != d2]
        if len(diff) != 1:
            raise ValueError(f"cannot locate batch axis: {x.shape} vs "
                             f"{y.shape}")
        return diff[0]

    return jax.tree.map(axis_of, a1, a2)


def _expand(mask, axis: int, ndim: int):
    """(B,) bool -> broadcastable shape with B at ``axis`` of an
    ``ndim``-rank leaf."""
    return mask.reshape((1,) * axis + (-1,) + (1,) * (ndim - axis - 1))


def freeze_inactive(old_cache, new_cache, active, axes):
    """Select ``new_cache`` for active lanes and ``old_cache`` for inactive
    ones, per leaf at its batch axis — retired/empty slots never drift while
    other requests decode (SSM states included; the attention ring guards
    its own writes, recurrent states rely on this select)."""
    return jax.tree.map(
        lambda o, n, ax: jnp.where(_expand(active, ax, n.ndim), n, o),
        old_cache, new_cache, axes)


class CachePool:
    """``num_slots`` cache lanes carved out of one preallocated cache.

    Slot lifecycle is owned by the engine (this class only tracks the free
    list); ``insert`` is the single compiled entry point — slot index and
    request cache are traced, so admissions at any slot share one
    signature.
    """

    def __init__(self, api, cfg, num_slots: int, cache_len: int, *,
                 force_window: int = 0, dtype=None):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self.cache_len = cache_len
        dtype = jnp.dtype(cfg.compute_dtype) if dtype is None else dtype
        self.cache = api.init_cache(cfg, num_slots, cache_len,
                                    force_window=force_window, dtype=dtype)
        self.axes = cache_batch_axes(api, cfg)
        self._free: List[int] = list(range(num_slots - 1, -1, -1))

        def _insert(pool, req_cache, slot):
            return jax.tree.map(
                lambda p, r, ax: jax.lax.dynamic_update_slice_in_dim(
                    p, r.astype(p.dtype), slot, axis=ax),
                pool, req_cache, self.axes)

        self._insert = jax.jit(_insert, donate_argnums=(0,))

    # -- slot management ----------------------------------------------------

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def acquire(self) -> int:
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop()

    def release(self, slot: int) -> None:
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)

    # -- data path ----------------------------------------------------------

    def insert(self, req_cache, slot: int) -> None:
        """Write a batch-1 prefill cache into lane ``slot`` (traced — one
        compiled signature for every slot/admission)."""
        self.cache = self._insert(self.cache, req_cache,
                                  jnp.asarray(slot, jnp.int32))
