"""FIFO admission control with prefill chunking for the serving engine.

Two budgets bound what one engine step may admit:

  * ``max_tokens_in_flight`` — worst-case token footprint (prompt + full
    horizon) summed over resident requests.  Keeps the pool from filling
    with long-horizon requests that would starve the queue for many steps.
  * ``prefill_chunk`` — prompt tokens prefillable per engine step.  Prefill
    is the latency spike of continuous batching (a full forward over the
    prompt stalls every resident decode); chunking spreads admissions of a
    burst across steps so resident streams keep ticking.  A prompt longer
    than the chunk is admitted alone on a fresh step rather than starved.

``bucket_len`` pads prompt lengths up to a bucket multiple so the number of
distinct compiled prefill signatures stays bounded under arbitrary traces
(the pad is masked out downstream via ``prefill(..., true_len=...)``).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, List, Optional

from repro import obs
from repro.serve.request import Request


def bucket_len(n: int, bucket: int) -> int:
    """Smallest multiple of ``bucket`` >= n (identity when bucket <= 0)."""
    if bucket <= 0:
        return n
    return -(-n // bucket) * bucket


@dataclasses.dataclass
class SchedulerConfig:
    max_tokens_in_flight: int = 0             # 0 == unbounded
    prefill_chunk: int = 0                    # 0 == unbounded per step


class FIFOScheduler:
    """Arrival-ordered admission: the head request admits as soon as a slot
    and both budgets allow; later arrivals never jump the queue (no
    head-of-line reordering — per-cluster fairness is the paper's story,
    smarter policies can subclass)."""

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        self._queue: Deque[Request] = deque()

    def submit(self, request: Request) -> None:
        self._queue.append(request)

    def requeue_front(self, requests: List[Request]) -> None:
        """Push evicted/unplaceable requests back at the head, list order
        preserved (``requests[0]`` pops first).

        Contract: a tick's displaced requests must arrive in ONE call,
        ordered oldest-submit first — the engine batches its victims and
        sorts by original submit sequence.  Separate per-victim calls would
        stack each later call in front of the earlier one, reversing
        arrival order across the tick (the requeue-ordering bug this
        replaces).  Resumed requests keep their id and original submit
        time, so TTFT keeps measuring from the user's submit."""
        for r in reversed(requests):
            obs.instant("sched.requeue", track=f"req:{r.id}", id=r.id,
                        queue_depth=len(self._queue))
            self._queue.appendleft(r)

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def pending_tokens(self) -> int:
        """Worst-case token footprint queued (the engine's retry_after
        estimator divides this by the slot count)."""
        return sum(r.total_tokens for r in self._queue)

    def queued(self) -> List[Request]:
        """Snapshot of the queue, head first (read-only view for the
        engine's shed-victim selection; mutation goes through
        :meth:`remove` / :meth:`cancel_where` so FIFO order is kept)."""
        return list(self._queue)

    def remove(self, request: Request) -> bool:
        """Drop one queued request (load shedding); the relative order of
        everything else is untouched.  Returns False if it already left
        the queue (admitted this tick)."""
        try:
            self._queue.remove(request)
            return True
        except ValueError:
            return False

    def cancel_where(self, pred: Callable[[Request], bool]
                     ) -> List[Request]:
        """Remove every queued request matching ``pred`` (deadline/TTFT
        sweeps), preserving the survivors' FIFO order.  Returns the
        removed requests in queue order."""
        flags = [bool(pred(r)) for r in self._queue]
        removed = [r for r, f in zip(self._queue, flags) if f]
        if removed:
            kept = [r for r, f in zip(self._queue, flags) if not f]
            self._queue.clear()
            self._queue.extend(kept)
        return removed

    def admit(self, *, now_step: int, free_slots: int,
              tokens_in_flight: int, free_blocks: int = -1,
              blocks_needed: Optional[Callable[[Request], int]] = None
              ) -> List[Request]:
        """Pop the FIFO prefix admissible this step.

        With a paged pool, admission is accounted in *blocks* rather than
        lanes: ``free_blocks`` is the pool's current free-list size and
        ``blocks_needed(req)`` prices a request at its prefill block count
        (decode growth is granted on demand, parking on exhaustion) — a
        short request no longer costs a whole ``cache_len`` lane, which is
        exactly where the paged concurrency win comes from.  With prefix
        sharing the engine's ``blocks_needed`` prices only UNSHARED blocks
        (a whole-prompt chain hit costs 0), so cluster-skewed traffic
        admits far past the free list's nominal capacity.  ``free_blocks``
        < 0 (contiguous lanes) disables block accounting.
        """
        cfg = self.config
        out: List[Request] = []
        prefill_used = 0
        blocks_used = 0
        while self._queue and len(out) < free_slots:
            req = self._queue[0]
            if req.arrival_step > now_step:
                break                          # trace time not reached (FIFO)
            if cfg.max_tokens_in_flight > 0 and tokens_in_flight + \
                    req.total_tokens > cfg.max_tokens_in_flight:
                break
            if free_blocks >= 0 and blocks_needed is not None and \
                    blocks_used + blocks_needed(req) > free_blocks:
                break                          # pool full — wait for frees
            if cfg.prefill_chunk > 0 and prefill_used > 0 and \
                    prefill_used + req.prompt_len > cfg.prefill_chunk:
                break                          # chunk full — next step
            out.append(self._queue.popleft())
            prefill_used += req.prompt_len
            tokens_in_flight += req.total_tokens
            if blocks_needed is not None:
                blocks_used += blocks_needed(req)
        return out
