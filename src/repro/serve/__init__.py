"""repro.serve — forecast-serving: sampling + the continuous-batching
engine (engine / scheduler / cache_pool / request / metrics)."""

from repro.serve.cache_pool import (BlockAllocator, CachePool,
                                    PagedCachePool)
from repro.serve.engine import ForecastEngine
from repro.serve.request import FinishedRequest, Request, SamplingParams
from repro.serve.scheduler import FIFOScheduler, SchedulerConfig

__all__ = ["ForecastEngine", "Request", "SamplingParams", "FinishedRequest",
           "FIFOScheduler", "SchedulerConfig", "CachePool", "PagedCachePool",
           "BlockAllocator"]
