"""repro.serve — forecast-serving: sampling + the continuous-batching
engine (engine / scheduler / cache_pool / request / metrics), with
request-level fault tolerance (SLO deadlines, load shedding, poison
quarantine) and a crash-recoverable write-ahead request journal."""

from repro.serve.cache_pool import (BlockAllocator, CachePool,
                                    PagedCachePool)
from repro.serve.engine import ForecastEngine
from repro.serve.journal import JournalState, RequestJournal, replay_journal
from repro.serve.request import (FinishedRequest, QuarantinedRequest,
                                 Request, SamplingParams, SubmitVerdict)
from repro.serve.scheduler import FIFOScheduler, SchedulerConfig

__all__ = ["ForecastEngine", "Request", "SamplingParams", "FinishedRequest",
           "SubmitVerdict", "QuarantinedRequest", "FIFOScheduler",
           "SchedulerConfig", "CachePool", "PagedCachePool",
           "BlockAllocator", "RequestJournal", "JournalState",
           "replay_journal"]
