"""Token sampling: greedy / temperature / top-k / top-p (nucleus).

Pure functions over logits (B, V) so they compose with any family's
decode_step under jit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = jnp.finfo(jnp.float32).min


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(key, logits: jnp.ndarray, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 0.0) -> jnp.ndarray:
    """logits (B, V) -> tokens (B,)."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k > 0:
        # clamp to the vocab: top_k > V would index past the sorted logits
        k_eff = min(int(top_k), logits.shape[-1])
        kth = jnp.sort(logits, axis=-1)[:, -k_eff][:, None]
        logits = jnp.where(logits < kth, _NEG, logits)
    if 0.0 < top_p < 1.0:
        # top_p >= 1.0 keeps the whole distribution; skipping the cutoff
        # avoids the degenerate all-excluded row when cumsum rounds past 1
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest logit value still inside the nucleus
        keep = cum - probs < top_p                  # first token always kept
        cutoff = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits < cutoff, _NEG, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_vec(keys, logits: jnp.ndarray, *, temperature, top_k,
               top_p) -> jnp.ndarray:
    """Per-row sampling for ragged serving batches: logits (B, V) ->
    tokens (B,).

    ``keys`` is a (B, 2) uint32 array (one independent PRNG key per row —
    request isolation: a row's stream never depends on its batch
    neighbours); ``temperature``/``top_k``/``top_p`` are (B,) arrays so the
    request mix changes without re-jitting the serve step.  Rows with
    ``temperature <= 0`` decode greedily; ``top_k`` is clamped to the vocab
    and ``top_p >= 1`` disables the nucleus cutoff, mirroring ``sample``.
    """
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temperature = jnp.asarray(temperature, jnp.float32)
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)

    lg = logits / jnp.maximum(temperature, 1e-6)[:, None]
    sorted_desc = jnp.sort(lg, axis=-1)[:, ::-1]
    kk = jnp.clip(top_k, 0, V)
    kth = sorted_desc[jnp.arange(B), jnp.maximum(kk - 1, 0)][:, None]
    lg = jnp.where((kk[:, None] > 0) & (lg < kth), _NEG, lg)

    sorted_k = jnp.sort(lg, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(sorted_k, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep = cum - probs < top_p[:, None]
    cutoff = jnp.min(jnp.where(keep, sorted_k, jnp.inf), axis=-1,
                     keepdims=True)
    use_p = ((top_p > 0.0) & (top_p < 1.0))[:, None]
    lg = jnp.where(use_p & (lg < cutoff), _NEG, lg)

    sampled = jax.vmap(
        lambda k, row: jax.random.categorical(k, row))(keys, lg)
    return jnp.where(temperature <= 0.0, greedy_tok,
                     sampled.astype(jnp.int32))


def generate(api, params, cfg, cache, first_token, *, steps: int,
             start_pos: int, key=None, temperature: float = 0.0,
             top_k: int = 0, top_p: float = 0.0, force_window: int = 0):
    """Autoregressive generation loop (lax.scan — jit-able end to end).

    first_token: (B, 1) int32 from prefill. Returns (tokens (B, steps),
    final cache)."""
    B = first_token.shape[0]
    key = key if key is not None else jax.random.PRNGKey(0)

    def step(carry, i):
        tok, cache, k = carry
        logits, cache = api.decode_step(
            params, cfg, cache, {"token": tok, "pos": start_pos + i},
            force_window=force_window)
        k, sub = jax.random.split(k)
        nxt = sample(sub, logits[:, -1, :], temperature=temperature,
                     top_k=top_k, top_p=top_p)[:, None]
        return (nxt, cache, k), nxt[:, 0]

    (_, cache, _), toks = jax.lax.scan(
        step, (first_token, cache, key),
        jnp.arange(steps, dtype=jnp.int32))
    return toks.T, cache                          # (B, steps)
