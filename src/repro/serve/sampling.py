"""Token sampling: greedy / temperature / top-k / top-p (nucleus).

Pure functions over logits (B, V) so they compose with any family's
decode_step under jit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = jnp.finfo(jnp.float32).min


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(key, logits: jnp.ndarray, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 0.0) -> jnp.ndarray:
    """logits (B, V) -> tokens (B,)."""
    logits = logits.astype(jnp.float32)
    if temperature <= 0.0:
        return greedy(logits)
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, _NEG, logits)
    if top_p > 0.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest logit value still inside the nucleus
        keep = cum - probs < top_p                  # first token always kept
        cutoff = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits < cutoff, _NEG, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def generate(api, params, cfg, cache, first_token, *, steps: int,
             start_pos: int, key=None, temperature: float = 0.0,
             top_k: int = 0, top_p: float = 0.0, force_window: int = 0):
    """Autoregressive generation loop (lax.scan — jit-able end to end).

    first_token: (B, 1) int32 from prefill. Returns (tokens (B, steps),
    final cache)."""
    B = first_token.shape[0]
    key = key if key is not None else jax.random.PRNGKey(0)

    def step(carry, i):
        tok, cache, k = carry
        logits, cache = api.decode_step(
            params, cfg, cache, {"token": tok, "pos": start_pos + i},
            force_window=force_window)
        k, sub = jax.random.split(k)
        nxt = sample(sub, logits[:, -1, :], temperature=temperature,
                     top_k=top_k, top_p=top_p)[:, None]
        return (nxt, cache, k), nxt[:, 0]

    (_, cache, _), toks = jax.lax.scan(
        step, (first_token, cache, key),
        jnp.arange(steps, dtype=jnp.int32))
    return toks.T, cache                          # (B, steps)
