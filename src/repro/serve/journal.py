"""Write-ahead request journal: crash-recoverable serving state.

The engine appends one record per request-lifecycle event to a single
journal file; after a crash (kill -9 included) a fresh engine replays the
journal and resubmits every submitted-but-unfinished request with its
already-generated tokens as resume state, so decode continues through the
engine's normal resume machinery **bit-identically** (the per-request
sample counter continues from ``len(generated)``, exactly as the
evict-recompute and swap paths already guarantee).

File format — the append-only sibling of ``train/checkpoint.py``'s
atomic-rename discipline (same magic+length+CRC framing, applied
per *record* because a journal grows in place instead of being replaced):

  * 8-byte file magic ``RPJRNL01``;
  * then records, each ``u32 payload_len | u32 crc32(payload) | payload``
    with a msgpack-encoded dict payload carrying at least ``{"t": kind}``.

Durability contract:

  * ``submit`` / ``finish`` records are flushed + fsync'd immediately —
    an acknowledged request is never lost, and a finished/shed/
    quarantined request is never resurrected;
  * ``token`` records buffer in memory and are flushed + fsync'd once
    per engine step (``commit``) — a crash loses at most the current
    step's tokens, which replay regenerates deterministically.

Replay reads sequentially and **stops at the first torn or corrupt
record** (short header, short payload, CRC mismatch, undecodable
msgpack): everything before the tear is trusted, everything after is
discarded — a kill mid-append therefore truncates to the last durable
event instead of poisoning recovery.  The next engine appending to the
same file first truncates the torn tail so the file stays parseable.

Record kinds:

  ``submit``  — full request spec (prompt, horizon, sampling, SLOs);
  ``token``   — one emitted token (id, token);
  ``finish``  — terminal: ``reason`` in {"length", "eos", "deadline",
                "ttft_slo", "quarantined:*", "shed"}.
"""

from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import msgpack
import numpy as np

from repro.serve.request import Request, SamplingParams

__all__ = ["RequestJournal", "JournalState", "replay_journal"]

_FILE_MAGIC = b"RPJRNL01"
_REC_FMT = "<II"                       # payload length, payload CRC32
_REC_LEN = struct.calcsize(_REC_FMT)
# sanity bound: no single record (even a long-prompt submit) approaches
# this; a length field beyond it means we are reading garbage
_MAX_RECORD = 64 * 1024 * 1024


def _pack_request(req: Request) -> dict:
    s = req.sampling
    return {
        "t": "submit", "id": req.id,
        "prompt": np.asarray(req.prompt, np.int32).tobytes(),
        "max_new_tokens": int(req.max_new_tokens),
        "eos_id": None if req.eos_id is None else int(req.eos_id),
        "arrival_step": int(req.arrival_step),
        "deadline_s": None if req.deadline_s is None else float(req.deadline_s),
        "ttft_slo_s": None if req.ttft_slo_s is None else float(req.ttft_slo_s),
        "sampling": {"temperature": float(s.temperature),
                     "top_k": int(s.top_k), "top_p": float(s.top_p),
                     "seed": int(s.seed)},
    }


class RequestJournal:
    """Append-only WAL over one file; see module docstring.  Opened for
    append: an existing journal (e.g. after a crash) is first scanned,
    its torn tail (if any) truncated away, and new records continue after
    the last durable one — replay then sees one coherent history across
    engine generations."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fresh = not os.path.exists(path)
        if not fresh:
            # truncate a torn tail from the previous generation so our
            # appends don't land after unparseable bytes
            good = _scan(path)[1]
            self._f = open(path, "r+b")
            self._f.truncate(good)
            self._f.seek(good)
        else:
            self._f = open(path, "wb")
            self._f.write(_FILE_MAGIC)
        self._pending: List[bytes] = []
        if fresh:
            self._fsync()

    # -- low-level -----------------------------------------------------------

    def _frame(self, payload: dict) -> bytes:
        raw = msgpack.packb(payload, use_bin_type=True)
        return struct.pack(_REC_FMT, len(raw), zlib.crc32(raw)) + raw

    def _fsync(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())

    def _append_durable(self, payload: dict) -> None:
        """Write buffered tokens first (order matters for replay), then
        the record, then fsync — the record is durable on return."""
        self.commit(sync=False)
        self._f.write(self._frame(payload))
        self._fsync()

    # -- engine-facing API ---------------------------------------------------

    def log_submit(self, req: Request) -> None:
        """Durable on return: an acknowledged submit survives kill -9."""
        self._append_durable(_pack_request(req))

    def log_token(self, req_id: str, token: int) -> None:
        """Buffered; durable at the next ``commit``/``log_finish`` — a
        crash may lose the current step's tokens, which replay
        regenerates deterministically."""
        self._pending.append(self._frame(
            {"t": "token", "id": req_id, "tok": int(token)}))

    def log_finish(self, req_id: str, reason: str) -> None:
        """Durable on return: a finished/shed/quarantined request is
        never replayed."""
        self._append_durable({"t": "finish", "id": req_id, "reason": reason})

    def commit(self, sync: bool = True) -> None:
        """Flush buffered token records (once per engine step)."""
        if self._pending:
            self._f.write(b"".join(self._pending))
            self._pending.clear()
            if sync:
                self._fsync()

    def close(self) -> None:
        if not self._f.closed:
            self.commit()
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------

@dataclass
class JournalState:
    """What a journal scan recovered."""

    submitted: Dict[str, dict] = field(default_factory=dict)  # id -> spec
    tokens: Dict[str, List[int]] = field(default_factory=dict)
    finished: Dict[str, str] = field(default_factory=dict)    # id -> reason
    torn: bool = False             # a torn/corrupt tail was discarded
    records: int = 0

    @property
    def unfinished_ids(self) -> List[str]:
        """Submitted-but-unfinished ids, in original submit order (the
        replayed engine resubmits in this order, preserving FIFO)."""
        return [i for i in self.submitted if i not in self.finished]

    def unfinished_requests(self) -> List[Request]:
        """Reconstruct every unfinished request for resubmission.  A
        request with journaled tokens comes back as a *resume* request —
        prompt extended by its generated tokens, ``resume`` carrying the
        original prompt length — so the engine's existing recompute path
        continues decode with the sample counter at ``len(generated)``:
        bit-identical to never having crashed."""
        out: List[Request] = []
        for rid in self.unfinished_ids:
            spec = self.submitted[rid]
            prompt = np.frombuffer(spec["prompt"], np.int32)
            gen = self.tokens.get(rid, [])
            resume = None
            if gen:
                resume = {"generated": list(gen),
                          "prompt_len": int(prompt.shape[0])}
                prompt = np.concatenate(
                    [prompt, np.asarray(gen, np.int32)])
            s = spec["sampling"]
            out.append(Request(
                id=rid, prompt=prompt,
                max_new_tokens=int(spec["max_new_tokens"]),
                sampling=SamplingParams(
                    temperature=float(s["temperature"]),
                    top_k=int(s["top_k"]), top_p=float(s["top_p"]),
                    seed=int(s["seed"])),
                eos_id=spec["eos_id"],
                arrival_step=0,            # replay admits immediately
                deadline_s=spec.get("deadline_s"),
                ttft_slo_s=spec.get("ttft_slo_s"),
                resume=resume))
        return out


def _scan(path: str) -> Tuple[List[dict], int]:
    """Sequentially decode records; returns ``(payloads, good_bytes)``
    where ``good_bytes`` is the offset just past the last intact record
    (the truncation point for append-after-crash)."""
    with open(path, "rb") as f:
        raw = f.read()
    if raw[:len(_FILE_MAGIC)] != _FILE_MAGIC:
        raise ValueError(f"{path}: not a request journal "
                         f"(bad magic {raw[:8]!r})")
    out: List[dict] = []
    off = len(_FILE_MAGIC)
    while off + _REC_LEN <= len(raw):
        length, crc = struct.unpack_from(_REC_FMT, raw, off)
        body = raw[off + _REC_LEN: off + _REC_LEN + length]
        if length > _MAX_RECORD or len(body) != length \
                or zlib.crc32(body) != crc:
            break                          # torn tail: stop, trust prefix
        try:
            payload = msgpack.unpackb(body, raw=False)
        except Exception:
            break
        out.append(payload)
        off += _REC_LEN + length
    return out, off


def replay_journal(path: str) -> JournalState:
    """Scan ``path`` and fold its records into a :class:`JournalState`.
    Unknown record kinds are skipped (forward compatibility); a torn tail
    sets ``state.torn`` and is otherwise ignored."""
    payloads, good = _scan(path)
    state = JournalState()
    state.torn = good < os.path.getsize(path)
    for p in payloads:
        kind = p.get("t")
        if kind == "submit":
            # a re-submit (e.g. a client retrying a shed request under
            # the same id) restarts that id's history: earlier tokens and
            # terminal records belong to the closed incarnation
            state.submitted[p["id"]] = p
            state.tokens.pop(p["id"], None)
            state.finished.pop(p["id"], None)
        elif kind == "token":
            state.tokens.setdefault(p["id"], []).append(int(p["tok"]))
        elif kind == "finish":
            state.finished[p["id"]] = p["reason"]
        state.records += 1
    return state
