"""Serving metrics: throughput, time-to-first-token, slot occupancy.

Host-side counters only — nothing here enters jit.  The engine calls the
record hooks; ``summary()`` folds them into the dict that
``benchmarks/serving_bench.py`` persists to ``BENCH_serving.json``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List


@dataclasses.dataclass
class EngineMetrics:
    num_slots: int
    pool_blocks: int = 0                      # physical cache blocks (paged:
                                              # real blocks; lanes otherwise)
    started: float = dataclasses.field(default_factory=time.perf_counter)
    finished_at: float = 0.0
    decode_steps: int = 0
    decode_tokens: int = 0                    # tokens sampled in decode steps
    prefill_tokens: int = 0                   # real (unpadded) prompt tokens
    requests_admitted: int = 0
    requests_finished: int = 0
    occupancy_sum: float = 0.0                # sum over steps of active/slots
    block_util_sum: float = 0.0               # sum over steps of used/pool
    peak_in_flight: int = 0                   # max resident requests
    parked_events: int = 0                    # block-grant failures (paged)
    evictions: int = 0                        # livelock-breaking evictions
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    first_step_s: float = 0.0                 # jit-compile-laden first step
    steady_decode_s: float = 0.0              # decode wall time past step 1

    def record_admit(self, prompt_len: int) -> None:
        self.requests_admitted += 1
        self.prefill_tokens += prompt_len

    def record_decode_step(self, active: int, tokens_out: int,
                           elapsed_s: float, *, in_flight: int = 0,
                           blocks_in_use: int = 0) -> None:
        if self.decode_steps == 0:
            self.first_step_s = elapsed_s
        else:
            self.steady_decode_s += elapsed_s
        self.decode_steps += 1
        self.decode_tokens += tokens_out
        self.occupancy_sum += active / max(self.num_slots, 1)
        self.block_util_sum += blocks_in_use / max(self.pool_blocks, 1)
        self.peak_in_flight = max(self.peak_in_flight, in_flight or active)

    def record_park(self) -> None:
        self.parked_events += 1

    def record_evict(self) -> None:
        self.evictions += 1

    def record_finish(self, ttft_s: float) -> None:
        self.requests_finished += 1
        self.ttft_s.append(ttft_s)
        self.finished_at = time.perf_counter()

    def summary(self) -> Dict[str, float]:
        span = (self.finished_at or time.perf_counter()) - self.started
        steady_steps = max(self.decode_steps - 1, 1)
        return {
            "requests": self.requests_finished,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "wall_s": span,
            "tok_per_s": self.decode_tokens / span if span > 0 else 0.0,
            # steady-state decode rate: excludes the jit-compile first step
            "steady_tok_per_s": (
                self.decode_tokens * (steady_steps / max(self.decode_steps, 1))
                / self.steady_decode_s if self.steady_decode_s > 0 else 0.0),
            "mean_ttft_s": (sum(self.ttft_s) / len(self.ttft_s)
                            if self.ttft_s else 0.0),
            "max_ttft_s": max(self.ttft_s) if self.ttft_s else 0.0,
            "mean_occupancy": (self.occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0),
            # block-level utilization: the paged pool's win shows up here —
            # lanes can sit near-full while blocks (actual HBM) do not
            "mean_block_utilization": (
                self.block_util_sum / self.decode_steps
                if self.decode_steps else 0.0),
            "pool_blocks": self.pool_blocks,
            "peak_in_flight": self.peak_in_flight,
            "parked_events": self.parked_events,
            "evictions": self.evictions,
        }
