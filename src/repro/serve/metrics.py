"""Serving metrics: throughput, latency percentiles, slot/block occupancy.

Host-side counters only — nothing here enters jit.  The engine calls the
record hooks; ``summary()`` folds them into the dict that
``benchmarks/serving_bench.py`` persists to ``BENCH_serving.json``.

Latency is reported as distributions, not just means: TTFT (submit ->
first token, one sample per finished request) and inter-token latency
(wall time of one batched decode step — every active request receives its
next token at the step boundary, so the step time IS each stream's
per-token latency) both feed ``repro.obs.Histogram`` reservoirs, and
``summary()`` exposes p50/p95/p99 for each.

Wall-clock accounting: ``wall_s`` spans from construction (or reset) to
the **last recorded event** — decode steps and retires both advance the
clock, so work after the final request finish (or a run where nothing
finishes at all) is priced into ``tok_per_s`` instead of silently
dropped.  ``steady_tok_per_s`` excludes the jit-compile-laden first decode
step: the steady token count is the total scaled by (steps−1)/steps, and
a run with a single decode step has no steady-state to report (0.0).

Summary fields
==============
``requests``              finished request count
``decode_steps``          batched decode steps executed
``decode_tokens``         tokens sampled across decode steps
``prefill_tokens``        real (unpadded) prompt tokens prefilled
``wall_s``                construction -> last recorded event
``tok_per_s``             decode_tokens / wall_s
``steady_tok_per_s``      decode rate excluding the first (compile) step
``mean_ttft_s``           mean submit -> first-token latency
``max_ttft_s``            worst TTFT
``ttft_p50/p95/p99_s``    TTFT percentiles (reservoir; exact below 4096
                          requests)
``itl_p50/p95/p99_s``     inter-token latency percentiles over decode
                          steps
``mean_occupancy``        mean active-lanes / num_slots per step
``mean_block_utilization``mean used-blocks / pool_blocks per step (the
                          paged pool's HBM win shows up here — lanes can
                          sit near-full while blocks do not)
``pool_blocks``           physical cache blocks (paged; lanes otherwise)
``peak_in_flight``        max resident requests observed
``parked_events``         block-grant failures (paged)
``evictions``             livelock-breaking evictions (recompute fallback)
``share_hits``            admissions that mapped >= 1 shared prefix block
``full_prompt_hits``      admissions that skipped prefill entirely (whole
                          prompt matched a live chain)
``shared_blocks``         blocks mapped read-only instead of allocated
``cow_copies``/``cow_bytes``       copy-on-write block copies / bytes moved
``swap_outs``/``swap_out_bytes``   lanes swapped to host / HBM bytes freed
``swap_ins``/``swap_in_bytes``     lanes restored from host / bytes refilled
``mean_fragmentation``    mean free-list shredding per step ((runs−1)/
                          (free−1) from ``BlockAllocator``; 0 contiguous,
                          1 fully shredded)
``peak_fragmentation``    worst per-step fragmentation observed
``requests_submitted``    submits the engine accepted (verdict "ok")
``shed``                  submits rejected by backpressure (bounded queue)
``deadline_misses``       SLO cancellations (whole-request OR first-token)
``ttft_slo_misses``       subset of the above where TTFT was the miss
``quarantined``           poisoned/malformed requests parked (total; the
                          per-reason split lives on ``quarantined`` dict)
``deadline_miss_rate``    deadline_misses / requests_submitted
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

from repro.obs import Histogram


@dataclasses.dataclass
class EngineMetrics:
    num_slots: int
    pool_blocks: int = 0                      # physical cache blocks (paged:
                                              # real blocks; lanes otherwise)
    started: float = dataclasses.field(default_factory=time.perf_counter)
    last_event_at: float = 0.0                # latest decode step OR finish
    decode_steps: int = 0
    decode_tokens: int = 0                    # tokens sampled in decode steps
    prefill_tokens: int = 0                   # real (unpadded) prompt tokens
    requests_admitted: int = 0
    requests_finished: int = 0
    occupancy_sum: float = 0.0                # sum over steps of active/slots
    block_util_sum: float = 0.0               # sum over steps of used/pool
    peak_in_flight: int = 0                   # max resident requests
    parked_events: int = 0                    # block-grant failures (paged)
    evictions: int = 0                        # livelock-breaking evictions
    share_hits: int = 0                       # admissions sharing >=1 block
    full_prompt_hits: int = 0                 # prefill skipped entirely
    shared_blocks: int = 0                    # blocks mapped, not allocated
    cow_copies: int = 0
    cow_bytes: int = 0
    swap_outs: int = 0
    swap_out_bytes: int = 0
    swap_ins: int = 0
    swap_in_bytes: int = 0
    frag_sum: float = 0.0                     # sum over steps of pool frag
    peak_fragmentation: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    ttft_hist: Histogram = dataclasses.field(default_factory=Histogram)
    itl_hist: Histogram = dataclasses.field(default_factory=Histogram)
    first_step_s: float = 0.0                 # jit-compile-laden first step
    steady_decode_s: float = 0.0              # decode wall time past step 1
    # fault-tolerance accounting (requests, not steps):
    requests_submitted: int = 0               # accepted submits (verdict ok)
    requests_shed: int = 0                    # backpressure rejections
    deadline_misses: int = 0                  # SLO cancellations, either kind
    ttft_slo_misses: int = 0                  # subset: first-token SLO
    quarantined: Dict[str, int] = dataclasses.field(default_factory=dict)

    def record_admit(self, prompt_len: int) -> None:
        self.requests_admitted += 1
        self.prefill_tokens += prompt_len

    def record_decode_step(self, active: int, tokens_out: int,
                           elapsed_s: float, *, in_flight: int = 0,
                           blocks_in_use: int = 0,
                           fragmentation: float = 0.0) -> None:
        """One batched decode step: ``active`` lanes produced
        ``tokens_out`` tokens in ``elapsed_s`` wall seconds."""
        if self.decode_steps == 0:
            self.first_step_s = elapsed_s
        else:
            self.steady_decode_s += elapsed_s
            # the first step's latency is dominated by jit compilation —
            # recording it would poison the p99 of every short run
            self.itl_hist.add(elapsed_s)
        self.decode_steps += 1
        self.decode_tokens += tokens_out
        self.occupancy_sum += active / max(self.num_slots, 1)
        self.block_util_sum += blocks_in_use / max(self.pool_blocks, 1)
        self.frag_sum += fragmentation
        self.peak_fragmentation = max(self.peak_fragmentation, fragmentation)
        self.peak_in_flight = max(self.peak_in_flight, in_flight or active)
        self.last_event_at = time.perf_counter()

    def record_park(self) -> None:
        self.parked_events += 1

    def record_evict(self) -> None:
        self.evictions += 1

    def record_share(self, blocks: int, full_hit: bool) -> None:
        self.share_hits += 1
        self.shared_blocks += blocks
        self.full_prompt_hits += bool(full_hit)

    def record_cow(self, nbytes: int) -> None:
        self.cow_copies += 1
        self.cow_bytes += nbytes

    def record_swap_out(self, nbytes: int) -> None:
        self.swap_outs += 1
        self.swap_out_bytes += nbytes

    def record_swap_in(self, nbytes: int) -> None:
        self.swap_ins += 1
        self.swap_in_bytes += nbytes

    def record_finish(self, ttft_s: float = None) -> None:
        """``ttft_s=None`` counts the finish without a TTFT sample — an
        SLO-cancelled request that never produced a first token has no
        TTFT to report (recording the deadline value instead would poison
        the percentiles)."""
        self.requests_finished += 1
        if ttft_s is not None:
            self.ttft_s.append(ttft_s)
            self.ttft_hist.add(ttft_s)
        self.last_event_at = time.perf_counter()

    def record_submit(self) -> None:
        self.requests_submitted += 1

    def record_shed(self) -> None:
        self.requests_shed += 1

    def record_deadline_miss(self, *, ttft: bool = False) -> None:
        """One SLO cancellation; ``ttft=True`` when the first-token SLO
        (rather than the whole-request deadline) was the one missed."""
        self.deadline_misses += 1
        self.ttft_slo_misses += bool(ttft)

    def record_quarantine(self, reason: str) -> None:
        self.quarantined[reason] = self.quarantined.get(reason, 0) + 1

    def summary(self) -> Dict[str, float]:
        # span to the LAST recorded event, not the last request finish:
        # decode steps after the final finish (and runs where no request
        # ever finishes) must still be priced into tok_per_s.  With no
        # events at all, fall back to "now".
        span = (self.last_event_at or time.perf_counter()) - self.started
        # steady-state excludes the compile-laden first step; with a single
        # decode step there is no steady state (the old (steps-1)/steps
        # scaling degenerated at decode_steps == 1)
        if self.decode_steps > 1 and self.steady_decode_s > 0:
            steady_tokens = (self.decode_tokens *
                             (self.decode_steps - 1) / self.decode_steps)
            steady = steady_tokens / self.steady_decode_s
        else:
            steady = 0.0
        th, ih = self.ttft_hist, self.itl_hist
        return {
            "requests": self.requests_finished,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "prefill_tokens": self.prefill_tokens,
            "wall_s": span,
            "tok_per_s": self.decode_tokens / span if span > 0 else 0.0,
            "steady_tok_per_s": steady,
            "mean_ttft_s": (sum(self.ttft_s) / len(self.ttft_s)
                            if self.ttft_s else 0.0),
            "max_ttft_s": max(self.ttft_s) if self.ttft_s else 0.0,
            "ttft_p50_s": th.percentile(50),
            "ttft_p95_s": th.percentile(95),
            "ttft_p99_s": th.percentile(99),
            "itl_p50_s": ih.percentile(50),
            "itl_p95_s": ih.percentile(95),
            "itl_p99_s": ih.percentile(99),
            "mean_occupancy": (self.occupancy_sum / self.decode_steps
                               if self.decode_steps else 0.0),
            # block-level utilization: the paged pool's win shows up here —
            # lanes can sit near-full while blocks (actual HBM) do not
            "mean_block_utilization": (
                self.block_util_sum / self.decode_steps
                if self.decode_steps else 0.0),
            "pool_blocks": self.pool_blocks,
            "peak_in_flight": self.peak_in_flight,
            "parked_events": self.parked_events,
            "evictions": self.evictions,
            "share_hits": self.share_hits,
            "full_prompt_hits": self.full_prompt_hits,
            "shared_blocks": self.shared_blocks,
            "cow_copies": self.cow_copies,
            "cow_bytes": self.cow_bytes,
            "swap_outs": self.swap_outs,
            "swap_out_bytes": self.swap_out_bytes,
            "swap_ins": self.swap_ins,
            "swap_in_bytes": self.swap_in_bytes,
            "mean_fragmentation": (self.frag_sum / self.decode_steps
                                   if self.decode_steps else 0.0),
            "peak_fragmentation": self.peak_fragmentation,
            "requests_submitted": self.requests_submitted,
            "shed": self.requests_shed,
            "deadline_misses": self.deadline_misses,
            "ttft_slo_misses": self.ttft_slo_misses,
            "quarantined": int(sum(self.quarantined.values())),
            # rate over accepted submits: either-SLO cancellations per
            # request the engine agreed to serve (sheds excluded — they
            # never entered an SLO window)
            "deadline_miss_rate": (
                self.deadline_misses / self.requests_submitted
                if self.requests_submitted else 0.0),
        }
