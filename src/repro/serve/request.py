"""Request / generation state for the continuous-batching forecast engine.

A ``Request`` is one client's forecast query: a tokenized prompt (the
quantized history window in the FedTime serving story), a generation
budget, and per-request sampling parameters.  ``GenState`` is the engine's
per-slot mutable bookkeeping while the request is in flight; it never
enters jit — everything the compiled step sees is packed into fixed-shape
batch arrays by the engine.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

# streaming callback: (request_id, token, is_last) fired per generated token
StreamFn = Callable[[str, int, bool], None]


@dataclasses.dataclass
class SamplingParams:
    """Per-request sampling knobs, routed through ``sampling.sample_vec``
    inside the compiled serve step (arrays, never static — the request mix
    changes without re-jit).  ``temperature <= 0`` decodes greedily."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One forecast-serving request."""
    id: str
    prompt: Sequence[int]                     # tokenized history window
    max_new_tokens: int                       # forecast horizon in tokens
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    eos_id: Optional[int] = None              # optional stop token
    arrival_step: int = 0                     # earliest engine step admitting
    stream: Optional[StreamFn] = None         # per-token streaming callback
    # SLOs, measured on the engine's clock from the request's FIRST submit
    # (shed-and-retried requests restart their window; evict/requeue and
    # journal-replay resumes keep the original):
    deadline_s: Optional[float] = None        # whole-request completion SLO
    ttft_slo_s: Optional[float] = None        # first-token SLO
    # engine-internal (eviction/recompute): a request re-queued mid-decode
    # carries its already-generated tokens in the prompt; ``resume`` records
    # {"generated": [...], "prompt_len": orig} so emitted output, sampling
    # counters and the finished record stay those of the original request
    resume: Optional[dict] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"request {self.id}: prompt must be a non-empty "
                             f"1-D token sequence")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.id}: max_new_tokens must be >= 1")
        for name in ("deadline_s", "ttft_slo_s"):
            v = getattr(self, name)
            if v is not None and not (float(v) > 0.0):
                raise ValueError(
                    f"request {self.id}: {name} must be > 0 when set")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_tokens(self) -> int:
        """Worst-case footprint: prompt + full horizon (admission budget).
        A resumed request's prompt carries its already-generated tokens,
        which its (unchanged, original) horizon already counts — subtract
        them so eviction/recompute never inflates the budget a request
        was admitted under (it would become permanently unadmittable
        against a tight ``max_tokens_in_flight``)."""
        resumed = len(self.resume["generated"]) if self.resume else 0
        return self.prompt_len - resumed + self.max_new_tokens


@dataclasses.dataclass
class GenState:
    """Per-slot in-flight state (host side)."""
    request: Request
    slot: int
    pos: int                                  # position of the NEXT decode
    last_token: int                           # token fed to the next step
    generated: List[int] = dataclasses.field(default_factory=list)
    steps_done: int = 0                       # tokens sampled so far
    admitted_step: int = 0
    admitted_time: float = 0.0
    first_token_time: float = 0.0

    @property
    def remaining(self) -> int:
        return self.request.max_new_tokens - len(self.generated)

    def emit(self, token: int, *, is_last: bool, now: float) -> None:
        if not self.generated:
            self.first_token_time = now
        self.generated.append(int(token))
        if self.request.stream is not None:
            self.request.stream(self.request.id, int(token), is_last)


@dataclasses.dataclass
class FinishedRequest:
    """Engine output record for one retired request.  ``reason`` is
    ``"length"``/``"eos"`` for clean completions, ``"deadline"``/
    ``"ttft_slo"`` for SLO cancellations (``tokens`` then holds whatever
    was generated before the miss)."""
    id: str
    tokens: np.ndarray                        # (n_generated,) int32
    prompt_len: int
    admitted_step: int
    finished_step: int
    ttft_s: float                             # admission -> first token
    reason: str                               # "length"|"eos"|"deadline"|"ttft_slo"


@dataclasses.dataclass(frozen=True)
class SubmitVerdict:
    """What ``ForecastEngine.submit`` tells the caller happened.

    ``verdict``:
      * ``"ok"``          — queued (``shed_id`` names a *different*, older
        queued request this admit displaced, if any);
      * ``"shed"``        — the submitted request itself was shed by
        backpressure; retry after ``retry_after_s`` engine seconds;
      * ``"quarantined"`` — rejected at submit (malformed prompt); never
        queued, audited in ``engine.quarantined``.
    """
    id: str
    verdict: str                              # "ok" | "shed" | "quarantined"
    retry_after_s: float = 0.0                # shed: suggested resubmit delay
    shed_id: Optional[str] = None             # ok: queued victim it displaced
    reason: Optional[str] = None              # quarantined: audit reason

    @property
    def ok(self) -> bool:
        return self.verdict == "ok"


@dataclasses.dataclass(frozen=True)
class QuarantinedRequest:
    """Audit record for a poisoned/malformed request parked by the
    engine: why, when, and how far decode got before the screen fired."""
    id: str
    reason: str                    # "malformed_prompt" | "nonfinite_logits"
    step: int                      # engine step the quarantine fired on
    prompt_len: int
    generated: int                 # tokens emitted before quarantine
