"""Continuous-batching forecast-serving engine over the sharded decode path.

The step loop the ROADMAP's top open item asks for: requests are admitted
FIFO under token budgets (``scheduler``), prefilled into a free lane of the
preallocated cache pool (``cache_pool``), then decoded *together* by the one
compiled ragged ``serve_step`` — per-slot positions, per-slot sampling
params, inactive lanes masked and frozen — until each request hits its
horizon or stop token and its lane is recycled.  Batch composition changes
every step; the compiled step signature never does (asserted by
``num_step_signatures``), which is what lets one jit serve an arbitrary
request trace.

Cache layout: uniform attention-ring families (dense/moe without
local/global alternation) default to the **paged block pool** — one shared
block pool plus per-lane block tables, so a lane only pins the blocks its
tokens occupy and short requests stop reserving full ``cache_len`` lanes
(REPRO_PAGED_KV=0 or ``paged=False`` restores contiguous lanes; SSM/hybrid
state lanes are always dense).  Paged decode grants blocks on demand as a
request's write position crosses a block boundary; on pool exhaustion the
request **parks** (its lane masked inactive, its blocks and neighbours
untouched) until frees arrive, and if *every* resident is parked the
youngest is moved out of the pool so the engine never livelocks while
holding blocks hostage.

Prefix sharing (``share_prefixes``, default on for paged pools /
REPRO_PREFIX_SHARE=0 disables): admission consults the pool's prefix-hash
index.  A whole-prompt hit maps every prefix block read-only (refcount
bump, zero new blocks) and skips prefill entirely — the chain's stored
last-token logits seed the first sample, so a cluster of users replaying
the same history costs one prefill total.  A partial block-aligned hit
shares the matched blocks and prefills as usual, with the shared blocks
masked out of the insert scatter (the donor's data is bit-identical —
deterministic prefill at equal positions).  The first write that would
land in a block with refcount > 1 copy-on-writes it in the grant pass:
fresh block, device tile copy, table remap, decref.  Admission pricing
(``blocks_needed``) counts only unshared blocks, so sharers admit even
when the free list alone couldn't cover them.

Swap tier (``swap_tier``, default on for paged pools / REPRO_SWAP_TIER=0
disables): the livelock-breaker snapshots the victim lane's logical ring
on device (async gather — it drains to host np arrays behind later decode
steps), frees its blocks, and requeues the request; on re-admission the
saved ring is re-inserted through the same compiled insert and decode
resumes bit-exactly where it left off — no recompute, TTFT keeps the
original submit time.  Evict-and-recompute (``_evict``) remains the final
fallback (swap tier off, or the handle is gone).  Same-tick victims are
requeued in one batch ordered by original submit order, so multi-eviction
ticks preserve FIFO.

Decode composes with the whole serving stack: fused flash-decode kernels
(``REPRO_FLASH_DECODE``; block tables ride a scalar-prefetch operand), int8
caches (``REPRO_KV_INT8``), and seq-sharded cache layouts
(``REPRO_CACHE_SHARD=seq`` under an active mesh — rings shard the slot
axis, paged pools the block axis, with the same pmax/psum combine).
Shared blocks change none of it: tables are read-only to the kernels, so a
physical block appearing in several tables just streams the same tile to
each sharer.

    engine = ForecastEngine(cfg, params, num_slots=8, cache_len=256)
    engine.submit(Request(id="r0", prompt=toks, max_new_tokens=32))
    done = engine.run()              # {id: FinishedRequest}

Fault tolerance (the serving mirror of ``repro.fault``'s training story):

  * **SLOs** — requests may carry ``deadline_s`` (whole-request) and
    ``ttft_slo_s`` (first-token) windows, measured on the engine clock
    from first submit.  With a ``fault.clock.VirtualClock`` the engine
    advances ``step_time_s`` virtual seconds per tick (no ``time.sleep``
    anywhere); without one it reads ``time.perf_counter()``.  A sweep at
    the top of every tick cancels expired queued AND resident requests
    mid-decode with full reclamation — lane batch rows zeroed, blocks
    released (refcounts/partition preserved), swap handles dropped — and
    audits each as a ``serve.deadline_miss`` instant + a finished record
    with reason ``"deadline"``/``"ttft_slo"`` carrying partial tokens.
  * **Backpressure** — ``max_queue`` bounds the submit queue; on overflow
    the engine sheds the cheapest-to-retry candidate (fewest total
    tokens, newest-first on ties, NEVER a request past first token —
    resumes are exempt) and ``submit`` returns a ``SubmitVerdict`` with a
    deterministic ``retry_after_s`` hint instead of raising.
  * **Quarantine** — ``submit`` screens prompts against the vocab
    (malformed requests quarantine before touching the device);
    ``fault.guard.logits_finite`` runs inside the compiled step on every
    decode slice, and a lane going non-finite is quarantined alone: no
    token emitted, blocks released, neighbours' lanes untouched, audit in
    ``engine.quarantined`` + a flight-recorder repro bundle.  The chaos
    NaN injector (``engine.poison(id)``) rides the same step via a
    ``poison`` batch row, so arming it never adds a jit signature.
  * **Journal** — ``journal=`` (or ``REPRO_SERVE_JOURNAL``) write-ahead
    logs submits/tokens/finishes (``serve/journal.py``, per-record CRC +
    fsync); after a crash ``replay_journal(path).unfinished_requests()``
    resubmits every incomplete request with its generated tokens as
    resume state — decode continues bit-identically (the fold_in sample
    counter continues), zero lost or duplicated requests.

Env knobs (each the default for the corresponding ctor arg):
``REPRO_SERVE_MAX_QUEUE`` (int, 0 = unbounded), ``REPRO_SERVE_DEADLINE_S``
/ ``REPRO_SERVE_TTFT_SLO_S`` (floats, applied to requests that don't set
their own), ``REPRO_SERVE_STEP_S`` (virtual seconds per tick under a
virtual clock, default 0.05), ``REPRO_SERVE_JOURNAL`` (journal path).

Observability (``repro.obs``, ``REPRO_TRACE=0`` disables): every request
gets its own Perfetto track carrying the lifecycle
``req.submit -> req.queued -> req.prefill -> req.first_token ->
req.decode -> req.lifecycle -> req.retire`` (park/evict as instant
events, plus ``pool.share_hit`` / ``pool.cow_copy`` / ``pool.swap_out`` /
``pool.swap_in`` instants with byte counts whenever sharing or the swap
tier fire); each engine tick emits an ``engine.decode_step`` span (wrapped in
``jax.profiler.TraceAnnotation`` so host and XLA device traces line up)
plus a ``pool`` counter track (blocks in use / active lanes).  Exactly one
``req.lifecycle`` span is emitted per FINISHED request — eviction and
recompute re-emit the per-residency phases, never the lifecycle — so a
trace's lifecycle-span count always equals ``requests_finished``.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.fault.clock import VirtualClock
from repro.launch.steps import make_serve_step
from repro.models.registry import get_model
from repro.serve.cache_pool import (PAGED_FAMILIES, CachePool,
                                    PagedCachePool)
from repro.serve.journal import RequestJournal
from repro.serve.metrics import EngineMetrics
from repro.serve.request import (FinishedRequest, GenState,
                                 QuarantinedRequest, Request, SubmitVerdict)
from repro.serve.sampling import sample_vec
from repro.serve.scheduler import (FIFOScheduler, SchedulerConfig,
                                   bucket_len)

# families whose batch dict is {"tokens"} and whose decode path supports
# per-slot ragged positions (attention rings via attn_decode, SSM states
# via the serve-step freeze)
_SERVABLE = ("dense", "moe", "ssm", "hybrid")
_BUCKETABLE = ("dense", "moe")               # right-pad-safe prefill (causal
                                             # attention only, no recurrence)


class ForecastEngine:
    """Request-level serving engine: admit -> prefill-into-slot -> batched
    ragged decode -> retire."""

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 4,
                 cache_len: int = 256, max_tokens_in_flight: int = 0,
                 prefill_chunk: int = 0, prefill_bucket: int = 0,
                 force_window: int = 0, paged: Optional[bool] = None,
                 block_size: int = 0, pool_blocks: int = 0,
                 share_prefixes: Optional[bool] = None,
                 swap_tier: Optional[bool] = None,
                 clock: Optional[VirtualClock] = None,
                 step_time_s: Optional[float] = None,
                 max_queue: Optional[int] = None,
                 default_deadline_s: Optional[float] = None,
                 default_ttft_slo_s: Optional[float] = None,
                 journal=None):
        if cfg.family not in _SERVABLE:
            raise ValueError(f"family {cfg.family!r} not servable by the "
                             f"engine (supported: {_SERVABLE})")
        if prefill_bucket and cfg.family not in _BUCKETABLE:
            raise ValueError(f"prefill_bucket requires a causal-attention "
                             f"prefill (families {_BUCKETABLE}); "
                             f"{cfg.family!r} carries recurrent state "
                             f"through pad tokens")
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.prefill_bucket = prefill_bucket
        self.force_window = force_window
        if paged is None:                     # default on where eligible
            paged = (os.environ.get("REPRO_PAGED_KV", "1") != "0"
                     and cfg.family in PAGED_FAMILIES
                     and not cfg.local_global_alternating)
        self.paged = paged
        if paged:
            self.pool = PagedCachePool(cfg, num_slots, cache_len,
                                       block_size=block_size,
                                       pool_blocks=pool_blocks,
                                       force_window=force_window)
        else:
            if block_size or pool_blocks:
                raise ValueError("block_size/pool_blocks require paged=True")
            if share_prefixes or swap_tier:
                raise ValueError("share_prefixes/swap_tier require the "
                                 "paged pool")
            self.pool = CachePool(self.api, cfg, num_slots, cache_len,
                                  force_window=force_window)
        # CoW prefix sharing + host swap tier: paged-pool features, on by
        # default there (REPRO_PREFIX_SHARE=0 / REPRO_SWAP_TIER=0 or the
        # ctor args turn them off independently)
        self.share_prefixes = bool(paged and (
            share_prefixes if share_prefixes is not None
            else os.environ.get("REPRO_PREFIX_SHARE", "1") != "0"))
        self.swap_tier = bool(paged and (
            swap_tier if swap_tier is not None
            else os.environ.get("REPRO_SWAP_TIER", "1") != "0"))
        # swapped-out lanes: request id -> {"cache": leaves, "pos", "blocks"}
        # — leaves start as async device gathers and drain to host np arrays
        # behind later decode steps (see step())
        self.swap: Dict[str, dict] = {}
        self._swap_pending: List[str] = []
        # per-request submit sequence: multi-eviction ticks requeue in this
        # order, so FIFO survives same-tick victims (resumes keep the id)
        self._seq: Dict[str, int] = {}
        self.scheduler = FIFOScheduler(SchedulerConfig(
            max_tokens_in_flight=max_tokens_in_flight,
            prefill_chunk=prefill_chunk))
        self.metrics = EngineMetrics(num_slots,
                                     pool_blocks=self.pool.pool_blocks)
        self.step_count = 0
        self.finished: Dict[str, FinishedRequest] = {}
        self.slots: List[Optional[GenState]] = [None] * num_slots
        self._submit_time: Dict[str, float] = {}

        # -- fault tolerance (SLOs / shedding / quarantine / journal) ----
        def _env_f(name):
            v = os.environ.get(name, "")
            return float(v) if v else None
        self.clock = clock
        # virtual seconds one engine tick costs on the SLO clock; only the
        # virtual clock advances by it (wall mode reads perf_counter)
        self.step_time_s = (step_time_s if step_time_s is not None
                            else _env_f("REPRO_SERVE_STEP_S") or 0.05)
        self.max_queue = (max_queue if max_queue is not None
                          else int(os.environ.get("REPRO_SERVE_MAX_QUEUE",
                                                  "0")))
        self._default_deadline_s = (default_deadline_s
                                    if default_deadline_s is not None
                                    else _env_f("REPRO_SERVE_DEADLINE_S"))
        self._default_ttft_slo_s = (default_ttft_slo_s
                                    if default_ttft_slo_s is not None
                                    else _env_f("REPRO_SERVE_TTFT_SLO_S"))
        if journal is None:
            journal = os.environ.get("REPRO_SERVE_JOURNAL") or None
        self.journal: Optional[RequestJournal] = (
            RequestJournal(journal) if isinstance(journal, str) else journal)
        self.quarantined: Dict[str, QuarantinedRequest] = {}
        self.shed_log: Dict[str, float] = {}   # id -> retry_after_s hint
        self._poison: set = set()              # chaos: ids to NaN-inject
        self._poison_row = np.zeros((num_slots,), bool)
        # SLO windows anchor at the FIRST submit (requeues/resumes keep
        # it); a shed request's re-submit starts a fresh window
        self._slo_submit: Dict[str, float] = {}
        # global-attention rings must hold the whole sequence: dense/moe
        # without a (forced) sliding window, and hybrid, whose attention
        # layers are always global.  Windowed archs wrap by design; pure
        # SSM state is O(1).
        self._ring_is_global = (
            cfg.family in _BUCKETABLE and cfg.sliding_window == 0
            and not force_window) or cfg.family == "hybrid"

        # fixed-shape per-slot batch arrays — the ONLY thing the compiled
        # step sees; host-side admission/eviction just rewrites rows
        self._tok = np.zeros((num_slots, 1), np.int32)
        self._pos = np.full((num_slots,), -1, np.int32)
        self._temp = np.zeros((num_slots,), np.float32)
        self._topk = np.zeros((num_slots,), np.int32)
        self._topp = np.zeros((num_slots,), np.float32)
        self._key = np.zeros((num_slots, 2), np.uint32)
        self._t = np.zeros((num_slots,), np.int32)

        self._step_fn = jax.jit(
            make_serve_step(cfg, force_window=force_window, sampling=True,
                            guard=True),
            donate_argnums=(1,))

        def _prefill(params, tokens, true_len):
            return self.api.prefill(params, cfg, {"tokens": tokens},
                                    cache_len=cache_len,
                                    force_window=force_window,
                                    true_len=true_len)

        self._prefill_fn = jax.jit(_prefill)

        def _first(logits, key, temp, top_k, top_p, t):
            # same finite screen the decode step runs: a prompt whose
            # prefill already went non-finite quarantines at admission
            lg = logits[:, -1, :]
            ok = jnp.all(jnp.isfinite(lg))
            keys = jax.random.fold_in(key, t)[None]
            return sample_vec(keys, lg, temperature=temp[None],
                              top_k=top_k[None], top_p=top_p[None])[0], ok

        self._first_fn = jax.jit(_first)

    # -- public surface ------------------------------------------------------

    def submit(self, request: Request) -> SubmitVerdict:
        """Queue a request.  Structural impossibilities (footprint that
        could never admit) still raise — they are caller bugs; traffic
        conditions return a verdict instead: ``"quarantined"`` for
        malformed prompts (audited, never queued) and ``"shed"`` under
        backpressure (bounded ``max_queue``, cheapest-to-retry
        newest-first victim, never a request past first token)."""
        budget = self.scheduler.config.max_tokens_in_flight
        if budget > 0 and request.total_tokens > budget:
            # would never admit: run() would spin on it forever
            raise ValueError(
                f"request {request.id}: total tokens "
                f"({request.total_tokens}) exceed max_tokens_in_flight "
                f"({budget}) — it could never be admitted")
        footprint = max(request.total_tokens,
                        bucket_len(request.prompt_len, self.prefill_bucket))
        if self._ring_is_global and footprint > self.pool.cache_len:
            raise ValueError(
                f"request {request.id}: prompt + horizon (bucketed: "
                f"{footprint}) exceeds cache_len ({self.pool.cache_len})")
        if self.paged:
            need = self.pool.blocks_for(footprint)
            if need > self.pool.pool_blocks:
                # even alone it would park forever: reject at submit
                raise ValueError(
                    f"request {request.id}: needs {need} blocks, pool has "
                    f"{self.pool.pool_blocks}")
        # malformed-prompt screen: out-of-vocab ids would index garbage
        # embeddings (or crash a gather) — quarantine before any device
        # work, audited like a mid-decode poison
        prompt = np.asarray(request.prompt)
        if int(prompt.min()) < 0 or int(prompt.max()) >= self.cfg.vocab_size:
            self._quarantine_submit(request, "malformed_prompt")
            return SubmitVerdict(request.id, "quarantined",
                                 reason="malformed_prompt")
        if request.deadline_s is None:
            request.deadline_s = self._default_deadline_s
        if request.ttft_slo_s is None:
            request.ttft_slo_s = self._default_ttft_slo_s
        self._seq.setdefault(request.id, len(self._seq))
        shed_id = None
        if self.max_queue > 0 and request.resume is None and \
                self.scheduler.pending >= self.max_queue:
            victim = self._shed_victim(request)
            if victim is request:
                self._record_shed(request, queued=False)
                return SubmitVerdict(request.id, "shed",
                                     retry_after_s=self._retry_after_s())
            self.scheduler.remove(victim)
            self._record_shed(victim, queued=True)
            shed_id = victim.id
        if request.resume is None:            # eviction re-queues internally
            obs.instant("req.submit", track=f"req:{request.id}",
                        id=request.id, prompt_len=request.prompt_len,
                        max_new_tokens=request.max_new_tokens)
            if self.journal is not None:
                self.journal.log_submit(request)
            self.metrics.record_submit()
        self._submit_time[request.id] = time.perf_counter()
        # SLO anchor: resumes (journal replay, evict requeue) keep the
        # original window; a fresh submit — including a shed request's
        # retry — starts one
        res = request.resume or {}
        if request.resume is None:
            self._slo_submit[request.id] = self._now()
        else:
            self._slo_submit.setdefault(
                request.id,
                res.get("slo_submit") if res.get("slo_submit") is not None
                else self._now())
        self.scheduler.submit(request)
        return SubmitVerdict(request.id, "ok", shed_id=shed_id)

    def poison(self, request_id: str) -> None:
        """Chaos hook: NaN-inject this request's logits row on its next
        decode step (via the compiled step's ``poison`` batch input — no
        new jit signature).  The guard then quarantines the lane."""
        self._poison.add(request_id)

    @property
    def active_requests(self) -> int:
        return sum(s is not None for s in self.slots)

    # -- SLOs / shedding / quarantine ----------------------------------------

    def _now(self) -> float:
        """The engine's SLO clock: virtual when one was injected (chaos/
        CI — deadlines honored with zero ``time.sleep``), wall otherwise.
        Distinct from the wall-clock TTFT/throughput metrics."""
        return (self.clock.now() if self.clock is not None
                else time.perf_counter())

    def _retry_after_s(self) -> float:
        """Deterministic backoff hint for a shed request: roughly the
        engine-seconds needed to drain the current queue through the
        available lanes."""
        steps = self.scheduler.pending_tokens() / max(len(self.slots), 1)
        return self.step_time_s * (steps + 1.0)

    def _shed_victim(self, incoming: Request) -> Request:
        """Cheapest-to-retry, newest-first: fewest total tokens, ties to
        the latest submit sequence.  Only requests that have produced no
        token are candidates (queued resumes carry generated tokens and a
        paid-for TTFT — shedding them wastes finished work and breaks the
        'never past first token' contract), so the incoming request is
        always a candidate of last resort."""
        cands = [incoming] + [q for q in self.scheduler.queued()
                              if q.resume is None]
        return min(cands, key=lambda r: (r.total_tokens,
                                         -self._seq.get(r.id, 0)))

    def _record_shed(self, req: Request, *, queued: bool) -> None:
        retry = self._retry_after_s()
        self.metrics.record_shed()
        self.shed_log[req.id] = retry
        self._slo_submit.pop(req.id, None)
        obs.instant("serve.shed", track=f"req:{req.id}", id=req.id,
                    queued=queued, retry_after_s=retry,
                    queue_depth=self.scheduler.pending,
                    total_tokens=req.total_tokens)
        obs.counter("serve.shed", 1)
        if queued and self.journal is not None:
            # the victim's submit is journaled: close it so replay never
            # resurrects a request we told the client to retry
            self.journal.log_finish(req.id, "shed")

    def _quarantine_submit(self, req: Request, reason: str) -> None:
        """Park a request that failed the submit-time screen: audited,
        never queued, never touching the device."""
        self.quarantined[req.id] = QuarantinedRequest(
            req.id, reason, self.step_count, req.prompt_len, 0)
        self.metrics.record_quarantine(reason)
        self._audit_quarantine(req, reason, slot=-1, generated=0)

    def _quarantine_lane(self, st: GenState, reason: str) -> None:
        """Quarantine ONE resident lane mid-decode: no token emitted, the
        lane's batch row zeroed and its blocks released (refcounts and the
        partition invariant preserved — neighbours never notice), audit +
        flight-recorder repro bundle dumped."""
        req, slot = st.request, st.slot
        res = req.resume or {}
        self.quarantined[req.id] = QuarantinedRequest(
            req.id, reason, self.step_count,
            int(res.get("prompt_len", req.prompt_len)), len(st.generated))
        self.metrics.record_quarantine(reason)
        self._clear_lane_rows(slot)
        self._audit_quarantine(req, reason, slot=slot,
                               generated=len(st.generated))

    def _audit_quarantine(self, req: Request, reason: str, *, slot: int,
                          generated: int) -> None:
        self._poison.discard(req.id)
        self._slo_submit.pop(req.id, None)
        sp = req.sampling
        # the instant doubles as the repro bundle: enough of the request
        # (prompt head, sampling knobs, progress) rides into the flight
        # dump to replay the poisoned step offline
        obs.instant("serve.quarantine", track=f"req:{req.id}", id=req.id,
                    reason=reason, slot=slot, step=self.step_count,
                    prompt_len=req.prompt_len, generated=generated,
                    prompt_head=[int(t) for t in
                                 np.asarray(req.prompt)[:16]],
                    seed=sp.seed, temperature=sp.temperature)
        obs.counter(f"serve.quarantine.{reason}", 1)
        if self.journal is not None:
            self.journal.log_finish(req.id, f"quarantined:{reason}")
        obs.flight_maybe_dump("engine.quarantine")

    def _clear_lane_rows(self, slot: int) -> None:
        """Full lane reclamation: GenState gone, every batch row zeroed,
        blocks back to the pool (CoW refcounts handled by release)."""
        self.slots[slot] = None
        self._pos[slot] = -1
        self._tok[slot, 0] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 0.0
        self._key[slot] = 0
        self._t[slot] = 0
        self.pool.release(slot)

    def _expiry(self, req: Request, started: bool,
                now: float) -> Optional[str]:
        """Which SLO (if any) ``req`` has blown at ``now``.  Windows are
        measured from the FIRST submit; a request finishing exactly at
        its deadline is on time (strict >)."""
        t0 = self._slo_submit.get(req.id)
        if t0 is None:
            return None
        if req.deadline_s is not None and now - t0 > req.deadline_s:
            return "deadline"
        if req.ttft_slo_s is not None and not started \
                and now - t0 > req.ttft_slo_s:
            return "ttft_slo"
        return None

    def _slo_sweep(self) -> None:
        """Top of every tick: cancel expired queued and resident requests
        BEFORE admission, so the blocks and lanes a cancellation frees are
        grantable in the same tick (the grant pass hands them out in
        submit order — cancellation never reorders FIFO resumption)."""
        if not self._slo_submit:
            return
        now = self._now()

        def q_kind(req: Request) -> Optional[str]:
            started = bool((req.resume or {}).get("generated"))
            return self._expiry(req, started, now)

        for req in self.scheduler.cancel_where(
                lambda r: q_kind(r) is not None):
            self._cancel_queued(req, q_kind(req), now)
        for st in [s for s in self.slots if s is not None]:
            kind = self._expiry(st.request, bool(st.generated), now)
            if kind is not None:
                self._retire(st, kind)

    def _cancel_queued(self, req: Request, kind: str, now: float) -> None:
        """Deadline-cancel a request that is not resident: drop its swap
        handle (host tier reclamation), finish it with whatever it
        generated in prior residencies, audit the miss."""
        res = req.resume or {}
        if res.get("swap") in self.swap:
            self.swap.pop(res["swap"])
        gen = [int(t) for t in res.get("generated", [])]
        t0 = self._slo_submit.pop(req.id, None)
        self.metrics.record_deadline_miss(ttft=kind == "ttft_slo")
        first = res.get("first_token_time") or 0.0
        submit_t = (res.get("submitted")
                    or self._submit_time.get(req.id, now))
        ttft = (first - submit_t) if first else None
        self.metrics.record_finish(ttft)
        track = f"req:{req.id}"
        obs.instant("serve.deadline_miss", track=track, id=req.id,
                    kind=kind, queued=True, generated=len(gen),
                    waited_s=now - t0 if t0 is not None else 0.0)
        obs.counter(f"serve.deadline_miss.{kind}", 1)
        wall = time.perf_counter()
        obs.add_span("req.lifecycle", submit_t, wall, track=track,
                     id=req.id, reason=kind, tokens=len(gen),
                     ttft_s=ttft or 0.0)
        obs.instant("req.retire", track=track, id=req.id, reason=kind)
        if self.journal is not None:
            self.journal.log_finish(req.id, kind)
        self.finished[req.id] = FinishedRequest(
            id=req.id, tokens=np.asarray(gen, np.int32),
            prompt_len=int(res.get("prompt_len", req.prompt_len)),
            admitted_step=-1, finished_step=self.step_count,
            ttft_s=ttft or 0.0, reason=kind)

    @property
    def tokens_in_flight(self) -> int:
        return sum(s.request.total_tokens for s in self.slots
                   if s is not None)

    def num_step_signatures(self) -> int:
        """Compiled serve_step signatures so far — the engine's no-re-jit
        invariant is that this stays 1 across every admission/eviction."""
        return self._step_fn._cache_size()

    def step(self) -> None:
        """One engine tick: sweep SLOs (cancellations free capacity for
        this very tick), admit what fits, grow/park paged lanes, then one
        batched decode.  Under a virtual clock the tick ends by advancing
        ``step_time_s`` virtual seconds; the journal (if any) commits its
        buffered token records at the same boundary."""
        self._slo_sweep()
        free_blocks = self.pool.free_blocks if self.paged else -1
        blocks_needed = self._admit_blocks if self.paged else None
        for req in self.scheduler.admit(
                now_step=self.step_count,
                free_slots=self.pool.free_slots,
                tokens_in_flight=self.tokens_in_flight,
                free_blocks=free_blocks,
                blocks_needed=blocks_needed):
            try:
                self._admit(req)
            except RuntimeError:
                # share-aware pricing raced a chain invalidation (or the
                # pool shrank between pricing and grant): the admission was
                # rolled back — put the request back at the head and stop
                # admitting this tick
                self.scheduler.requeue_front([req])
                break
        if self.paged:
            self._grant_pass()
        self._decode()
        self.step_count += 1
        if self.journal is not None:
            self.journal.commit()
        if self.clock is not None:
            self.clock.advance(self.step_time_s)
        # drain swap-outs to host np arrays AFTER the decode dispatched —
        # the device gather overlaps the step instead of blocking it
        while self._swap_pending:
            handle = self.swap.get(self._swap_pending.pop())
            if handle is not None and not handle.get("host"):
                handle["cache"] = jax.tree.map(np.asarray, handle["cache"])
                handle["host"] = True

    def run(self, max_steps: int = 0) -> Dict[str, FinishedRequest]:
        """Drive steps until every submitted request retires."""
        while self.scheduler.pending or self.active_requests:
            if max_steps and self.step_count >= max_steps:
                raise RuntimeError(f"engine did not drain within "
                                   f"{max_steps} steps")
            self.step()
        return self.finished

    # -- internals -----------------------------------------------------------

    def _bucketed_len(self, req: Request) -> int:
        P = req.prompt_len
        Pb = bucket_len(P, self.prefill_bucket)
        if req.resume and self._ring_is_global and Pb > self.pool.cache_len:
            return P            # resumed prompts skip bucketing on overflow
        return Pb

    def _admit_blocks(self, req: Request) -> int:
        """Paged admission price: blocks covering the prefill ring extent
        (decode growth is granted on demand).  Share-aware: blocks served
        by a live prefix chain cost nothing — a whole-prompt hit admits
        free, which is what lets a cluster of identical histories oversubscribe
        the same pool bytes.  A swap-tier resume prices its saved extent."""
        res = req.resume or {}
        if self.swap_tier and res.get("swap") in self.swap:
            handle = self.swap[res["swap"]]
            return self.pool.blocks_for(min(handle["pos"],
                                            self.pool.ring_len))
        need = self.pool.blocks_for(self._bucketed_len(req))
        if self.share_prefixes:
            shared, full_hit, _ = self.pool.match_prefix(req.prompt)
            if full_hit:
                return 0
            need -= len(shared)
        return max(need, 0)

    def _admit(self, req: Request) -> None:
        track = f"req:{req.id}"
        t_admit = time.perf_counter()
        res = req.resume or {}
        obs.add_span("req.queued",
                     res.get("submitted")
                     or self._submit_time.get(req.id, t_admit), t_admit,
                     track=track, id=req.id)
        slot = self.pool.acquire()
        if self.swap_tier and res.get("swap") in self.swap:
            handle = self.swap.pop(res["swap"])
            try:
                self._swap_in(req, slot, handle)
            except RuntimeError:               # pool raced below the price
                self.swap[res["swap"]] = handle
                self.pool.release(slot)
                raise
            return
        P = req.prompt_len
        Pb = self._bucketed_len(req)
        shared: List[int] = []
        full_hit, chain_logits = False, None
        if self.paged:
            if self.share_prefixes:
                shared, full_hit, chain_logits = \
                    self.pool.match_prefix(req.prompt)
            try:
                self.pool.share_map(slot, shared)
                if not full_hit:
                    self.pool.grant_tail(
                        slot, len(shared),
                        self.pool.blocks_for(Pb) - len(shared))
            except RuntimeError:               # pool raced below the price
                self.pool.release(slot)        # decrefs any shared mapping
                raise
            if shared:
                self.metrics.record_share(len(shared), full_hit)
                obs.instant("pool.share_hit", track=track, id=req.id,
                            slot=slot, blocks=len(shared),
                            full_prompt=bool(full_hit),
                            bytes=len(shared) * self.pool.block_bytes)

        if full_hit and chain_logits is not None:
            # whole prompt lives in the pool already: zero prefill, zero
            # new blocks — the chain's stored last-token logits row seeds
            # the first sample exactly as a fresh prefill's would
            logits = jnp.asarray(chain_logits)[None, None]
            self.metrics.record_admit(0)
        else:
            toks = np.zeros((1, Pb), np.int32)
            toks[0, :P] = req.prompt
            # true_len rides along whenever bucketing is on (one bucketed
            # prefill signature even for exact-fit prompts); a resume that
            # skipped bucketing prefills at its exact length
            true_len = (jnp.asarray([P], jnp.int32)
                        if self.prefill_bucket and (Pb != P or not req.resume)
                        else None)
            with obs.span("req.prefill", device=True, track=track,
                          id=req.id, prompt_len=P, padded_len=Pb, slot=slot,
                          shared_blocks=len(shared),
                          resumed=req.resume is not None):
                cache1, logits = self._prefill_fn(self.params,
                                                  jnp.asarray(toks),
                                                  true_len)
                if self.paged:
                    # shared prefix blocks are read-only — the donor's data
                    # is bit-identical, so mask them out of the scatter
                    self.pool.insert(cache1, slot, skip_blocks=len(shared))
                else:
                    self.pool.insert(cache1, slot)
            self.metrics.record_admit(P)

        prior: List[int] = list(res.get("generated", []))
        sp = req.sampling
        base_key = np.asarray(jax.random.PRNGKey(sp.seed), np.uint32)
        # sample counter continues across eviction/recompute: token i of the
        # ORIGINAL request is always drawn from fold_in(key, i)
        tok0, ok0 = self._first_fn(
            logits, jnp.asarray(base_key),
            jnp.asarray(sp.temperature, jnp.float32),
            jnp.asarray(sp.top_k, jnp.int32),
            jnp.asarray(sp.top_p, jnp.float32),
            jnp.asarray(len(prior), jnp.int32))
        if not bool(ok0):
            # prefill already went non-finite: quarantine at admission,
            # BEFORE the prompt could be indexed as a prefix donor (a
            # poisoned chain would hand NaN logits to every sharer)
            self.quarantined[req.id] = QuarantinedRequest(
                req.id, "nonfinite_logits", self.step_count,
                int(res.get("prompt_len", req.prompt_len)), len(prior))
            self.metrics.record_quarantine("nonfinite_logits")
            self.pool.release(slot)
            self._audit_quarantine(req, "nonfinite_logits", slot=slot,
                                   generated=len(prior))
            return
        tok0 = int(tok0)
        if not full_hit and self.share_prefixes and req.resume is None:
            # index this prompt for future sharers (resumes carry
            # generated continuations — not reusable prompts)
            self.pool.register_prefix(
                slot, req.prompt, np.asarray(logits[0, -1]))

        now = time.perf_counter()
        st = GenState(request=req, slot=slot, pos=P, last_token=tok0,
                      generated=prior,
                      admitted_step=self.step_count, admitted_time=now)
        done = st.remaining == 1 or tok0 == req.eos_id
        first_of_original = not prior          # st.emit appends into `prior`
        st.emit(tok0, is_last=done, now=now)
        if self.journal is not None:
            self.journal.log_token(req.id, tok0)
        if first_of_original:
            obs.instant("req.first_token", track=track, id=req.id)
        if done:
            self._retire(st, "eos" if tok0 == req.eos_id else "length")
            return
        self.slots[slot] = st
        self._tok[slot, 0] = tok0
        self._pos[slot] = P
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        self._key[slot] = base_key
        self._t[slot] = len(prior) + 1        # last token came from prefill

    # -- paged block lifecycle ----------------------------------------------

    def _grant_pass(self) -> None:
        """Before each paged decode: make sure every resident lane's next
        write slot has a physical block IT OWNS.  A write block with
        refcount > 1 is copy-on-written first (sharers never mutate a
        donor's prefix; CoW failure parks like any grant failure); a sole
        owner whose ring wrapped back over indexed prefix content drops the
        stale chain entries before the write lands.  Grants collect into
        one device-side kv_pos reset; lanes that can't be granted park
        (masked inactive, no writes — a parked lane can never corrupt a
        neighbour).  If parking leaves nothing runnable, the youngest
        parked lane leaves the pool — swapped to the host tier when
        enabled, evicted to recompute otherwise — and the pass retries.
        Same-tick victims requeue in ONE batch ordered by original submit
        order, so multi-eviction ticks preserve FIFO and a resumed TTFT
        never resets."""
        victims: List[Request] = []
        while True:
            fresh: List[int] = []
            parked: List[int] = []
            # walk lanes in original-submit order, NOT slot-index order:
            # blocks freed mid-tick (an SLO cancellation, a retire) must
            # unpark waiting lanes FIFO — the oldest parked request gets
            # the first grant, whatever slot it happens to occupy
            order = sorted(
                (i for i, s in enumerate(self.slots) if s is not None),
                key=lambda i: self._seq.get(self.slots[i].request.id, 0))
            for i in order:
                st = self.slots[i]
                lb = (st.pos % self.pool.ring_len) // self.pool.block_size
                pb = int(self.pool.table[i, lb])
                if pb >= 0:
                    if self.pool.refcount(pb) > 1:
                        try:                   # shared write block: CoW
                            old, new = self.pool.cow(i, lb)
                        except RuntimeError:   # no block for the copy
                            self._park(i, st)
                            parked.append(i)
                            continue
                        self.metrics.record_cow(self.pool.block_bytes)
                        obs.instant("pool.cow_copy",
                                    track=f"req:{st.request.id}",
                                    id=st.request.id, slot=i, src=old,
                                    dst=new,
                                    bytes=self.pool.block_bytes)
                    elif st.pos >= self.pool.ring_len:
                        # sole owner wrapping over indexed prefix content
                        self.pool.invalidate_block(pb)
                    if self._pos[i] < 0:      # runnable now — unpark
                        self._pos[i] = st.pos
                    continue
                try:
                    fresh.append(self.pool.grant(i, lb))
                    if self._pos[i] < 0:
                        self._pos[i] = st.pos
                except RuntimeError:          # pool exhausted — park
                    self._park(i, st)
                    parked.append(i)
            self.pool.reset_blocks(fresh)
            runnable = any(s is not None and self._pos[i] >= 0
                           for i, s in enumerate(self.slots))
            if runnable or not parked:
                break
            if len(parked) == len([s for s in self.slots if s is not None]) \
                    and len(parked) == 1:
                raise RuntimeError(
                    f"paged pool too small: a single resident request "
                    f"cannot grow ({self.pool.pool_blocks} blocks of "
                    f"{self.pool.block_size})")
            victim = max(parked, key=lambda i: (
                self.slots[i].admitted_step,
                self._seq.get(self.slots[i].request.id, 0)))
            # park-storm: nothing runnable, a lane is being displaced —
            # snapshot the flight recorder before state changes further
            obs.flight_maybe_dump("engine.park_storm")
            if self.swap_tier:
                victims.append(self._swap_out(victim))
            else:
                victims.append(self._evict(victim))
        if victims:
            victims.sort(key=lambda r: self._seq.get(r.id, 0))
            self.scheduler.requeue_front(victims)

    def _park(self, slot: int, st: GenState) -> None:
        if self._pos[slot] >= 0:
            self.metrics.record_park()
            obs.instant("req.park", track=f"req:{st.request.id}",
                        id=st.request.id, slot=slot,
                        free_blocks=self.pool.free_blocks)
        self._pos[slot] = -1

    def _resume_request(self, st: GenState) -> Request:
        """The requeued form of a displaced lane: prompt := original prompt
        + everything generated, ``max_new_tokens`` the ORIGINAL horizon —
        ``GenState.generated`` carries the prior tokens, so the
        remaining-budget arithmetic, the per-token fold_in sample counter,
        and greedy continuations are all identical to the uninterrupted
        run.  The resume dict keeps the original submit time and
        first-token time, so TTFT never resets on recompute/swap-in."""
        req = st.request
        res = req.resume or {}
        orig_prompt_len = int(res.get("prompt_len", req.prompt_len))
        orig_prompt = np.asarray(req.prompt, np.int32)[:orig_prompt_len]
        done = np.asarray(st.generated, np.int32)   # prior + this residency
        return Request(
            id=req.id, prompt=np.concatenate([orig_prompt, done]),
            max_new_tokens=req.max_new_tokens,
            sampling=req.sampling, eos_id=req.eos_id, arrival_step=0,
            stream=req.stream,
            deadline_s=req.deadline_s, ttft_slo_s=req.ttft_slo_s,
            resume={"generated": [int(t) for t in done],
                    "prompt_len": orig_prompt_len,
                    "first_token_time": res.get("first_token_time")
                    or st.first_token_time,
                    "submitted": res.get("submitted")
                    or self._submit_time.get(req.id),
                    # SLO window keeps ticking across displacement
                    "slo_submit": self._slo_submit.get(req.id)})

    def _clear_lane(self, slot: int) -> None:
        self.slots[slot] = None
        self._pos[slot] = -1
        self._tok[slot, 0] = 0
        self.pool.release(slot)

    def _evict(self, slot: int) -> Request:
        """Recompute fallback: free the lane's blocks and return the
        resumed request (the caller batches same-tick victims into one
        FIFO-ordered requeue)."""
        st = self.slots[slot]
        resumed = self._resume_request(st)
        self._clear_lane(slot)
        self.metrics.record_evict()
        obs.instant("req.evict", track=f"req:{st.request.id}",
                    id=st.request.id, slot=slot,
                    generated=len(st.generated))
        obs.flight_maybe_dump("engine.evict")
        return resumed

    # -- swap tier ------------------------------------------------------------

    def _swap_out(self, slot: int) -> Request:
        """Displace a parked lane WITHOUT losing its KV: snapshot the
        logical ring on device (async — drained to host behind later
        steps), free the blocks, return the resumed request.  Recompute
        never happens unless the handle disappears."""
        st = self.slots[slot]
        req = st.request
        resumed = self._resume_request(st)
        resumed.resume["swap"] = req.id
        lane = self.pool.gather_lane(slot)     # BEFORE release zeroes the row
        blocks = self.pool.lane_blocks(slot)
        nbytes = blocks * self.pool.block_bytes
        self.swap[req.id] = {"cache": lane, "pos": st.pos, "blocks": blocks}
        self._swap_pending.append(req.id)
        self._clear_lane(slot)
        self.metrics.record_swap_out(nbytes)
        obs.instant("pool.swap_out", track=f"req:{req.id}", id=req.id,
                    slot=slot, blocks=blocks, bytes=nbytes,
                    generated=len(st.generated))
        return resumed

    def _swap_in(self, req: Request, slot: int, handle: dict) -> None:
        """Re-admit a swapped-out lane: grant blocks for the saved ring
        extent, re-insert the snapshot through the one compiled insert, and
        restore the batch rows exactly — no prefill, no resample; the next
        decode step continues where the lane left off."""
        res = req.resume or {}
        track = f"req:{req.id}"
        need = self.pool.blocks_for(min(handle["pos"], self.pool.ring_len))
        granted = self.pool.grant_prefix(slot, need)   # raises w/o effects
        nbytes = need * self.pool.block_bytes
        with obs.span("req.swap_in", device=True, track=track, id=req.id,
                      slot=slot, blocks=need, bytes=nbytes):
            self.pool.insert(jax.tree.map(jnp.asarray, handle["cache"]),
                             slot)
        del granted
        prior: List[int] = list(res.get("generated", []))
        sp = req.sampling
        now = time.perf_counter()
        st = GenState(request=req, slot=slot, pos=int(handle["pos"]),
                      last_token=prior[-1], generated=list(prior),
                      admitted_step=self.step_count, admitted_time=now)
        st.first_token_time = res.get("first_token_time") or 0.0
        self.metrics.record_admit(0)
        self.metrics.record_swap_in(nbytes)
        obs.instant("pool.swap_in", track=track, id=req.id, slot=slot,
                    blocks=need, bytes=nbytes)
        self.slots[slot] = st
        self._tok[slot, 0] = prior[-1]
        self._pos[slot] = st.pos
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        self._key[slot] = np.asarray(jax.random.PRNGKey(sp.seed), np.uint32)
        self._t[slot] = len(prior)            # next token's fold_in counter

    # -- decode / retire -----------------------------------------------------

    def _decode(self) -> None:
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and self._pos[i] >= 0]
        if not active:
            return
        # chaos NaN injector: the poison row is ALWAYS in the batch (all
        # False when disarmed) so arming it never changes the signature
        for i, s in enumerate(self.slots):
            self._poison_row[i] = (bool(self._poison) and s is not None
                                   and s.request.id in self._poison)
        batch = {
            "token": jnp.asarray(self._tok),
            "pos": jnp.asarray(self._pos),
            "temperature": jnp.asarray(self._temp),
            "top_k": jnp.asarray(self._topk),
            "top_p": jnp.asarray(self._topp),
            "key": jnp.asarray(self._key),
            "t": jnp.asarray(self._t),
            "poison": jnp.asarray(self._poison_row),
        }
        if self.paged:
            batch["block_tbl"] = jnp.asarray(self.pool.table)
            batch["ring_len"] = jnp.asarray(self.pool.ring_len, jnp.int32)
        t0 = time.perf_counter()
        with obs.span("engine.decode_step", device=True,
                      step=self.step_count, active=len(active)):
            tok, ok, self.pool.cache = self._step_fn(self.params,
                                                     self.pool.cache, batch)
            tok_np = np.asarray(tok)          # blocks until the step lands
            ok_np = np.asarray(ok)
        self.metrics.record_decode_step(
            len(active), len(active), time.perf_counter() - t0,
            in_flight=self.active_requests,
            blocks_in_use=self.pool.blocks_in_use,
            fragmentation=self.pool.fragmentation)
        obs.counter_track("pool", blocks_in_use=self.pool.blocks_in_use,
                          active_lanes=len(active),
                          free_runs=self.pool.free_runs,
                          fragmentation=self.pool.fragmentation)
        if obs.enabled() and self.step_count % 16 == 0:
            obs.watermark("engine.decode")     # devmem track, sampled
        now = time.perf_counter()
        for i in active:
            st = self.slots[i]
            if not bool(ok_np[i]):
                # this lane's logits slice went non-finite (organic or
                # injected): no token emitted, lane quarantined alone —
                # the scatter already wrote its cache row, but the blocks
                # are released with the lane, so nothing leaks
                self._quarantine_lane(st, "nonfinite_logits")
                continue
            t = int(tok_np[i, 0])
            done = st.remaining == 1 or t == st.request.eos_id
            st.emit(t, is_last=done, now=now)
            if self.journal is not None:
                self.journal.log_token(st.request.id, t)
            st.pos += 1
            st.steps_done += 1
            if done:
                self._retire(st, "eos" if t == st.request.eos_id
                             else "length")
            else:
                self._tok[i, 0] = t
                self._pos[i] = st.pos
                self._t[i] += 1

    def _retire(self, st: GenState, reason: str) -> None:
        slot = st.slot
        if self.slots[slot] is st:
            self.slots[slot] = None
        self._pos[slot] = -1
        self._tok[slot, 0] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 0.0
        self._key[slot] = 0
        self._t[slot] = 0
        self.pool.release(slot)
        res = st.request.resume or {}
        track = f"req:{st.request.id}"
        slo_t0 = self._slo_submit.pop(st.request.id, None)
        self._poison.discard(st.request.id)
        if reason in ("deadline", "ttft_slo"):
            # resident cancel: mid-decode, partial tokens kept, lane and
            # blocks just reclaimed above — audit the miss
            self.metrics.record_deadline_miss(ttft=reason == "ttft_slo")
            obs.instant("serve.deadline_miss", track=track,
                        id=st.request.id, kind=reason, queued=False,
                        generated=len(st.generated),
                        waited_s=(self._now() - slo_t0
                                  if slo_t0 is not None else 0.0))
            obs.counter(f"serve.deadline_miss.{reason}", 1)
        first_tok = res.get("first_token_time") or st.first_token_time
        # resumes carry the ORIGINAL submit time: TTFT measures the user's
        # wait, not the latest recompute/swap-in residency
        submit_t = (res.get("submitted")
                    or self._submit_time.get(st.request.id,
                                             st.admitted_time))
        ttft = first_tok - submit_t
        self.metrics.record_finish(ttft)
        now = time.perf_counter()
        obs.add_span("req.decode", first_tok, now, track=track,
                     id=st.request.id, tokens=len(st.generated))
        # exactly ONE lifecycle span per finished request (never re-emitted
        # on eviction/recompute): trace-validity checks count these against
        # metrics.requests_finished
        obs.add_span("req.lifecycle", submit_t, now, track=track,
                     id=st.request.id, reason=reason,
                     tokens=len(st.generated), ttft_s=ttft)
        obs.instant("req.retire", track=track, id=st.request.id,
                    reason=reason)
        if self.journal is not None:
            self.journal.log_finish(st.request.id, reason)
        self.finished[st.request.id] = FinishedRequest(
            id=st.request.id,
            tokens=np.asarray(st.generated, np.int32),
            prompt_len=res.get("prompt_len", st.request.prompt_len),
            admitted_step=st.admitted_step,
            finished_step=self.step_count,
            ttft_s=ttft,
            reason=reason)
