"""Continuous-batching forecast-serving engine over the sharded decode path.

The step loop the ROADMAP's top open item asks for: requests are admitted
FIFO under token budgets (``scheduler``), prefilled into a free lane of the
preallocated cache pool (``cache_pool``), then decoded *together* by the one
compiled ragged ``serve_step`` — per-slot positions, per-slot sampling
params, inactive lanes masked and frozen — until each request hits its
horizon or stop token and its lane is recycled.  Batch composition changes
every step; the compiled step signature never does (asserted by
``num_step_signatures``), which is what lets one jit serve an arbitrary
request trace.

Cache layout: uniform attention-ring families (dense/moe without
local/global alternation) default to the **paged block pool** — one shared
block pool plus per-lane block tables, so a lane only pins the blocks its
tokens occupy and short requests stop reserving full ``cache_len`` lanes
(REPRO_PAGED_KV=0 or ``paged=False`` restores contiguous lanes; SSM/hybrid
state lanes are always dense).  Paged decode grants blocks on demand as a
request's write position crosses a block boundary; on pool exhaustion the
request **parks** (its lane masked inactive, its blocks and neighbours
untouched) until frees arrive, and if *every* resident is parked the
youngest is moved out of the pool so the engine never livelocks while
holding blocks hostage.

Prefix sharing (``share_prefixes``, default on for paged pools /
REPRO_PREFIX_SHARE=0 disables): admission consults the pool's prefix-hash
index.  A whole-prompt hit maps every prefix block read-only (refcount
bump, zero new blocks) and skips prefill entirely — the chain's stored
last-token logits seed the first sample, so a cluster of users replaying
the same history costs one prefill total.  A partial block-aligned hit
shares the matched blocks and prefills as usual, with the shared blocks
masked out of the insert scatter (the donor's data is bit-identical —
deterministic prefill at equal positions).  The first write that would
land in a block with refcount > 1 copy-on-writes it in the grant pass:
fresh block, device tile copy, table remap, decref.  Admission pricing
(``blocks_needed``) counts only unshared blocks, so sharers admit even
when the free list alone couldn't cover them.

Swap tier (``swap_tier``, default on for paged pools / REPRO_SWAP_TIER=0
disables): the livelock-breaker snapshots the victim lane's logical ring
on device (async gather — it drains to host np arrays behind later decode
steps), frees its blocks, and requeues the request; on re-admission the
saved ring is re-inserted through the same compiled insert and decode
resumes bit-exactly where it left off — no recompute, TTFT keeps the
original submit time.  Evict-and-recompute (``_evict``) remains the final
fallback (swap tier off, or the handle is gone).  Same-tick victims are
requeued in one batch ordered by original submit order, so multi-eviction
ticks preserve FIFO.

Decode composes with the whole serving stack: fused flash-decode kernels
(``REPRO_FLASH_DECODE``; block tables ride a scalar-prefetch operand), int8
caches (``REPRO_KV_INT8``), and seq-sharded cache layouts
(``REPRO_CACHE_SHARD=seq`` under an active mesh — rings shard the slot
axis, paged pools the block axis, with the same pmax/psum combine).
Shared blocks change none of it: tables are read-only to the kernels, so a
physical block appearing in several tables just streams the same tile to
each sharer.

    engine = ForecastEngine(cfg, params, num_slots=8, cache_len=256)
    engine.submit(Request(id="r0", prompt=toks, max_new_tokens=32))
    done = engine.run()              # {id: FinishedRequest}

Observability (``repro.obs``, ``REPRO_TRACE=0`` disables): every request
gets its own Perfetto track carrying the lifecycle
``req.submit -> req.queued -> req.prefill -> req.first_token ->
req.decode -> req.lifecycle -> req.retire`` (park/evict as instant
events, plus ``pool.share_hit`` / ``pool.cow_copy`` / ``pool.swap_out`` /
``pool.swap_in`` instants with byte counts whenever sharing or the swap
tier fire); each engine tick emits an ``engine.decode_step`` span (wrapped in
``jax.profiler.TraceAnnotation`` so host and XLA device traces line up)
plus a ``pool`` counter track (blocks in use / active lanes).  Exactly one
``req.lifecycle`` span is emitted per FINISHED request — eviction and
recompute re-emit the per-residency phases, never the lifecycle — so a
trace's lifecycle-span count always equals ``requests_finished``.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.launch.steps import make_serve_step
from repro.models.registry import get_model
from repro.serve.cache_pool import (PAGED_FAMILIES, CachePool,
                                    PagedCachePool)
from repro.serve.metrics import EngineMetrics
from repro.serve.request import FinishedRequest, GenState, Request
from repro.serve.sampling import sample_vec
from repro.serve.scheduler import (FIFOScheduler, SchedulerConfig,
                                   bucket_len)

# families whose batch dict is {"tokens"} and whose decode path supports
# per-slot ragged positions (attention rings via attn_decode, SSM states
# via the serve-step freeze)
_SERVABLE = ("dense", "moe", "ssm", "hybrid")
_BUCKETABLE = ("dense", "moe")               # right-pad-safe prefill (causal
                                             # attention only, no recurrence)


class ForecastEngine:
    """Request-level serving engine: admit -> prefill-into-slot -> batched
    ragged decode -> retire."""

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 4,
                 cache_len: int = 256, max_tokens_in_flight: int = 0,
                 prefill_chunk: int = 0, prefill_bucket: int = 0,
                 force_window: int = 0, paged: Optional[bool] = None,
                 block_size: int = 0, pool_blocks: int = 0,
                 share_prefixes: Optional[bool] = None,
                 swap_tier: Optional[bool] = None):
        if cfg.family not in _SERVABLE:
            raise ValueError(f"family {cfg.family!r} not servable by the "
                             f"engine (supported: {_SERVABLE})")
        if prefill_bucket and cfg.family not in _BUCKETABLE:
            raise ValueError(f"prefill_bucket requires a causal-attention "
                             f"prefill (families {_BUCKETABLE}); "
                             f"{cfg.family!r} carries recurrent state "
                             f"through pad tokens")
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.prefill_bucket = prefill_bucket
        self.force_window = force_window
        if paged is None:                     # default on where eligible
            paged = (os.environ.get("REPRO_PAGED_KV", "1") != "0"
                     and cfg.family in PAGED_FAMILIES
                     and not cfg.local_global_alternating)
        self.paged = paged
        if paged:
            self.pool = PagedCachePool(cfg, num_slots, cache_len,
                                       block_size=block_size,
                                       pool_blocks=pool_blocks,
                                       force_window=force_window)
        else:
            if block_size or pool_blocks:
                raise ValueError("block_size/pool_blocks require paged=True")
            if share_prefixes or swap_tier:
                raise ValueError("share_prefixes/swap_tier require the "
                                 "paged pool")
            self.pool = CachePool(self.api, cfg, num_slots, cache_len,
                                  force_window=force_window)
        # CoW prefix sharing + host swap tier: paged-pool features, on by
        # default there (REPRO_PREFIX_SHARE=0 / REPRO_SWAP_TIER=0 or the
        # ctor args turn them off independently)
        self.share_prefixes = bool(paged and (
            share_prefixes if share_prefixes is not None
            else os.environ.get("REPRO_PREFIX_SHARE", "1") != "0"))
        self.swap_tier = bool(paged and (
            swap_tier if swap_tier is not None
            else os.environ.get("REPRO_SWAP_TIER", "1") != "0"))
        # swapped-out lanes: request id -> {"cache": leaves, "pos", "blocks"}
        # — leaves start as async device gathers and drain to host np arrays
        # behind later decode steps (see step())
        self.swap: Dict[str, dict] = {}
        self._swap_pending: List[str] = []
        # per-request submit sequence: multi-eviction ticks requeue in this
        # order, so FIFO survives same-tick victims (resumes keep the id)
        self._seq: Dict[str, int] = {}
        self.scheduler = FIFOScheduler(SchedulerConfig(
            max_tokens_in_flight=max_tokens_in_flight,
            prefill_chunk=prefill_chunk))
        self.metrics = EngineMetrics(num_slots,
                                     pool_blocks=self.pool.pool_blocks)
        self.step_count = 0
        self.finished: Dict[str, FinishedRequest] = {}
        self.slots: List[Optional[GenState]] = [None] * num_slots
        self._submit_time: Dict[str, float] = {}
        # global-attention rings must hold the whole sequence: dense/moe
        # without a (forced) sliding window, and hybrid, whose attention
        # layers are always global.  Windowed archs wrap by design; pure
        # SSM state is O(1).
        self._ring_is_global = (
            cfg.family in _BUCKETABLE and cfg.sliding_window == 0
            and not force_window) or cfg.family == "hybrid"

        # fixed-shape per-slot batch arrays — the ONLY thing the compiled
        # step sees; host-side admission/eviction just rewrites rows
        self._tok = np.zeros((num_slots, 1), np.int32)
        self._pos = np.full((num_slots,), -1, np.int32)
        self._temp = np.zeros((num_slots,), np.float32)
        self._topk = np.zeros((num_slots,), np.int32)
        self._topp = np.zeros((num_slots,), np.float32)
        self._key = np.zeros((num_slots, 2), np.uint32)
        self._t = np.zeros((num_slots,), np.int32)

        self._step_fn = jax.jit(
            make_serve_step(cfg, force_window=force_window, sampling=True),
            donate_argnums=(1,))

        def _prefill(params, tokens, true_len):
            return self.api.prefill(params, cfg, {"tokens": tokens},
                                    cache_len=cache_len,
                                    force_window=force_window,
                                    true_len=true_len)

        self._prefill_fn = jax.jit(_prefill)

        def _first(logits, key, temp, top_k, top_p, t):
            keys = jax.random.fold_in(key, t)[None]
            return sample_vec(keys, logits[:, -1, :], temperature=temp[None],
                              top_k=top_k[None], top_p=top_p[None])[0]

        self._first_fn = jax.jit(_first)

    # -- public surface ------------------------------------------------------

    def submit(self, request: Request) -> None:
        budget = self.scheduler.config.max_tokens_in_flight
        if budget > 0 and request.total_tokens > budget:
            # would never admit: run() would spin on it forever
            raise ValueError(
                f"request {request.id}: total tokens "
                f"({request.total_tokens}) exceed max_tokens_in_flight "
                f"({budget}) — it could never be admitted")
        footprint = max(request.total_tokens,
                        bucket_len(request.prompt_len, self.prefill_bucket))
        if self._ring_is_global and footprint > self.pool.cache_len:
            raise ValueError(
                f"request {request.id}: prompt + horizon (bucketed: "
                f"{footprint}) exceeds cache_len ({self.pool.cache_len})")
        if self.paged:
            need = self.pool.blocks_for(footprint)
            if need > self.pool.pool_blocks:
                # even alone it would park forever: reject at submit
                raise ValueError(
                    f"request {request.id}: needs {need} blocks, pool has "
                    f"{self.pool.pool_blocks}")
        if request.resume is None:            # eviction re-queues internally
            obs.instant("req.submit", track=f"req:{request.id}",
                        id=request.id, prompt_len=request.prompt_len,
                        max_new_tokens=request.max_new_tokens)
        self._submit_time[request.id] = time.perf_counter()
        self._seq.setdefault(request.id, len(self._seq))
        self.scheduler.submit(request)

    @property
    def active_requests(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def tokens_in_flight(self) -> int:
        return sum(s.request.total_tokens for s in self.slots
                   if s is not None)

    def num_step_signatures(self) -> int:
        """Compiled serve_step signatures so far — the engine's no-re-jit
        invariant is that this stays 1 across every admission/eviction."""
        return self._step_fn._cache_size()

    def step(self) -> None:
        """One engine tick: admit what fits, grow/park paged lanes, then
        one batched decode."""
        free_blocks = self.pool.free_blocks if self.paged else -1
        blocks_needed = self._admit_blocks if self.paged else None
        for req in self.scheduler.admit(
                now_step=self.step_count,
                free_slots=self.pool.free_slots,
                tokens_in_flight=self.tokens_in_flight,
                free_blocks=free_blocks,
                blocks_needed=blocks_needed):
            try:
                self._admit(req)
            except RuntimeError:
                # share-aware pricing raced a chain invalidation (or the
                # pool shrank between pricing and grant): the admission was
                # rolled back — put the request back at the head and stop
                # admitting this tick
                self.scheduler.requeue_front([req])
                break
        if self.paged:
            self._grant_pass()
        self._decode()
        self.step_count += 1
        # drain swap-outs to host np arrays AFTER the decode dispatched —
        # the device gather overlaps the step instead of blocking it
        while self._swap_pending:
            handle = self.swap.get(self._swap_pending.pop())
            if handle is not None and not handle.get("host"):
                handle["cache"] = jax.tree.map(np.asarray, handle["cache"])
                handle["host"] = True

    def run(self, max_steps: int = 0) -> Dict[str, FinishedRequest]:
        """Drive steps until every submitted request retires."""
        while self.scheduler.pending or self.active_requests:
            if max_steps and self.step_count >= max_steps:
                raise RuntimeError(f"engine did not drain within "
                                   f"{max_steps} steps")
            self.step()
        return self.finished

    # -- internals -----------------------------------------------------------

    def _bucketed_len(self, req: Request) -> int:
        P = req.prompt_len
        Pb = bucket_len(P, self.prefill_bucket)
        if req.resume and self._ring_is_global and Pb > self.pool.cache_len:
            return P            # resumed prompts skip bucketing on overflow
        return Pb

    def _admit_blocks(self, req: Request) -> int:
        """Paged admission price: blocks covering the prefill ring extent
        (decode growth is granted on demand).  Share-aware: blocks served
        by a live prefix chain cost nothing — a whole-prompt hit admits
        free, which is what lets a cluster of identical histories oversubscribe
        the same pool bytes.  A swap-tier resume prices its saved extent."""
        res = req.resume or {}
        if self.swap_tier and res.get("swap") in self.swap:
            handle = self.swap[res["swap"]]
            return self.pool.blocks_for(min(handle["pos"],
                                            self.pool.ring_len))
        need = self.pool.blocks_for(self._bucketed_len(req))
        if self.share_prefixes:
            shared, full_hit, _ = self.pool.match_prefix(req.prompt)
            if full_hit:
                return 0
            need -= len(shared)
        return max(need, 0)

    def _admit(self, req: Request) -> None:
        track = f"req:{req.id}"
        t_admit = time.perf_counter()
        res = req.resume or {}
        obs.add_span("req.queued",
                     res.get("submitted")
                     or self._submit_time.get(req.id, t_admit), t_admit,
                     track=track, id=req.id)
        slot = self.pool.acquire()
        if self.swap_tier and res.get("swap") in self.swap:
            handle = self.swap.pop(res["swap"])
            try:
                self._swap_in(req, slot, handle)
            except RuntimeError:               # pool raced below the price
                self.swap[res["swap"]] = handle
                self.pool.release(slot)
                raise
            return
        P = req.prompt_len
        Pb = self._bucketed_len(req)
        shared: List[int] = []
        full_hit, chain_logits = False, None
        if self.paged:
            if self.share_prefixes:
                shared, full_hit, chain_logits = \
                    self.pool.match_prefix(req.prompt)
            try:
                self.pool.share_map(slot, shared)
                if not full_hit:
                    self.pool.grant_tail(
                        slot, len(shared),
                        self.pool.blocks_for(Pb) - len(shared))
            except RuntimeError:               # pool raced below the price
                self.pool.release(slot)        # decrefs any shared mapping
                raise
            if shared:
                self.metrics.record_share(len(shared), full_hit)
                obs.instant("pool.share_hit", track=track, id=req.id,
                            slot=slot, blocks=len(shared),
                            full_prompt=bool(full_hit),
                            bytes=len(shared) * self.pool.block_bytes)

        if full_hit and chain_logits is not None:
            # whole prompt lives in the pool already: zero prefill, zero
            # new blocks — the chain's stored last-token logits row seeds
            # the first sample exactly as a fresh prefill's would
            logits = jnp.asarray(chain_logits)[None, None]
            self.metrics.record_admit(0)
        else:
            toks = np.zeros((1, Pb), np.int32)
            toks[0, :P] = req.prompt
            # true_len rides along whenever bucketing is on (one bucketed
            # prefill signature even for exact-fit prompts); a resume that
            # skipped bucketing prefills at its exact length
            true_len = (jnp.asarray([P], jnp.int32)
                        if self.prefill_bucket and (Pb != P or not req.resume)
                        else None)
            with obs.span("req.prefill", device=True, track=track,
                          id=req.id, prompt_len=P, padded_len=Pb, slot=slot,
                          shared_blocks=len(shared),
                          resumed=req.resume is not None):
                cache1, logits = self._prefill_fn(self.params,
                                                  jnp.asarray(toks),
                                                  true_len)
                if self.paged:
                    # shared prefix blocks are read-only — the donor's data
                    # is bit-identical, so mask them out of the scatter
                    self.pool.insert(cache1, slot, skip_blocks=len(shared))
                else:
                    self.pool.insert(cache1, slot)
            if self.share_prefixes and req.resume is None:
                # index this prompt for future sharers (resumes carry
                # generated continuations — not reusable prompts)
                self.pool.register_prefix(
                    slot, req.prompt, np.asarray(logits[0, -1]))
            self.metrics.record_admit(P)

        prior: List[int] = list(res.get("generated", []))
        sp = req.sampling
        base_key = np.asarray(jax.random.PRNGKey(sp.seed), np.uint32)
        # sample counter continues across eviction/recompute: token i of the
        # ORIGINAL request is always drawn from fold_in(key, i)
        tok0 = int(self._first_fn(
            logits, jnp.asarray(base_key),
            jnp.asarray(sp.temperature, jnp.float32),
            jnp.asarray(sp.top_k, jnp.int32),
            jnp.asarray(sp.top_p, jnp.float32),
            jnp.asarray(len(prior), jnp.int32)))

        now = time.perf_counter()
        st = GenState(request=req, slot=slot, pos=P, last_token=tok0,
                      generated=prior,
                      admitted_step=self.step_count, admitted_time=now)
        done = st.remaining == 1 or tok0 == req.eos_id
        first_of_original = not prior          # st.emit appends into `prior`
        st.emit(tok0, is_last=done, now=now)
        if first_of_original:
            obs.instant("req.first_token", track=track, id=req.id)
        if done:
            self._retire(st, "eos" if tok0 == req.eos_id else "length")
            return
        self.slots[slot] = st
        self._tok[slot, 0] = tok0
        self._pos[slot] = P
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        self._key[slot] = base_key
        self._t[slot] = len(prior) + 1        # last token came from prefill

    # -- paged block lifecycle ----------------------------------------------

    def _grant_pass(self) -> None:
        """Before each paged decode: make sure every resident lane's next
        write slot has a physical block IT OWNS.  A write block with
        refcount > 1 is copy-on-written first (sharers never mutate a
        donor's prefix; CoW failure parks like any grant failure); a sole
        owner whose ring wrapped back over indexed prefix content drops the
        stale chain entries before the write lands.  Grants collect into
        one device-side kv_pos reset; lanes that can't be granted park
        (masked inactive, no writes — a parked lane can never corrupt a
        neighbour).  If parking leaves nothing runnable, the youngest
        parked lane leaves the pool — swapped to the host tier when
        enabled, evicted to recompute otherwise — and the pass retries.
        Same-tick victims requeue in ONE batch ordered by original submit
        order, so multi-eviction ticks preserve FIFO and a resumed TTFT
        never resets."""
        victims: List[Request] = []
        while True:
            fresh: List[int] = []
            parked: List[int] = []
            for i, st in enumerate(self.slots):
                if st is None:
                    continue
                lb = (st.pos % self.pool.ring_len) // self.pool.block_size
                pb = int(self.pool.table[i, lb])
                if pb >= 0:
                    if self.pool.refcount(pb) > 1:
                        try:                   # shared write block: CoW
                            old, new = self.pool.cow(i, lb)
                        except RuntimeError:   # no block for the copy
                            self._park(i, st)
                            parked.append(i)
                            continue
                        self.metrics.record_cow(self.pool.block_bytes)
                        obs.instant("pool.cow_copy",
                                    track=f"req:{st.request.id}",
                                    id=st.request.id, slot=i, src=old,
                                    dst=new,
                                    bytes=self.pool.block_bytes)
                    elif st.pos >= self.pool.ring_len:
                        # sole owner wrapping over indexed prefix content
                        self.pool.invalidate_block(pb)
                    if self._pos[i] < 0:      # runnable now — unpark
                        self._pos[i] = st.pos
                    continue
                try:
                    fresh.append(self.pool.grant(i, lb))
                    if self._pos[i] < 0:
                        self._pos[i] = st.pos
                except RuntimeError:          # pool exhausted — park
                    self._park(i, st)
                    parked.append(i)
            self.pool.reset_blocks(fresh)
            runnable = any(s is not None and self._pos[i] >= 0
                           for i, s in enumerate(self.slots))
            if runnable or not parked:
                break
            if len(parked) == len([s for s in self.slots if s is not None]) \
                    and len(parked) == 1:
                raise RuntimeError(
                    f"paged pool too small: a single resident request "
                    f"cannot grow ({self.pool.pool_blocks} blocks of "
                    f"{self.pool.block_size})")
            victim = max(parked, key=lambda i: (
                self.slots[i].admitted_step,
                self._seq.get(self.slots[i].request.id, 0)))
            # park-storm: nothing runnable, a lane is being displaced —
            # snapshot the flight recorder before state changes further
            obs.flight_maybe_dump("engine.park_storm")
            if self.swap_tier:
                victims.append(self._swap_out(victim))
            else:
                victims.append(self._evict(victim))
        if victims:
            victims.sort(key=lambda r: self._seq.get(r.id, 0))
            self.scheduler.requeue_front(victims)

    def _park(self, slot: int, st: GenState) -> None:
        if self._pos[slot] >= 0:
            self.metrics.record_park()
            obs.instant("req.park", track=f"req:{st.request.id}",
                        id=st.request.id, slot=slot,
                        free_blocks=self.pool.free_blocks)
        self._pos[slot] = -1

    def _resume_request(self, st: GenState) -> Request:
        """The requeued form of a displaced lane: prompt := original prompt
        + everything generated, ``max_new_tokens`` the ORIGINAL horizon —
        ``GenState.generated`` carries the prior tokens, so the
        remaining-budget arithmetic, the per-token fold_in sample counter,
        and greedy continuations are all identical to the uninterrupted
        run.  The resume dict keeps the original submit time and
        first-token time, so TTFT never resets on recompute/swap-in."""
        req = st.request
        res = req.resume or {}
        orig_prompt_len = int(res.get("prompt_len", req.prompt_len))
        orig_prompt = np.asarray(req.prompt, np.int32)[:orig_prompt_len]
        done = np.asarray(st.generated, np.int32)   # prior + this residency
        return Request(
            id=req.id, prompt=np.concatenate([orig_prompt, done]),
            max_new_tokens=req.max_new_tokens,
            sampling=req.sampling, eos_id=req.eos_id, arrival_step=0,
            stream=req.stream,
            resume={"generated": [int(t) for t in done],
                    "prompt_len": orig_prompt_len,
                    "first_token_time": res.get("first_token_time")
                    or st.first_token_time,
                    "submitted": res.get("submitted")
                    or self._submit_time.get(req.id)})

    def _clear_lane(self, slot: int) -> None:
        self.slots[slot] = None
        self._pos[slot] = -1
        self._tok[slot, 0] = 0
        self.pool.release(slot)

    def _evict(self, slot: int) -> Request:
        """Recompute fallback: free the lane's blocks and return the
        resumed request (the caller batches same-tick victims into one
        FIFO-ordered requeue)."""
        st = self.slots[slot]
        resumed = self._resume_request(st)
        self._clear_lane(slot)
        self.metrics.record_evict()
        obs.instant("req.evict", track=f"req:{st.request.id}",
                    id=st.request.id, slot=slot,
                    generated=len(st.generated))
        obs.flight_maybe_dump("engine.evict")
        return resumed

    # -- swap tier ------------------------------------------------------------

    def _swap_out(self, slot: int) -> Request:
        """Displace a parked lane WITHOUT losing its KV: snapshot the
        logical ring on device (async — drained to host behind later
        steps), free the blocks, return the resumed request.  Recompute
        never happens unless the handle disappears."""
        st = self.slots[slot]
        req = st.request
        resumed = self._resume_request(st)
        resumed.resume["swap"] = req.id
        lane = self.pool.gather_lane(slot)     # BEFORE release zeroes the row
        blocks = int((self.pool.table[slot] >= 0).sum())
        nbytes = blocks * self.pool.block_bytes
        self.swap[req.id] = {"cache": lane, "pos": st.pos, "blocks": blocks}
        self._swap_pending.append(req.id)
        self._clear_lane(slot)
        self.metrics.record_swap_out(nbytes)
        obs.instant("pool.swap_out", track=f"req:{req.id}", id=req.id,
                    slot=slot, blocks=blocks, bytes=nbytes,
                    generated=len(st.generated))
        return resumed

    def _swap_in(self, req: Request, slot: int, handle: dict) -> None:
        """Re-admit a swapped-out lane: grant blocks for the saved ring
        extent, re-insert the snapshot through the one compiled insert, and
        restore the batch rows exactly — no prefill, no resample; the next
        decode step continues where the lane left off."""
        res = req.resume or {}
        track = f"req:{req.id}"
        need = self.pool.blocks_for(min(handle["pos"], self.pool.ring_len))
        granted = self.pool.grant_prefix(slot, need)   # raises w/o effects
        nbytes = need * self.pool.block_bytes
        with obs.span("req.swap_in", device=True, track=track, id=req.id,
                      slot=slot, blocks=need, bytes=nbytes):
            self.pool.insert(jax.tree.map(jnp.asarray, handle["cache"]),
                             slot)
        del granted
        prior: List[int] = list(res.get("generated", []))
        sp = req.sampling
        now = time.perf_counter()
        st = GenState(request=req, slot=slot, pos=int(handle["pos"]),
                      last_token=prior[-1], generated=list(prior),
                      admitted_step=self.step_count, admitted_time=now)
        st.first_token_time = res.get("first_token_time") or 0.0
        self.metrics.record_admit(0)
        self.metrics.record_swap_in(nbytes)
        obs.instant("pool.swap_in", track=track, id=req.id, slot=slot,
                    blocks=need, bytes=nbytes)
        self.slots[slot] = st
        self._tok[slot, 0] = prior[-1]
        self._pos[slot] = st.pos
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        self._key[slot] = np.asarray(jax.random.PRNGKey(sp.seed), np.uint32)
        self._t[slot] = len(prior)            # next token's fold_in counter

    # -- decode / retire -----------------------------------------------------

    def _decode(self) -> None:
        active = [i for i, s in enumerate(self.slots)
                  if s is not None and self._pos[i] >= 0]
        if not active:
            return
        batch = {
            "token": jnp.asarray(self._tok),
            "pos": jnp.asarray(self._pos),
            "temperature": jnp.asarray(self._temp),
            "top_k": jnp.asarray(self._topk),
            "top_p": jnp.asarray(self._topp),
            "key": jnp.asarray(self._key),
            "t": jnp.asarray(self._t),
        }
        if self.paged:
            batch["block_tbl"] = jnp.asarray(self.pool.table)
            batch["ring_len"] = jnp.asarray(self.pool.ring_len, jnp.int32)
        t0 = time.perf_counter()
        with obs.span("engine.decode_step", device=True,
                      step=self.step_count, active=len(active)):
            tok, self.pool.cache = self._step_fn(self.params,
                                                 self.pool.cache, batch)
            tok_np = np.asarray(tok)          # blocks until the step lands
        self.metrics.record_decode_step(
            len(active), len(active), time.perf_counter() - t0,
            in_flight=self.active_requests,
            blocks_in_use=self.pool.blocks_in_use,
            fragmentation=self.pool.fragmentation)
        obs.counter_track("pool", blocks_in_use=self.pool.blocks_in_use,
                          active_lanes=len(active),
                          free_runs=self.pool.free_runs,
                          fragmentation=self.pool.fragmentation)
        if obs.enabled() and self.step_count % 16 == 0:
            obs.watermark("engine.decode")     # devmem track, sampled
        now = time.perf_counter()
        for i in active:
            st = self.slots[i]
            t = int(tok_np[i, 0])
            done = st.remaining == 1 or t == st.request.eos_id
            st.emit(t, is_last=done, now=now)
            st.pos += 1
            st.steps_done += 1
            if done:
                self._retire(st, "eos" if t == st.request.eos_id
                             else "length")
            else:
                self._tok[i, 0] = t
                self._pos[i] = st.pos
                self._t[i] += 1

    def _retire(self, st: GenState, reason: str) -> None:
        slot = st.slot
        if self.slots[slot] is st:
            self.slots[slot] = None
        self._pos[slot] = -1
        self._tok[slot, 0] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 0.0
        self._key[slot] = 0
        self._t[slot] = 0
        self.pool.release(slot)
        res = st.request.resume or {}
        first_tok = res.get("first_token_time") or st.first_token_time
        # resumes carry the ORIGINAL submit time: TTFT measures the user's
        # wait, not the latest recompute/swap-in residency
        submit_t = (res.get("submitted")
                    or self._submit_time.get(st.request.id,
                                             st.admitted_time))
        ttft = first_tok - submit_t
        self.metrics.record_finish(ttft)
        now = time.perf_counter()
        track = f"req:{st.request.id}"
        obs.add_span("req.decode", first_tok, now, track=track,
                     id=st.request.id, tokens=len(st.generated))
        # exactly ONE lifecycle span per finished request (never re-emitted
        # on eviction/recompute): trace-validity checks count these against
        # metrics.requests_finished
        obs.add_span("req.lifecycle", submit_t, now, track=track,
                     id=st.request.id, reason=reason,
                     tokens=len(st.generated), ttft_s=ttft)
        obs.instant("req.retire", track=track, id=st.request.id,
                    reason=reason)
        self.finished[st.request.id] = FinishedRequest(
            id=st.request.id,
            tokens=np.asarray(st.generated, np.int32),
            prompt_len=res.get("prompt_len", st.request.prompt_len),
            admitted_step=st.admitted_step,
            finished_step=self.step_count,
            ttft_s=ttft,
            reason=reason)
