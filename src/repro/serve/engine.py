"""Continuous-batching forecast-serving engine over the sharded decode path.

The step loop the ROADMAP's top open item asks for: requests are admitted
FIFO under token budgets (``scheduler``), prefilled into a free lane of the
preallocated cache pool (``cache_pool``), then decoded *together* by the one
compiled ragged ``serve_step`` — per-slot positions, per-slot sampling
params, inactive lanes masked and frozen — until each request hits its
horizon or stop token and its lane is recycled.  Batch composition changes
every step; the compiled step signature never does (asserted by
``num_step_signatures``), which is what lets one jit serve an arbitrary
request trace.

Decode composes with the whole serving stack: fused flash-decode kernels
(``REPRO_FLASH_DECODE``), int8 ring caches (``REPRO_KV_INT8``), and
seq-sharded cache layouts (``REPRO_CACHE_SHARD=seq`` under an active mesh —
the ragged step runs per-shard with the same pmax/psum combine, since lane
masking rides on per-slot positions which shard with the cache).

    engine = ForecastEngine(cfg, params, num_slots=8, cache_len=256)
    engine.submit(Request(id="r0", prompt=toks, max_new_tokens=32))
    done = engine.run()              # {id: FinishedRequest}
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.steps import make_serve_step
from repro.models.registry import get_model
from repro.serve.cache_pool import CachePool
from repro.serve.metrics import EngineMetrics
from repro.serve.request import FinishedRequest, GenState, Request
from repro.serve.sampling import sample_vec
from repro.serve.scheduler import (FIFOScheduler, SchedulerConfig,
                                   bucket_len)

# families whose batch dict is {"tokens"} and whose decode path supports
# per-slot ragged positions (attention rings via attn_decode, SSM states
# via the serve-step freeze)
_SERVABLE = ("dense", "moe", "ssm", "hybrid")
_BUCKETABLE = ("dense", "moe")               # right-pad-safe prefill (causal
                                             # attention only, no recurrence)


class ForecastEngine:
    """Request-level serving engine: admit -> prefill-into-slot -> batched
    ragged decode -> retire."""

    def __init__(self, cfg: ModelConfig, params, *, num_slots: int = 4,
                 cache_len: int = 256, max_tokens_in_flight: int = 0,
                 prefill_chunk: int = 0, prefill_bucket: int = 0,
                 force_window: int = 0):
        if cfg.family not in _SERVABLE:
            raise ValueError(f"family {cfg.family!r} not servable by the "
                             f"engine (supported: {_SERVABLE})")
        if prefill_bucket and cfg.family not in _BUCKETABLE:
            raise ValueError(f"prefill_bucket requires a causal-attention "
                             f"prefill (families {_BUCKETABLE}); "
                             f"{cfg.family!r} carries recurrent state "
                             f"through pad tokens")
        self.cfg = cfg
        self.params = params
        self.api = get_model(cfg)
        self.prefill_bucket = prefill_bucket
        self.force_window = force_window
        self.pool = CachePool(self.api, cfg, num_slots, cache_len,
                              force_window=force_window)
        self.scheduler = FIFOScheduler(SchedulerConfig(
            max_tokens_in_flight=max_tokens_in_flight,
            prefill_chunk=prefill_chunk))
        self.metrics = EngineMetrics(num_slots)
        self.step_count = 0
        self.finished: Dict[str, FinishedRequest] = {}
        self.slots: List[Optional[GenState]] = [None] * num_slots
        self._submit_time: Dict[str, float] = {}

        # fixed-shape per-slot batch arrays — the ONLY thing the compiled
        # step sees; host-side admission/eviction just rewrites rows
        self._tok = np.zeros((num_slots, 1), np.int32)
        self._pos = np.full((num_slots,), -1, np.int32)
        self._temp = np.zeros((num_slots,), np.float32)
        self._topk = np.zeros((num_slots,), np.int32)
        self._topp = np.zeros((num_slots,), np.float32)
        self._key = np.zeros((num_slots, 2), np.uint32)
        self._t = np.zeros((num_slots,), np.int32)

        self._step_fn = jax.jit(
            make_serve_step(cfg, force_window=force_window, sampling=True),
            donate_argnums=(1,))

        def _prefill(params, tokens, true_len):
            return self.api.prefill(params, cfg, {"tokens": tokens},
                                    cache_len=cache_len,
                                    force_window=force_window,
                                    true_len=true_len)

        self._prefill_fn = jax.jit(_prefill)

        def _first(logits, key, temp, top_k, top_p):
            keys = jax.random.fold_in(key, 0)[None]
            return sample_vec(keys, logits[:, -1, :], temperature=temp[None],
                              top_k=top_k[None], top_p=top_p[None])[0]

        self._first_fn = jax.jit(_first)

    # -- public surface ------------------------------------------------------

    def submit(self, request: Request) -> None:
        budget = self.scheduler.config.max_tokens_in_flight
        if budget > 0 and request.total_tokens > budget:
            # would never admit: run() would spin on it forever
            raise ValueError(
                f"request {request.id}: total tokens "
                f"({request.total_tokens}) exceed max_tokens_in_flight "
                f"({budget}) — it could never be admitted")
        # global-attention rings must hold the whole sequence: dense/moe
        # without a (forced) sliding window, and hybrid, whose attention
        # layers are always global.  Windowed archs wrap by design; pure
        # SSM state is O(1).
        ring_is_global = (
            self.cfg.family in _BUCKETABLE and self.cfg.sliding_window == 0
            and not self.force_window) or self.cfg.family == "hybrid"
        if ring_is_global:
            footprint = max(
                request.total_tokens,
                bucket_len(request.prompt_len, self.prefill_bucket))
            if footprint > self.pool.cache_len:
                raise ValueError(
                    f"request {request.id}: prompt + horizon (bucketed: "
                    f"{footprint}) exceeds cache_len "
                    f"({self.pool.cache_len})")
        self._submit_time[request.id] = time.perf_counter()
        self.scheduler.submit(request)

    @property
    def active_requests(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def tokens_in_flight(self) -> int:
        return sum(s.request.total_tokens for s in self.slots
                   if s is not None)

    def num_step_signatures(self) -> int:
        """Compiled serve_step signatures so far — the engine's no-re-jit
        invariant is that this stays 1 across every admission/eviction."""
        return self._step_fn._cache_size()

    def step(self) -> None:
        """One engine tick: admit what fits, then one batched decode."""
        for req in self.scheduler.admit(
                now_step=self.step_count,
                free_slots=self.pool.free_slots,
                tokens_in_flight=self.tokens_in_flight):
            self._admit(req)
        self._decode()
        self.step_count += 1

    def run(self, max_steps: int = 0) -> Dict[str, FinishedRequest]:
        """Drive steps until every submitted request retires."""
        while self.scheduler.pending or self.active_requests:
            if max_steps and self.step_count >= max_steps:
                raise RuntimeError(f"engine did not drain within "
                                   f"{max_steps} steps")
            self.step()
        return self.finished

    # -- internals -----------------------------------------------------------

    def _admit(self, req: Request) -> None:
        slot = self.pool.acquire()
        P = req.prompt_len
        Pb = bucket_len(P, self.prefill_bucket)
        toks = np.zeros((1, Pb), np.int32)
        toks[0, :P] = req.prompt
        true_len = (jnp.asarray([P], jnp.int32)
                    if self.prefill_bucket else None)
        cache1, logits = self._prefill_fn(self.params, jnp.asarray(toks),
                                          true_len)
        self.pool.insert(cache1, slot)

        sp = req.sampling
        base_key = np.asarray(jax.random.PRNGKey(sp.seed), np.uint32)
        tok0 = int(self._first_fn(
            logits, jnp.asarray(base_key),
            jnp.asarray(sp.temperature, jnp.float32),
            jnp.asarray(sp.top_k, jnp.int32),
            jnp.asarray(sp.top_p, jnp.float32)))

        now = time.perf_counter()
        st = GenState(request=req, slot=slot, pos=P, last_token=tok0,
                      admitted_step=self.step_count, admitted_time=now)
        self.metrics.record_admit(P)
        done = req.max_new_tokens == 1 or tok0 == req.eos_id
        st.emit(tok0, is_last=done, now=now)
        if done:
            self._retire(st, "eos" if tok0 == req.eos_id else "length")
            return
        self.slots[slot] = st
        self._tok[slot, 0] = tok0
        self._pos[slot] = P
        self._temp[slot] = sp.temperature
        self._topk[slot] = sp.top_k
        self._topp[slot] = sp.top_p
        self._key[slot] = base_key
        self._t[slot] = 1                     # token 0 came from prefill

    def _decode(self) -> None:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        batch = {
            "token": jnp.asarray(self._tok),
            "pos": jnp.asarray(self._pos),
            "temperature": jnp.asarray(self._temp),
            "top_k": jnp.asarray(self._topk),
            "top_p": jnp.asarray(self._topp),
            "key": jnp.asarray(self._key),
            "t": jnp.asarray(self._t),
        }
        t0 = time.perf_counter()
        tok, self.pool.cache = self._step_fn(self.params, self.pool.cache,
                                             batch)
        tok_np = np.asarray(tok)              # blocks until the step lands
        self.metrics.record_decode_step(len(active), len(active),
                                        time.perf_counter() - t0)
        now = time.perf_counter()
        for i in active:
            st = self.slots[i]
            t = int(tok_np[i, 0])
            done = st.remaining == 1 or t == st.request.eos_id
            st.emit(t, is_last=done, now=now)
            st.pos += 1
            st.steps_done += 1
            if done:
                self._retire(st, "eos" if t == st.request.eos_id
                             else "length")
            else:
                self._tok[i, 0] = t
                self._pos[i] = st.pos
                self._t[i] += 1

    def _retire(self, st: GenState, reason: str) -> None:
        slot = st.slot
        if self.slots[slot] is st:
            self.slots[slot] = None
        self._pos[slot] = -1
        self._tok[slot, 0] = 0
        self._temp[slot] = 0.0
        self._topk[slot] = 0
        self._topp[slot] = 0.0
        self._key[slot] = 0
        self._t[slot] = 0
        self.pool.release(slot)
        ttft = st.first_token_time - self._submit_time.get(
            st.request.id, st.admitted_time)
        self.metrics.record_finish(ttft)
        self.finished[st.request.id] = FinishedRequest(
            id=st.request.id,
            tokens=np.asarray(st.generated, np.int32),
            prompt_len=st.request.prompt_len,
            admitted_step=st.admitted_step,
            finished_step=self.step_count,
            ttft_s=ttft,
            reason=reason)
