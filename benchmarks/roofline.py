"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
derives, per (arch × shape × mesh):

  compute term    = FLOPs_per_device / peak_FLOPs            [s]
  memory term     = bytes_per_device / HBM_bw                [s]
  collective term = collective_bytes_per_device / ICI_bw     [s]

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs, and the dominant bottleneck.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
                                                     [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import SHAPES_BY_NAME, get_config
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               PRODUCTION_MESH_SHAPES)


def count_params(cfg) -> dict:
    """Exact param counts from the abstract init tree (no allocation).
    Returns {"total": N, "active": N_active} (MoE: routed experts scaled
    by top_k/E)."""
    from repro.launch.specs import param_shapes
    tree = param_shapes(cfg)

    def walk(t, path=()):
        total = active = 0
        if isinstance(t, dict):
            for k, v in t.items():
                a, b = walk(v, path + (k,))
                total += a
                active += b
            return total, active
        n = 1
        for s in t.shape:
            n *= s
        frac = 1.0
        if cfg.moe is not None and any(
                p in ("gate_proj", "up_proj", "down_proj") for p in path):
            frac = cfg.moe.top_k / cfg.moe.num_experts
        return n, int(n * frac)

    total, active = walk(tree)
    return {"total": total, "active": active}


def model_flops(cfg, shape) -> float:
    """Architectural 'useful' FLOPs for the step (global, all devices)."""
    n = count_params(cfg)["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


import functools


@functools.lru_cache(maxsize=None)
def fed_expected_collective_bytes(cfg, mesh_name: str) -> int:
    """Analytic per-device collective bytes for one federated aggregation
    round, from repro.dist.fed's axis mapping (ring all-reduce of the LoRA
    payload over the data/pod axes).  The measured HLO collective bytes of
    a fed_train step should be dominated by (and never smaller than) this
    term — the Fig. 5 comm metric and the roofline collective term are the
    same quantity measured two ways."""
    from repro.dist import fed
    from repro.launch.specs import param_shapes
    tree = param_shapes(cfg, fed=True)
    per_axis = fed.expected_collective_bytes(
        tree, PRODUCTION_MESH_SHAPES[mesh_name])
    return sum(per_axis.values())


def load_results(directory: str):
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyze_one(r: dict) -> dict:
    cfg = get_config(r["arch"])
    shape = SHAPES_BY_NAME[r["shape"]]
    flops_dev = r["flops_per_device"]
    bytes_dev = r["bytes_accessed_per_device"]
    coll_dev = r["collectives"]["total_bytes"]

    t_compute = flops_dev / PEAK_FLOPS_BF16
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * r["num_devices"]
    ratio = mf / hlo_global if hlo_global else 0.0
    bound_time = max(terms.values())
    frac_of_roofline = (t_compute / bound_time) if bound_time else 0.0

    fed_coll = 0
    if r.get("fed", False) and r["mesh"] in PRODUCTION_MESH_SHAPES:
        fed_coll = fed_expected_collective_bytes(cfg, r["mesh"])

    return {
        **{k: r[k] for k in ("arch", "shape", "mesh", "step_kind",
                             "num_devices", "compile_s")},
        "fed": r.get("fed", False),
        "fed_coll_expected_bytes": fed_coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": ratio,
        "compute_fraction_of_bound": frac_of_roofline,
        "temp_gib": r["memory"]["temp_bytes"] / 2 ** 30,
        "arg_gib": r["memory"]["argument_bytes"] / 2 ** 30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--csv", action="store_true", default=True)
    args = ap.parse_args()

    rows = [analyze_one(r) for r in load_results(args.dir)]
    if not rows:
        print("no dryrun results found; run repro.launch.dryrun first",
              file=sys.stderr)
        return

    if args.markdown:
        cols = ["arch", "shape", "mesh", "step_kind", "t_compute_s",
                "t_memory_s", "t_collective_s", "dominant", "useful_ratio",
                "temp_gib"]
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
        for r in rows:
            vals = []
            for c in cols:
                v = r[c]
                vals.append(f"{v:.3e}" if isinstance(v, float) else str(v))
            print("| " + " | ".join(vals) + " |")
    else:
        for r in rows:
            print(f"roofline,arch={r['arch']},shape={r['shape']},"
                  f"mesh={r['mesh']},fed={r['fed']},"
                  f"compute_s={r['t_compute_s']:.4e},"
                  f"memory_s={r['t_memory_s']:.4e},"
                  f"collective_s={r['t_collective_s']:.4e},"
                  f"dominant={r['dominant']},"
                  f"useful_ratio={r['useful_ratio']:.3f},"
                  f"temp_gib={r['temp_gib']:.2f}" +
                  (f",fed_coll_expected_bytes={r['fed_coll_expected_bytes']}"
                   if r["fed"] else ""))


if __name__ == "__main__":
    main()
