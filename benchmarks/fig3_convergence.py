"""Paper Figure 3: centralized-vs-federated convergence curves.

The paper reports the federated model converging ~3x faster (70 vs 200+
epochs); we reproduce the comparison under identical budgets and report
rounds/steps-to-threshold.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, fast_fedtime_config, forecast_data


def run(full: bool = False):
    from repro.core import fedtime
    from repro.data.federated import client_windows, partition_clients
    from repro.data.timeseries import DATASETS, generate, train_test_split
    from repro.train.fed_trainer import federated_fit
    from repro.train.trainer import fit

    lookback, T = (512, 96) if full else (96, 24)
    rounds = 12 if full else 4
    cfg = fast_fedtime_config(horizon=T, lookback=lookback)

    series = generate(DATASETS["etth1"], timesteps=8000 if full else 2400)
    tr, _ = train_test_split(series)
    clients = partition_clients(tr, 8, seed=0, channels_per_client=2)
    cdata = client_windows(clients, lookback, T, max_windows=64)

    # ---- federated ----
    res = federated_fit(cfg, cdata, rounds=rounds, batch_size=8)
    fed_curve = {}
    for log in res.logs:
        fed_curve.setdefault(log.round, []).append(log.train_loss)
    for r, losses in sorted(fed_curve.items()):
        emit("fig3", mode="federated", round=r,
             loss=round(float(np.mean(losses)), 4))

    # ---- centralized (same backbone, all data pooled, full fine-tune) ----
    M = 2
    params = fedtime.init(cfg, jax.random.PRNGKey(0), num_channels=M)
    x_all = np.concatenate([x for x, _ in cdata])
    y_all = np.concatenate([y for _, y in cdata])

    def batches():
        rng = np.random.default_rng(0)
        while True:
            s = rng.integers(0, len(x_all), 8)
            yield {"x": x_all[s], "y": y_all[s]}

    steps_per_round = cfg.fedtime.local_steps * cfg.fedtime.clients_per_round
    params, logs, _ = fit(
        lambda p, b: fedtime.loss(p, cfg, b), params, batches(),
        steps=rounds * steps_per_round, lr=1e-3)
    for r in range(rounds):
        chunk = logs[r * steps_per_round:(r + 1) * steps_per_round]
        emit("fig3", mode="centralized", round=r,
             loss=round(float(np.mean([l.loss for l in chunk])), 4))

    # steps-to-threshold summary (the paper's 3x claim, measured)
    fed_losses = [float(np.mean(v)) for _, v in sorted(fed_curve.items())]
    cen_losses = [float(np.mean([l.loss for l in
                                 logs[r * steps_per_round:
                                      (r + 1) * steps_per_round]]))
                  for r in range(rounds)]
    thresh = min(min(fed_losses), min(cen_losses)) * 1.5
    fed_hit = next((i for i, l in enumerate(fed_losses) if l <= thresh),
                   rounds)
    cen_hit = next((i for i, l in enumerate(cen_losses) if l <= thresh),
                   rounds)
    emit("fig3_summary", threshold=round(thresh, 4),
         federated_rounds_to_thresh=fed_hit,
         centralized_rounds_to_thresh=cen_hit)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
