"""Paper Figure 2: forecasting MSE vs look-back window length L."""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, fast_fedtime_config


def run(full: bool = False):
    from repro.core import fedtime
    from repro.data.federated import client_windows, partition_clients
    from repro.data.timeseries import (DATASETS, generate, make_windows,
                                       train_test_split)
    from repro.train.fed_trainer import federated_fit
    from repro.train.trainer import evaluate_forecaster

    lookbacks = [24, 48, 96, 192, 336, 720] if full else [24, 48, 96]
    T = 720 if full else 24
    rounds = 8 if full else 2

    series = generate(DATASETS["etth1"], timesteps=8000 if full else 3000)
    tr, te = train_test_split(series)

    for L in lookbacks:
        # keep patching valid: stride divides (L - patch)
        patch = 8 if L <= 96 else 16
        stride = patch // 2
        import dataclasses
        cfg = fast_fedtime_config(horizon=T, lookback=L)
        cfg = cfg.replace(fedtime=dataclasses.replace(
            cfg.fedtime, patch_len=patch, patch_stride=stride))
        clients = partition_clients(tr, 8, seed=0, channels_per_client=2)
        cdata = client_windows(clients, L, T, max_windows=48)
        res = federated_fit(cfg, cdata, rounds=rounds, batch_size=8)
        params = res.params_for_cluster(0)
        xte, yte = make_windows(te, L, T, stride=16)
        Mc = cdata[0][0].shape[-1]
        m = evaluate_forecaster(
            lambda q, x: fedtime.forward(q, cfg, x), params,
            xte[..., :Mc], yte[..., :Mc])
        emit("fig2", lookback=L, horizon=T, method="fedtime",
             mse=round(m["mse"], 4), mae=round(m["mae"], 4))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
