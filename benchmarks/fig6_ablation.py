"""Paper Figure 6: FedTime variants on the ACN (EV charging) setting —
without clustering (K=1), without PEFT (full-model federation), and the
full clustering+PEFT model."""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from benchmarks.common import emit, fast_fedtime_config


def run(full: bool = False):
    from repro.core import fedtime
    from repro.data.federated import client_windows, partition_clients
    from repro.data.timeseries import (DATASETS, generate, make_windows,
                                       train_test_split)
    from repro.train.fed_trainer import federated_fit
    from repro.train.trainer import evaluate_forecaster

    L, T = (512, 96) if full else (96, 24)
    rounds = 8 if full else 2

    series = generate(DATASETS["acn-caltech"],
                      timesteps=8000 if full else 3000)
    tr, te = train_test_split(series)
    clients = partition_clients(tr, 8, seed=0, channels_per_client=2)
    cdata = client_windows(clients, L, T, max_windows=48)
    xte, yte = make_windows(te, L, T, stride=16)

    base = fast_fedtime_config(horizon=T, lookback=L)
    variants = {
        "clustering+peft": base,
        "no_clustering": base.replace(
            fedtime=dataclasses.replace(base.fedtime, num_clusters=1)),
        "no_peft": base.replace(
            fedtime=dataclasses.replace(base.fedtime, qlora=False,
                                        lora_rank=64)),  # ~full capacity
    }

    for name, cfg in variants.items():
        res = federated_fit(cfg, cdata, rounds=rounds, batch_size=8)
        params = res.params_for_cluster(0)
        Mc = cdata[0][0].shape[-1]
        m = evaluate_forecaster(
            lambda q, x: fedtime.forward(q, cfg, x), params,
            xte[..., :Mc], yte[..., :Mc])
        emit("fig6", variant=name, mse=round(m["mse"], 4),
             mae=round(m["mae"], 4),
             comm_mb=round(res.total_megabytes(), 2),
             trainable_frac=round(res.trainable_frac, 4))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
