"""Shared benchmark scaffolding.

Every benchmark runs in FAST mode by default (CPU-sized models, minutes) and
accepts ``--full`` for paper-scale settings; both print ``name,value,...``
CSV rows so ``benchmarks/run.py`` can tee everything into bench_output.txt.
"""

from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import numpy as np


def emit(row_name: str, **fields):
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{row_name},{kv}", flush=True)
    return {"row": row_name, **fields}


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def fast_fedtime_config(horizon: int = 24, lookback: int = 96):
    from repro.configs import get_smoke_config
    from repro.configs.base import FedTimeConfig
    cfg = get_smoke_config("fedtime-llama2-7b")
    return cfg.replace(fedtime=FedTimeConfig(
        lookback=lookback, horizon=horizon, patch_len=8, patch_stride=4,
        num_clients=8, num_clusters=2, clients_per_round=4, local_steps=4,
        lora_rank=4, dpo_pairs=16))


def forecast_data(dataset: str, lookback: int, horizon: int, *,
                  timesteps: int = 2400, seed: int = 0):
    from repro.data.timeseries import (DATASETS, generate, make_windows,
                                       train_test_split)
    series = generate(DATASETS[dataset], timesteps=timesteps, seed=seed)
    tr, te = train_test_split(series)
    xtr, ytr = make_windows(tr, lookback, horizon, stride=2)
    xte, yte = make_windows(te, lookback, horizon, stride=8)
    return (xtr, ytr), (xte, yte), series
