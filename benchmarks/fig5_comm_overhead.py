"""Paper Figure 5: communication overhead (data volume MB, message count,
modelled time) — FedTime vs full-model federation vs centralized shipping,
on the ACN EV-charging setting (Caltech + JPL).

Exact byte accounting from repro.core.comm; also reports the mesh-mapped
collective bytes (DESIGN.md §3) so this figure and §Roofline's collective
term are the same quantity measured two ways.
"""

from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, fast_fedtime_config


def run(full: bool = False):
    from repro.core import comm, fedtime
    from repro.core.lora import (FAMILY_TARGETS, attach_lora, lora_tree,
                                 quantize_base, tree_nbytes,
                                 trainable_fraction)

    # paper scale when --full: LLaMA-2-7B backbone, 555 devices
    from repro.configs import get_config, get_smoke_config
    cfg = get_config("fedtime-llama2-7b") if full else fast_fedtime_config()
    ft = cfg.fedtime

    if full:
        # abstract tree only (7B would not fit this host) — byte accounting
        # needs shapes, not values
        from repro.launch.specs import param_shapes
        params = param_shapes(cfg, fed=True)
    else:
        params = fedtime.init(cfg, jax.random.PRNGKey(0), num_channels=3)
        params = attach_lora(params, jax.random.PRNGKey(1),
                             rank=ft.lora_rank, alpha=ft.lora_alpha,
                             targets=FAMILY_TARGETS["dense"])
        if ft.qlora:
            params = quantize_base(params, qblock=ft.qlora_block,
                                   targets=FAMILY_TARGETS["dense"])

    n_round = ft.clients_per_round
    k = ft.num_clusters
    rounds = 70 if full else 10          # paper: FedTime converges in ~70

    # baseline pinned to f32: the row's meaning must not drift with an
    # ambient REPRO_FED_WIRE — the figure exists to show the comparison
    ftime = comm.fedtime_round(params, clients_per_round=n_round,
                               num_clusters=k, wire="f32")
    # the communication fast path's wire format (REPRO_FED_WIRE=int8):
    # int8 codes + per-qblock absmax scales, error-feedback debiased
    fti8 = comm.fedtime_round(params, clients_per_round=n_round,
                              num_clusters=k, wire="int8")
    ffull = comm.fed_full_round(params, clients_per_round=n_round,
                                num_clusters=k)
    cen = comm.centralized_epoch(num_samples=1_500_000 if full else 10_000,
                                 lookback=ft.lookback, horizon=ft.horizon,
                                 channels=54, num_clients=ft.num_clients)

    for name, st, n in [("fedtime", ftime, rounds),
                        ("fedtime_int8", fti8, rounds),
                        ("fed_full_model", ffull, rounds),
                        ("centralized_data", cen, 1)]:
        emit("fig5", method=name,
             mb_per_round=round(st.megabytes, 3),
             total_mb=round(st.megabytes * n, 2),
             messages=st.messages * n,
             modelled_time_s=round(st.time_s * n, 2))

    emit("fig5_detail",
         lora_payload_mb=round(tree_nbytes(lora_tree(params)) / 1e6, 4),
         full_model_mb=round(tree_nbytes(params) / 1e6, 2),
         trainable_frac=round(trainable_fraction(params), 4))

    for mesh_shape, name in [({"data": 16, "model": 16}, "single_pod"),
                             ({"pod": 2, "data": 16, "model": 16},
                              "multi_pod")]:
        for wire in ("f32", "int8"):
            cb = comm.collective_bytes_per_round(params, mesh_shape,
                                                 wire=wire)
            emit("fig5_mesh", mesh=name, wire=wire,
                 **{f"{k}_mb": round(v / 1e6, 3) for k, v in cb.items()})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
