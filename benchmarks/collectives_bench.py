"""Collectives microbench — the federated communication fast path.

Two A/Bs, both in a subprocess (the emulated device count must be set
before jax initializes):

  * ring vs XLA psum at matched payload, per wire format: per-device bytes
    per aggregation round (the kernel's measured byte ledger — identical to
    the ``ring_wire_plan`` accounting) and wall time per round on the
    emulated 8-way data mesh.  The headline number: the int8 wire moves
    <= 0.27x the bytes of the f32 psum baseline.
  * ZeRO-1 AdamW gather vs scatter formulation: compiled collective bytes
    from the dry-run HLO cost model (``repro.launch.hlo_cost``) — the
    scatter-update schedule must be strictly smaller.

``benchmarks/run.py --only collectives`` writes the rows to
``BENCH_collectives.json`` (the per-PR comm-perf trajectory artifact).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_SUB = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.pop("REPRO_FED_WIRE", None)
os.environ.pop("REPRO_FED_RING", None)
import jax, jax.numpy as jnp, numpy as np
from repro.core.comm import ring_wire_plan
from repro.dist import fed, fedcomm

FULL = __FULL__
E = (1 << 22) if FULL else (1 << 20)          # payload elems per member
ITERS = 5
mesh = jax.make_mesh((8, 1), ("data", "model"))
ndev = 8
rng = np.random.default_rng(0)
n = 8
members = {"lora_a": jnp.asarray(rng.normal(size=(n, E)).astype(np.float32))}
w = jnp.full((n,), 1.0 / n)
exact = np.asarray(members["lora_a"]).mean(axis=0)


def timed(f):
    f()                                        # compile
    ts = []
    for _ in range(ITERS):
        t0 = time.perf_counter()
        jax.block_until_ready(f())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6          # us


rows = []
with mesh:
    # --- XLA psum baseline (f32; assumed ring lowering => classic bytes)
    os.environ["REPRO_FED_RING"] = "0"
    us = timed(lambda: fed.aggregate_adapters(members, w, mesh))
    del os.environ["REPRO_FED_RING"]
    f32_psum_bytes = ring_wire_plan(E, ndev, "f32").per_device_bytes
    rows.append({"case": "psum_xla", "wire": "f32",
                 "bytes_per_round": f32_psum_bytes, "us_per_round": us,
                 "bytes_vs_f32_psum": 1.0})

    # --- hand-rolled bidirectional ring, every wire format
    for wire in ("f32", "bf16", "int8"):
        ledger = []
        out = fedcomm.ring_aggregate(members, w, mesh, wire=wire,
                                     byte_ledger=ledger)
        measured = sum(b for _, b in ledger)
        plan = ring_wire_plan(E, ndev, wire)
        assert measured == plan.per_device_bytes, (wire, measured, plan)
        err = float(np.abs(np.asarray(out["lora_a"]) - exact).max())
        us = timed(lambda: fedcomm.ring_aggregate(members, w, mesh,
                                                  wire=wire))
        rows.append({"case": "ring", "wire": wire,
                     "bytes_per_round": measured, "us_per_round": us,
                     "bytes_vs_f32_psum": measured / f32_psum_bytes,
                     "max_abs_err": err})

# --- ZeRO-1 update: gather vs scatter collective term (dry-run cost model)
from repro.configs import get_smoke_config
from repro.launch.hlo_cost import analyze
from repro.models.registry import get_model
from repro.dist.sharding import param_specs, opt_state_specs, to_shardings
from repro.optim.adamw import adamw_init, adamw_update, adamw_update_zero1

cfg = get_smoke_config("qwen3-0.6b")
api = get_model(cfg)
zmesh = jax.make_mesh((4, 2), ("data", "model"))
params = api.init(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)
psh = to_shardings(param_specs(params, zmesh), zmesh)
osh = to_shardings(opt_state_specs(params, zmesh), zmesh)
with zmesh:
    for name, fn in (("zero1_gather",
                      lambda p, g, s: adamw_update(p, g, s, 3)),
                     ("zero1_scatter",
                      lambda p, g, s: adamw_update_zero1(p, g, s, 3,
                                                         mesh=zmesh))):
        jitted = jax.jit(fn, in_shardings=(psh, psh, {"mu": osh, "nu": osh}),
                         out_shardings=(psh, {"mu": osh, "nu": osh}))
        parsed = analyze(jitted.lower(params, params, opt).compile()
                         .as_text())
        rows.append({"case": name,
                     "collective_bytes": parsed["collective_total_bytes"],
                     "by_kind": parsed["collective_bytes"]})

for r in rows:
    print("ROW " + json.dumps(r), flush=True)
"""


def run(full: bool = False):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SUB.replace("__FULL__", str(full))],
        env=env, capture_output=True, text=True, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"collectives subprocess failed:\n{r.stdout}\n"
                           f"{r.stderr}")
    rows = []
    for line in r.stdout.splitlines():
        if line.startswith("ROW "):
            rows.append(emit("collectives", **json.loads(line[4:])))
    scatter = next(x for x in rows if x.get("case") == "zero1_scatter")
    gather = next(x for x in rows if x.get("case") == "zero1_gather")
    int8 = next(x for x in rows if x.get("case") == "ring"
                and x.get("wire") == "int8")
    rows.append(emit(
        "collectives_summary",
        int8_vs_f32_psum=round(int8["bytes_vs_f32_psum"], 4),
        int8_under_027=int8["bytes_vs_f32_psum"] <= 0.27,
        zero1_scatter_smaller=(scatter["collective_bytes"] <
                               gather["collective_bytes"]),
        zero1_collective_cut=round(
            1 - scatter["collective_bytes"] / gather["collective_bytes"],
            4)))
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
