"""Serving-engine benchmarks.

Row 1 — engine vs sequential: on a staggered 8-request Poisson trace the
continuous-batching step loop must beat the fixed-batch launcher serving
the same requests one after another (the only thing the repo could do
before the engine existed).  Both paths run the same compiled kernels and
are warmed before timing, so the delta is pure scheduling.

Row 2 — paged vs contiguous at EQUAL pool bytes: a heterogeneous-length
trace (few long forecasts + many short ones, the FedTime edge-client mix)
through the paged block pool and through contiguous lanes backed by the
same number of cache bytes.  Contiguous concurrency is capped at its lane
count no matter how small the requests are; the paged pool admits by block
footprint, so the same bytes hold strictly more requests in flight — the
row reports the peak-concurrency and aggregate-tok/s ratios, and asserts
the two engines' greedy outputs are bit-identical.

Row 3 — shared-prefix pool vs non-shared paged pool at EQUAL pool bytes: a
cluster-skewed trace (per cluster: one donor prompt, several identical
replays, one divergent-tail member — federated clients replaying a common
context window) through the same paged geometry twice, once with
copy-on-write prefix sharing + the host swap tier and once without.
Full-prompt chain hits admit at zero block cost and skip their prefill
entirely, so the shared pool sustains a multiple of the baseline's peak
concurrency; the row records the ratio plus share/CoW/swap counters and
asserts greedy outputs are bit-identical between the two engines.

Row 4 — Zipf-cluster synthetic trace through the shared pool: cluster
sizes drawn rank-Zipf (one head cluster dominating, singleton tail — the
fleet-shaped request mix a federated deployment actually sees), reporting
share-hit / full-hit / swap rates plus per-cluster TTFT percentiles rolled
up through the mergeable fleet ledger.

Row 5 — serving chaos: a staggered bounded-queue trace with ~25% injected
request-level faults (malformed prompts, NaN-poisoned lanes, unmeetable
deadlines, submit bursts) on the virtual clock, with the write-ahead
request journal armed.  Shed requests retry after their ``retry_after_s``
hint; the row reports shed/quarantine/deadline counters and the gated
invariants: zero greedy mismatches among survivors, zero requests left
unfinished after journal replay, one compiled serve_step signature.

Rows land in BENCH_serving.json via benchmarks/run.py.
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np


def _sequential_baseline(api, cfg, params, trace, cache_len):
    """The pre-engine serving story: requests decoded one at a time
    (fixed batch of 1) in arrival order, through the same compiled step."""
    from repro.launch.steps import make_serve_step
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    def one(prompt, gen):
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        cache, logits = api.prefill(params, cfg, {"tokens": toks},
                                    cache_len=cache_len)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        out = [int(tok[0, 0])]
        P = toks.shape[1]
        for i in range(gen - 1):
            tok, cache = serve(params, cache,
                               {"token": tok,
                                "pos": jnp.asarray(P + i, jnp.int32)})
            out.append(int(tok[0, 0]))
        jax.block_until_ready(tok)
        return out

    return one


def _warmed_engine(cfg, params, prompt_lens, probe_prompt, *, slots,
                   cache_len, **ekw):
    """Engine with every prefill signature in the trace + the serve/insert/
    first-token jits warmed, metrics reset — timed runs measure scheduling,
    not compilation."""
    from repro.serve import ForecastEngine, Request
    from repro.serve.metrics import EngineMetrics
    engine = ForecastEngine(cfg, params, num_slots=slots,
                            cache_len=cache_len, **ekw)
    for j, plen in enumerate(sorted(set(prompt_lens))):
        engine.submit(Request(id=f"warm{j}",
                              prompt=np.asarray(probe_prompt[:1] * plen,
                                                np.int32),
                              max_new_tokens=2))
    engine.run()
    offset = engine.step_count                # trace arrivals are relative
    engine.metrics = EngineMetrics(slots,
                                   pool_blocks=engine.pool.pool_blocks)
    engine.finished.clear()                   # drop warmup records
    return engine, offset


def _paged_vs_contiguous_case(full: bool):
    """Heterogeneous-length trace, equal pool bytes: contiguous lanes vs
    the paged block pool."""
    from repro.configs import get_smoke_config
    from repro.models.registry import get_model
    from repro.serve import Request

    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(1))

    cache_len = 96 if full else 48
    block = 8
    contig_slots = 3                          # pool bytes: 3 full lanes
    paged_slots = 14 if full else 10
    pool_blocks = contig_slots * (cache_len // block)   # same bytes
    n_short = 12 if full else 8
    long_p, long_g = (56, 40) if full else (28, 20)   # == a full lane
    short_p, short_g = (8, 12) if full else (6, 6)    # a few blocks
    rng = np.random.default_rng(11)
    reqs = [("L0", long_p, long_g), ("L1", long_p, long_g)] + [
        (f"S{i}", short_p, short_g) for i in range(n_short)]
    prompts = {rid: rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for rid, p, _ in reqs}

    def run_one(paged: bool):
        slots = paged_slots if paged else contig_slots
        ekw = dict(paged=True, block_size=block,
                   pool_blocks=pool_blocks) if paged else dict(paged=False)
        eng, _ = _warmed_engine(cfg, params, [p for _, p, _ in reqs],
                                prompts["L0"].tolist(), slots=slots,
                                cache_len=cache_len, **ekw)
        for rid, _, g in reqs:
            eng.submit(Request(id=rid, prompt=prompts[rid],
                               max_new_tokens=g))
        t0 = time.perf_counter()
        done = eng.run(max_steps=2000)
        wall = time.perf_counter() - t0
        toks = sum(len(f.tokens) for f in done.values())
        return eng, done, toks / wall

    eng_c, done_c, tps_c = run_one(paged=False)
    eng_p, done_p, tps_p = run_one(paged=True)
    mismatches = sum(done_p[rid].tokens.tolist() !=
                     done_c[rid].tokens.tolist() for rid, _, _ in reqs)
    sc, sp = eng_c.metrics.summary(), eng_p.metrics.summary()
    row = {
        "name": "serving_paged_vs_contiguous",
        "requests": len(reqs),
        "cache_len": cache_len,
        "block_size": block,
        "pool_blocks": pool_blocks,
        "contig_slots": contig_slots,
        "paged_slots": paged_slots,
        "peak_in_flight_contig": sc["peak_in_flight"],
        "peak_in_flight_paged": sp["peak_in_flight"],
        "concurrency_ratio": round(sp["peak_in_flight"]
                                   / max(sc["peak_in_flight"], 1), 2),
        "tok_per_s_contig": round(tps_c, 2),
        "tok_per_s_paged": round(tps_p, 2),
        "tok_per_s_ratio": round(tps_p / max(tps_c, 1e-9), 3),
        "mean_block_utilization_contig": round(
            sc["mean_block_utilization"], 3),
        "mean_block_utilization_paged": round(
            sp["mean_block_utilization"], 3),
        "parked_events": sp["parked_events"],
        "evictions": sp["evictions"],
        "greedy_mismatches": mismatches,
        "serve_step_signatures": eng_p.num_step_signatures(),
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    return row


def _cluster_skew_case(full: bool):
    """Cluster-skewed trace, equal pool bytes: CoW prefix sharing + swap
    tier vs the plain paged pool.  Per cluster one donor pays the prefill;
    identical replays full-hit the chain (0 blocks, 0 prefill) and
    divergent tails pay only their private blocks."""
    from repro.configs import get_smoke_config
    from repro.models.registry import get_model
    from repro.serve import Request

    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(2))

    cache_len, block = 48, 8
    n_clusters = 3
    n_dups = 4 if full else 2                 # identical replays per cluster
    gen = 12 if full else 8
    # core fills 3 blocks with the last only partial: a full-hit replay's
    # first own token lands IN a shared block -> copy-on-write fires
    core_len, tail_len = 22, 6
    slots = n_clusters * (n_dups + 2)         # every request could reside
    pool_blocks = 18                          # << slots * 6 blocks/lane
    rng = np.random.default_rng(7)
    cores = [rng.integers(0, cfg.vocab_size, core_len).astype(np.int32)
             for _ in range(n_clusters)]
    reqs = []                                 # (id, prompt, arrival)
    for c in range(n_clusters):
        reqs.append((f"c{c}d", cores[c], c))  # donors admit first
        # divergent tails queue BEFORE the replays: they pay real blocks,
        # so the non-shared baseline stalls on them while the shared pool
        # admits them at tail-only cost and the replays behind them free
        reqs.append((f"c{c}t", np.concatenate(
            [cores[c], rng.integers(0, cfg.vocab_size, tail_len)
             .astype(np.int32)]), n_clusters))
        for u in range(n_dups):
            reqs.append((f"c{c}u{u}", cores[c], n_clusters + 1 + u))

    def run_one(shared: bool):
        eng, offset = _warmed_engine(
            cfg, params, [core_len, core_len + tail_len], cores[0].tolist(),
            slots=slots, cache_len=cache_len, paged=True, block_size=block,
            pool_blocks=pool_blocks, share_prefixes=shared,
            swap_tier=shared)
        for rid, prompt, arr in reqs:
            eng.submit(Request(id=rid, prompt=prompt, max_new_tokens=gen,
                               arrival_step=arr + offset))
        t0 = time.perf_counter()
        done = eng.run(max_steps=2000)
        wall = time.perf_counter() - t0
        toks = sum(len(f.tokens) for f in done.values())
        return eng, done, toks / wall

    eng_b, done_b, tps_b = run_one(shared=False)
    eng_s, done_s, tps_s = run_one(shared=True)
    mismatches = sum(done_s[rid].tokens.tolist() !=
                     done_b[rid].tokens.tolist() for rid, _, _ in reqs)
    sb, ss = eng_b.metrics.summary(), eng_s.metrics.summary()
    row = {
        "name": "serving_shared_prefix",
        "requests": len(reqs),
        "clusters": n_clusters,
        "gen": gen,
        "cache_len": cache_len,
        "block_size": block,
        "pool_blocks": pool_blocks,
        "slots": slots,
        "peak_in_flight_baseline": sb["peak_in_flight"],
        "peak_in_flight_shared": ss["peak_in_flight"],
        "concurrency_ratio": round(ss["peak_in_flight"]
                                   / max(sb["peak_in_flight"], 1), 2),
        "prefill_tokens_baseline": sb["prefill_tokens"],
        "prefill_tokens_shared": ss["prefill_tokens"],
        "tok_per_s_baseline": round(tps_b, 2),
        "tok_per_s_shared": round(tps_s, 2),
        "share_hits": ss["share_hits"],
        "full_prompt_hits": ss["full_prompt_hits"],
        "shared_blocks": ss["shared_blocks"],
        "cow_copies": ss["cow_copies"],
        "swap_outs": ss["swap_outs"],
        "swap_ins": ss["swap_ins"],
        "evictions_shared": ss["evictions"],
        "greedy_mismatches": mismatches,
        "serve_step_signatures": eng_s.num_step_signatures(),
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    return row


def zipf_cluster_sizes(n_requests: int, n_clusters: int,
                       exponent: float = 1.2) -> np.ndarray:
    """Deterministic Zipf cluster sizes: size_k ∝ 1/k^exponent, rounded to
    sum exactly to ``n_requests`` with every cluster non-empty.  Rank 1 is
    the head cluster (the "millions of users replaying one context"
    regime); the tail clusters approximate singletons."""
    w = 1.0 / np.arange(1, n_clusters + 1, dtype=np.float64) ** exponent
    w /= w.sum()
    sizes = np.maximum(1, np.round(w * n_requests).astype(np.int64))
    while sizes.sum() > n_requests:
        sizes[int(np.argmax(sizes))] -= 1
    while sizes.sum() < n_requests:
        sizes[int(np.argmin(sizes))] += 1
    return sizes


def _zipf_trace_case(full: bool):
    """Zipf-distributed cluster sizes through the shared-prefix pool — the
    fleet-shaped synthetic trace (ROADMAP follow-up after PR 7).  Each
    cluster has one core prompt; the head cluster dominates the request
    count, so shared-prefix admission should turn most of the trace into
    full-prompt chain hits.  Per-request TTFTs land in a
    :class:`repro.obs.fleet.FleetLedger` keyed by cluster, so the row's
    latency percentiles come from the same mergeable-sketch roll-up the
    federated trainer uses; share-hit / swap rates come off the engine
    metrics."""
    from repro.configs import get_smoke_config
    from repro.models.registry import get_model
    from repro.obs.fleet import FleetLedger
    from repro.serve import Request

    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(3))

    cache_len, block = 48, 8
    n_req = 36 if full else 18
    n_clusters = 6
    exponent = 1.2
    sizes = zipf_cluster_sizes(n_req, n_clusters, exponent)
    core_len, tail_len, gen = 22, 6, 8
    slots = 16 if full else 12
    pool_blocks = 14                          # << slots·lane: swaps happen
    rng = np.random.default_rng(13)
    cores = [rng.integers(0, cfg.vocab_size, core_len).astype(np.int32)
             for _ in range(n_clusters)]

    reqs = []                                 # (id, cluster, prompt, arrival)
    for c, size in enumerate(sizes):
        for m in range(int(size)):
            if m == 0:                        # donor pays the prefill
                prompt, kind = cores[c], "donor"
            elif m % 3 == 2:                  # divergent tail: own blocks
                prompt = np.concatenate(
                    [cores[c], rng.integers(0, cfg.vocab_size, tail_len)
                     .astype(np.int32)])
                kind = "tail"
            else:                             # exact replay: chain full hit
                prompt, kind = cores[c], "replay"
            # donors (m=0) arrive first, then the member waves interleave
            reqs.append((f"z{c}m{m}", c, prompt, kind, m))

    eng, offset = _warmed_engine(
        cfg, params, [core_len, core_len + tail_len], cores[0].tolist(),
        slots=slots, cache_len=cache_len, paged=True, block_size=block,
        pool_blocks=pool_blocks, share_prefixes=True, swap_tier=True)
    for rid, c, prompt, kind, arr in reqs:
        eng.submit(Request(id=rid, prompt=prompt, max_new_tokens=gen,
                           arrival_step=arr + offset))
    t0 = time.perf_counter()
    done = eng.run(max_steps=4000)
    wall = time.perf_counter() - t0
    summ = eng.metrics.summary()

    ledger = FleetLedger()
    for i, (rid, c, prompt, kind, _) in enumerate(reqs):
        fin = done[rid]
        ledger.record(0, c, i, wall_s=fin.ttft_s, kind=kind,
                      tokens=len(fin.tokens))
    ttft = ledger.fleet_sketch("wall_s")
    head = ledger.cluster_sketch(0, "wall_s")
    admitted = max(summ["requests"], 1)
    row = {
        "name": "serving_zipf_trace",
        "requests": n_req,
        "clusters": n_clusters,
        "zipf_exponent": exponent,
        "head_cluster_size": int(sizes[0]),
        "cache_len": cache_len,
        "block_size": block,
        "pool_blocks": pool_blocks,
        "slots": slots,
        "peak_in_flight": summ["peak_in_flight"],
        "share_hits": summ["share_hits"],
        "full_prompt_hits": summ["full_prompt_hits"],
        "share_hit_rate": round(summ["share_hits"] / admitted, 3),
        "full_hit_rate": round(summ["full_prompt_hits"] / admitted, 3),
        "swap_outs": summ["swap_outs"],
        "swap_ins": summ["swap_ins"],
        "swap_out_rate": round(summ["swap_outs"] / admitted, 3),
        "evictions": summ["evictions"],
        "mean_fragmentation": round(summ["mean_fragmentation"], 3),
        "peak_fragmentation": round(summ["peak_fragmentation"], 3),
        "ttft_p50_s": round(ttft.quantile(50), 4),
        "ttft_p99_s": round(ttft.quantile(99), 4),
        "head_ttft_p99_s": round(head.quantile(99), 4),
        "tok_per_s": round(
            sum(len(f.tokens) for f in done.values()) / wall, 2),
        "unfinished": n_req - len([r for r in reqs if r[0] in done]),
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    return row


def _chaos_case(full: bool):
    """Fault-injected serving trace (ISSUE 10 acceptance shape): ~25% of
    the requests carry one request-scoped fault, backpressure sheds under
    a bounded queue (shed clients retry), SLOs run on the virtual clock,
    and every event is journaled.  Deterministic end to end — every gated
    number is scheduling arithmetic, not wall clock."""
    from repro.configs import get_smoke_config
    from repro.fault import FaultPlan
    from repro.fault.clock import VirtualClock
    from repro.models.registry import get_model
    from repro.serve import Request, replay_journal

    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(4))

    n_req = 24 if full else 16
    # seed 26 draws one fault of EACH request-scoped kind at exactly 25%
    plan = FaultPlan.random_serving(n_req, 0.25, seed=26)
    cache_len, step_s, max_queue = 48, 0.1, 2
    lens, gens = [6, 9, 7, 11], [5, 3, 6, 4]
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab_size,
                            lens[i % 4]).astype(np.int32)
               for i in range(n_req)]

    # fault-free reference: each request solo through the same kernels
    one = _sequential_baseline(api, cfg, params, None, cache_len)
    refs = {f"q{i}": one(prompts[i].tolist(), gens[i % 4])
            for i in range(n_req) if plan.kind_for(i) != "malformed"}

    jrnl = os.path.join(tempfile.mkdtemp(prefix="repro_chaos_"),
                        "req.jrnl")
    from repro.serve import ForecastEngine
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=cache_len,
                         clock=VirtualClock(), step_time_s=step_s,
                         max_queue=max_queue, journal=jrnl)

    def build(i):
        kind = plan.kind_for(i)
        prompt = prompts[i]
        if kind == "malformed":
            prompt = plan.malform_prompt(i, prompt, cfg.vocab_size)
        return Request(id=f"q{i}", prompt=prompt,
                       max_new_tokens=gens[i % 4],
                       deadline_s=0.05 if kind == "deadline" else None)

    pending = sorted((0 if plan.kind_for(i) == "burst" else i // 3, i)
                     for i in range(n_req))
    shed_events, t = 0, 0
    t0 = time.perf_counter()
    while pending or eng.scheduler.pending or eng.active_requests:
        if t >= 2000:
            break
        still = []
        for (due, i) in pending:
            if due > t:
                still.append((due, i))
                continue
            v = eng.submit(build(i))
            if plan.kind_for(i) == "poison" and v.ok:
                eng.poison(f"q{i}")
            if v.verdict == "shed":
                shed_events += 1
                still.append((t + int(v.retry_after_s / step_s) + 1, i))
            elif v.shed_id is not None:        # displaced victim retries
                shed_events += 1
                j = int(v.shed_id[1:])
                still.append(
                    (t + int(eng.shed_log[v.shed_id] / step_s) + 1, j))
        pending = sorted(still)
        eng.step()
        t += 1
    wall = time.perf_counter() - t0
    done = eng.finished
    eng.journal.close()
    state = replay_journal(jrnl)

    # survivors: clean finishes must match the fault-free run exactly;
    # a deadline-cancelled request's partial output must be a prefix
    mismatches = 0
    for rid, fin in done.items():
        got = fin.tokens.tolist()
        if fin.reason in ("length", "eos"):
            mismatches += got != refs[rid]
        elif fin.reason in ("deadline", "ttft_slo"):
            mismatches += got != refs[rid][:len(got)]
    summ = eng.metrics.summary()
    row = {
        "name": "serving_chaos",
        "requests": n_req,
        "injected_fault_rate": round(plan.fault_rate(n_req), 3),
        "faults": {k: len(plan.indices(k))
                   for k in sorted(set(plan.faults.values()))},
        "max_queue": max_queue,
        "slots": 2,
        "cache_len": cache_len,
        "step_time_s": step_s,
        "engine_steps": t,
        "shed_events": shed_events,
        "shed_rate": round(shed_events / n_req, 3),
        "quarantined": summ["quarantined"],
        "deadline_misses": summ["deadline_misses"],
        "ttft_slo_misses": summ["ttft_slo_misses"],
        "deadline_miss_rate": round(summ["deadline_miss_rate"], 3),
        "unaccounted": n_req - len(done) - len(eng.quarantined),
        "greedy_mismatches": mismatches,
        # the crash-recovery invariant: after the run the journal must
        # replay to NOTHING outstanding (every submit has its terminal)
        "unfinished": len(state.unfinished_ids),
        "journal_records": state.records,
        "journal_torn": int(state.torn),
        "tok_per_s": round(
            sum(len(f.tokens) for f in done.values()) / wall, 2),
        "serve_step_signatures": eng.num_step_signatures(),
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    return row


def run(full: bool = False):
    from repro.configs import get_smoke_config
    from repro.launch.serve import make_trace
    from repro.models.registry import get_model
    from repro.serve.request import Request, SamplingParams

    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))

    n_req = 8
    gen = 32 if full else 12
    max_prompt = 32 if full else 16
    trace = make_trace(cfg, n_req, gen=gen, max_prompt=max_prompt,
                       rate=0.75, seed=0)
    cache_len = max(len(r["prompt"]) + r["max_new_tokens"] for r in trace)
    slots = 4

    engine, offset = _warmed_engine(cfg, params,
                                    [len(r["prompt"]) for r in trace],
                                    trace[0]["prompt"], slots=slots,
                                    cache_len=cache_len)
    for r in trace:
        engine.submit(Request(
            id=r["id"], prompt=np.asarray(r["prompt"], np.int32),
            max_new_tokens=r["max_new_tokens"],
            arrival_step=r["arrival_step"] + offset,
            sampling=SamplingParams()))
    t0 = time.perf_counter()
    done = engine.run()
    engine_wall = time.perf_counter() - t0
    summ = engine.metrics.summary()
    total_tokens = sum(len(f.tokens) for f in done.values())
    engine_tok_s = total_tokens / engine_wall

    # --- sequential fixed-batch baseline (warmed the same way) ---
    one = _sequential_baseline(api, cfg, params, trace, cache_len)
    one(trace[0]["prompt"][:4], 2)            # warm prefill+decode jits
    t0 = time.perf_counter()
    seq_out = {r["id"]: one(r["prompt"], r["max_new_tokens"])
               for r in trace}
    seq_wall = time.perf_counter() - t0
    seq_tokens = sum(len(v) for v in seq_out.values())
    seq_tok_s = seq_tokens / seq_wall

    # greedy trace: engine must reproduce the sequential outputs exactly
    mismatches = sum(done[i].tokens.tolist() != seq_out[i]
                     for i in seq_out)

    row = {
        "name": "serving_engine_vs_sequential",
        "requests": n_req,
        "gen": gen,
        "slots": slots,
        "cache_len": cache_len,
        "engine_tok_per_s": round(engine_tok_s, 2),
        "sequential_tok_per_s": round(seq_tok_s, 2),
        "speedup": round(engine_tok_s / seq_tok_s, 3),
        "engine_wall_s": round(engine_wall, 3),
        "sequential_wall_s": round(seq_wall, 3),
        "mean_ttft_s": round(summ["mean_ttft_s"], 4),
        "mean_occupancy": round(summ["mean_occupancy"], 3),
        "decode_steps": summ["decode_steps"],
        "serve_step_signatures": engine.num_step_signatures(),
        "greedy_mismatches": mismatches,
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    return [row, _paged_vs_contiguous_case(full), _cluster_skew_case(full),
            _zipf_trace_case(full), _chaos_case(full)]


if __name__ == "__main__":
    run()
