"""Serving-engine benchmark: staggered Poisson trace, engine vs sequential.

The engine's claim is aggregate throughput under concurrent load: on a
staggered 8-request trace the continuous-batching step loop must beat the
fixed-batch launcher serving the same requests one after another (the only
thing the repo could do before the engine existed).  Both paths run the
same compiled kernels and are warmed before timing, so the delta is pure
scheduling: ragged batched decode vs sequential single-stream decode.

Rows land in BENCH_serving.json via benchmarks/run.py.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _sequential_baseline(api, cfg, params, trace, cache_len):
    """The pre-engine serving story: requests decoded one at a time
    (fixed batch of 1) in arrival order, through the same compiled step."""
    from repro.launch.steps import make_serve_step
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    def one(prompt, gen):
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        cache, logits = api.prefill(params, cfg, {"tokens": toks},
                                    cache_len=cache_len)
        tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
        out = [int(tok[0, 0])]
        P = toks.shape[1]
        for i in range(gen - 1):
            tok, cache = serve(params, cache,
                               {"token": tok,
                                "pos": jnp.asarray(P + i, jnp.int32)})
            out.append(int(tok[0, 0]))
        jax.block_until_ready(tok)
        return out

    return one


def run(full: bool = False):
    from repro.configs import get_smoke_config
    from repro.launch.serve import make_trace, run_engine
    from repro.models.registry import get_model
    from repro.serve import ForecastEngine
    from repro.serve.request import Request, SamplingParams
    from repro.serve.metrics import EngineMetrics

    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))

    n_req = 8
    gen = 32 if full else 12
    max_prompt = 32 if full else 16
    trace = make_trace(cfg, n_req, gen=gen, max_prompt=max_prompt,
                       rate=0.75, seed=0)
    cache_len = max(len(r["prompt"]) + r["max_new_tokens"] for r in trace)
    slots = 4

    # --- engine: warm EVERY prefill signature in the trace (one request
    # per distinct prompt length) + the serve/insert/first-token jits, so
    # the timed run measures scheduling, not compilation ---
    engine = ForecastEngine(cfg, params, num_slots=slots,
                            cache_len=cache_len)
    for j, plen in enumerate(sorted({len(r["prompt"]) for r in trace})):
        engine.submit(Request(id=f"warm{j}",
                              prompt=np.asarray(trace[0]["prompt"][:1] * plen,
                                                np.int32),
                              max_new_tokens=2))
    engine.run()
    offset = engine.step_count                # trace arrivals are relative
    engine.metrics = EngineMetrics(slots)
    engine.finished.clear()                   # drop warmup records
    for r in trace:
        engine.submit(Request(
            id=r["id"], prompt=np.asarray(r["prompt"], np.int32),
            max_new_tokens=r["max_new_tokens"],
            arrival_step=r["arrival_step"] + offset,
            sampling=SamplingParams()))
    t0 = time.perf_counter()
    done = engine.run()
    engine_wall = time.perf_counter() - t0
    summ = engine.metrics.summary()
    total_tokens = sum(len(f.tokens) for f in done.values())
    engine_tok_s = total_tokens / engine_wall

    # --- sequential fixed-batch baseline (warmed the same way) ---
    one = _sequential_baseline(api, cfg, params, trace, cache_len)
    one(trace[0]["prompt"][:4], 2)            # warm prefill+decode jits
    t0 = time.perf_counter()
    seq_out = {r["id"]: one(r["prompt"], r["max_new_tokens"])
               for r in trace}
    seq_wall = time.perf_counter() - t0
    seq_tokens = sum(len(v) for v in seq_out.values())
    seq_tok_s = seq_tokens / seq_wall

    # greedy trace: engine must reproduce the sequential outputs exactly
    mismatches = sum(done[i].tokens.tolist() != seq_out[i]
                     for i in seq_out)

    row = {
        "name": "serving_engine_vs_sequential",
        "requests": n_req,
        "gen": gen,
        "slots": slots,
        "cache_len": cache_len,
        "engine_tok_per_s": round(engine_tok_s, 2),
        "sequential_tok_per_s": round(seq_tok_s, 2),
        "speedup": round(engine_tok_s / seq_tok_s, 3),
        "engine_wall_s": round(engine_wall, 3),
        "sequential_wall_s": round(seq_wall, 3),
        "mean_ttft_s": round(summ["mean_ttft_s"], 4),
        "mean_occupancy": round(summ["mean_occupancy"], 3),
        "decode_steps": summ["decode_steps"],
        "serve_step_signatures": engine.num_step_signatures(),
        "greedy_mismatches": mismatches,
    }
    print(",".join(f"{k}={v}" for k, v in row.items()))
    return [row]


if __name__ == "__main__":
    run()
