"""Paper Table 2: long-term forecasting MSE/MAE — FedTime vs centralized
baselines (DLinear, PatchTST) + persistence, across datasets × horizons.

Absolute Table-2 values depend on LLaMA-2 pretrained text knowledge
(unavailable offline, DESIGN.md §6); the reproduction target is the
*ranking* under identical budgets.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fast_fedtime_config, forecast_data


def run(full: bool = False):
    from repro.baselines import dlinear, patchtst
    from repro.core import fedtime
    from repro.data.federated import client_windows, partition_clients
    from repro.train.fed_trainer import federated_fit
    from repro.train.trainer import evaluate_forecaster, fit

    datasets = (["weather", "traffic", "electricity", "etth1", "etth2",
                 "ettm1", "ettm2"] if full else ["etth1", "weather"])
    horizons = [96, 192, 336, 720] if full else [24, 48]
    lookback = 512 if full else 96
    steps = 400 if full else 40
    rounds = 10 if full else 3

    for ds in datasets:
        for T in horizons:
            (xtr, ytr), (xte, yte), _ = forecast_data(
                ds, lookback, T, timesteps=8000 if full else 2000)

            # persistence
            persist = np.repeat(xte[:, -1:, :], T, axis=1)
            emit("table2", dataset=ds, horizon=T, method="persistence",
                 mse=round(float(np.mean((persist - yte) ** 2)), 4),
                 mae=round(float(np.mean(np.abs(persist - yte))), 4))

            # DLinear
            p = dlinear.init(jax.random.PRNGKey(0), lookback, T)

            def batches(x=xtr, y=ytr):
                rng = np.random.default_rng(0)
                while True:
                    s = rng.integers(0, len(x), 64)
                    yield {"x": x[s], "y": y[s]}

            p, _, _ = fit(lambda pp, b: dlinear.loss(pp, b), p, batches(),
                          steps=steps, lr=5e-3)
            m = evaluate_forecaster(lambda pp, x: dlinear.forward(pp, x),
                                    p, xte, yte)
            emit("table2", dataset=ds, horizon=T, method="dlinear",
                 mse=round(m["mse"], 4), mae=round(m["mae"], 4))

            # PatchTST (centralized)
            cfgp = patchtst.make_config(lookback=lookback, horizon=T,
                                        d_model=64 if not full else 128,
                                        num_layers=2 if not full else 3,
                                        num_heads=4 if not full else 16,
                                        d_ff=128 if not full else 256,
                                        patch_len=8, stride=4)
            M = xtr.shape[-1]
            pp = patchtst.init(cfgp, jax.random.PRNGKey(1), num_channels=M)
            pp, _, _ = fit(lambda q, b: patchtst.loss(q, cfgp, b), pp,
                           batches(), steps=steps // 2, lr=1e-3)
            m = evaluate_forecaster(
                lambda q, x: patchtst.forward(q, cfgp, x), pp, xte, yte)
            emit("table2", dataset=ds, horizon=T, method="patchtst",
                 mse=round(m["mse"], 4), mae=round(m["mae"], 4))

            # FedTime (federated LLM)
            cfg = fast_fedtime_config(horizon=T, lookback=lookback)
            clients = partition_clients(
                _train_series(ds, full), cfg.fedtime.num_clients, seed=0,
                channels_per_client=min(M, 3))
            cdata = client_windows(clients, lookback, T, max_windows=64)
            res = federated_fit(cfg, cdata, rounds=rounds, batch_size=8)
            params = res.params_for_cluster(0)
            Mc = cdata[0][0].shape[-1]
            m = evaluate_forecaster(
                lambda q, x: fedtime.forward(q, cfg, x), params,
                xte[..., :Mc], yte[..., :Mc])
            emit("table2", dataset=ds, horizon=T, method="fedtime",
                 mse=round(m["mse"], 4), mae=round(m["mae"], 4))


def _train_series(ds: str, full: bool):
    from repro.data.timeseries import DATASETS, generate, train_test_split
    series = generate(DATASETS[ds], timesteps=8000 if full else 2000)
    tr, _ = train_test_split(series)
    return tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
