"""Benchmark runner — one harness per paper table/figure (+ kernels +
roofline).  Prints ``name,key=value,...`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # fast (CPU-minutes)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale settings
  PYTHONPATH=src python -m benchmarks.run --only table2,fig5
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated subset (table2,table3,fig2,fig3,"
                         "fig5,fig6,kernels,serving,collectives,roofline)")
    args = ap.parse_args()

    from benchmarks import (collectives_bench, fig2_lookback,
                            fig3_convergence, fig5_comm_overhead,
                            fig6_ablation, kernels_bench, serving_bench,
                            table2_forecasting, table3_federated)

    suites = {
        "table2": table2_forecasting.run,      # Table 2: MSE/MAE grid
        "table3": table3_federated.run,        # Table 3: federated compare
        "fig2": fig2_lookback.run,             # Fig 2: look-back sweep
        "fig3": fig3_convergence.run,          # Fig 3: convergence
        "fig5": fig5_comm_overhead.run,        # Fig 5: comm overhead
        "fig6": fig6_ablation.run,             # Fig 6: ablation
        "kernels": kernels_bench.run,          # kernel microbench
        "serving": serving_bench.run,          # engine + paged-pool A/Bs
        "collectives": collectives_bench.run,  # ring vs psum + ZeRO-1 A/Bs
    }
    only = set(filter(None, args.only.split(",")))
    unknown = only - set(suites) - {"roofline"}
    if unknown:
        ap.error(f"unknown suite(s) {sorted(unknown)}; choose from "
                 f"{sorted(suites) + ['roofline']}")

    failures = 0
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            rows = fn(full=args.full)
            if name == "kernels" and rows:
                # the perf trajectory artifact: kernel timings per PR
                with open("BENCH_kernels.json", "w") as f:
                    json.dump({"full": args.full, "rows": rows}, f, indent=2)
                print("# wrote BENCH_kernels.json", flush=True)
            if name == "serving" and rows:
                with open("BENCH_serving.json", "w") as f:
                    json.dump({"full": args.full, "rows": rows}, f, indent=2)
                print("# wrote BENCH_serving.json", flush=True)
            if name == "collectives" and rows:
                # the comm-perf trajectory artifact: ring vs psum bytes/us
                # per wire + ZeRO-1 gather vs scatter collective term
                with open("BENCH_collectives.json", "w") as f:
                    json.dump({"full": args.full, "rows": rows}, f, indent=2)
                print("# wrote BENCH_collectives.json", flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()

    if not only or "roofline" in only:
        print("# === roofline (from dry-run artifacts) ===", flush=True)
        try:
            import benchmarks.roofline as roofline
            sys.argv = ["roofline"]
            roofline.main()
        except Exception as e:
            print(f"# roofline skipped: {e}", flush=True)

    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
