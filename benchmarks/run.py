"""Benchmark runner — one harness per paper table/figure (+ kernels +
roofline).  Prints ``name,key=value,...`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # fast (CPU-minutes)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale settings
  PYTHONPATH=src python -m benchmarks.run --only table2,fig5
  PYTHONPATH=src python -m benchmarks.run --only kernels --gate

The three perf suites (kernels / serving / collectives) persist their rows
into ``BENCH_<suite>.json`` through ``repro.obs.bench_gate.write_bench``:
rows MERGE by identity key into whatever the file already holds (so
``--only serving`` refreshes the serving rows without clobbering the other
file's history — each suite owns its own file — and partial reruns within a
suite keep unmatched old rows), and every write stamps provenance (git SHA,
jax/jaxlib versions, device kind, REPRO_* env) next to the data.

``--gate`` turns the runner into a regression gate: the committed
``BENCH_*.json`` are loaded as BASELINE before the suites overwrite them,
the fresh rows are compared metric-by-metric against
``repro.obs.bench_gate.GATES`` (relative tolerance for wall-clock ratios,
exact for deterministic byte/count invariants, absolute floors
independent of baseline), and any regression fails the process — this is
what CI runs.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated subset (table2,table3,fig2,fig3,"
                         "fig5,fig6,kernels,serving,collectives,roofline)")
    ap.add_argument("--gate", action="store_true",
                    help="compare fresh perf rows against the committed "
                         "BENCH_*.json baselines and exit 1 on regression")
    args = ap.parse_args()

    from benchmarks import (collectives_bench, fig2_lookback,
                            fig3_convergence, fig5_comm_overhead,
                            fig6_ablation, kernels_bench, serving_bench,
                            table2_forecasting, table3_federated)
    from repro.obs import bench_gate

    suites = {
        "table2": table2_forecasting.run,      # Table 2: MSE/MAE grid
        "table3": table3_federated.run,        # Table 3: federated compare
        "fig2": fig2_lookback.run,             # Fig 2: look-back sweep
        "fig3": fig3_convergence.run,          # Fig 3: convergence
        "fig5": fig5_comm_overhead.run,        # Fig 5: comm overhead
        "fig6": fig6_ablation.run,             # Fig 6: ablation
        "kernels": kernels_bench.run,          # kernel microbench
        "serving": serving_bench.run,          # engine + paged-pool A/Bs
        "collectives": collectives_bench.run,  # ring vs psum + ZeRO-1 A/Bs
    }
    only = set(filter(None, args.only.split(",")))
    unknown = only - set(suites) - {"roofline"}
    if unknown:
        ap.error(f"unknown suite(s) {sorted(unknown)}; choose from "
                 f"{sorted(suites) + ['roofline']}")

    # gate baselines must be read BEFORE the suites rewrite the files
    baselines = {}
    if args.gate:
        current_prov = bench_gate.provenance()
        for suite in bench_gate.BENCH_SUITES:
            base = bench_gate.load_bench(suite)
            if base is None:
                print(f"# gate: no committed BENCH_{suite}.json — "
                      f"absolute bounds only", flush=True)
            baselines[suite] = base
            # cross-backend baselines make relative gates bogus: warn,
            # don't fail (absolute bounds still hold)
            for warning in bench_gate.provenance_drift(
                    bench_gate.load_provenance(suite), current_prov):
                print(f"# gate WARNING [{suite}]: {warning}", flush=True)

    failures = 0
    gate_results: dict = {}
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            rows = fn(full=args.full)
            if name in bench_gate.BENCH_SUITES and rows:
                # perf trajectory artifacts (merged, provenance-stamped)
                path = bench_gate.write_bench(name, rows, full=args.full)
                print(f"# wrote {path}", flush=True)
                if args.gate:
                    gate_results[name] = bench_gate.check_suite(
                        name, rows, baselines.get(name))
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()

    if not only or "roofline" in only:
        print("# === roofline (from dry-run artifacts) ===", flush=True)
        try:
            import benchmarks.roofline as roofline
            sys.argv = ["roofline"]
            roofline.main()
        except Exception as e:
            print(f"# roofline skipped: {e}", flush=True)

    if args.gate and gate_results:
        report = bench_gate.gate_report(gate_results)
        print(report, flush=True)
        if any(gate_results.values()):
            failures += 1

    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
