"""Paper Table 3: federated comparison at long horizon — FedTime vs
Fed-PatchTST vs FSLSTM under identical federation budgets."""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, fast_fedtime_config, forecast_data


def _full_local_update(loss_fn, params, batches, steps):
    """Full-model local training (the non-PEFT baselines ship everything)."""
    import jax.numpy as jnp
    from repro.optim.adamw import adamw_init, adamw_update
    grad_fn = jax.value_and_grad(loss_fn)
    opt = adamw_init(params)

    def step(carry, i):
        p, o = carry
        b = jax.tree.map(lambda a: a[i % a.shape[0]], batches)
        l, g = grad_fn(p, b)
        p, o = adamw_update(p, g, o, i + 1, lr=1e-3)
        return (p, o), l

    (params, _), losses = jax.lax.scan(step, (params, opt),
                                       jnp.arange(steps))
    return params, losses.mean()


def _federate_full_model(init_fn, loss_fn, forward_fn, cdata, *, rounds,
                         local_steps, key):
    """Full-weight FedAvg loop for the non-PEFT baselines (Fed-PatchTST,
    FSLSTM ship complete models each round)."""
    import jax.numpy as jnp
    from repro.optim.fedadam import fedavg
    params = init_fn(key)
    update = jax.jit(lambda p, b: _full_local_update(loss_fn, p, b,
                                                     local_steps))
    for r in range(rounds):
        updates, ws = [], []
        for s, (x, y) in enumerate(cdata):
            rng = np.random.default_rng(100 * r + s)
            sel = rng.integers(0, len(x), (local_steps, 8))
            batches = {"x": jnp.asarray(x[sel]), "y": jnp.asarray(y[sel])}
            p2, _ = update(params, batches)
            updates.append(p2)
            ws.append(len(x))
        params = fedavg(updates, np.asarray(ws, np.float32))
    return params


def run(full: bool = False):
    from repro.baselines import fslstm, patchtst
    from repro.core import fedtime
    from repro.data.federated import client_windows, partition_clients
    from repro.data.timeseries import DATASETS, generate, train_test_split
    from repro.train.fed_trainer import federated_fit
    from repro.train.trainer import evaluate_forecaster

    datasets = (["weather", "traffic", "electricity", "etth1", "etth2",
                 "ettm1", "ettm2"] if full else ["etth1"])
    T = 720 if full else 24
    lookback = 512 if full else 96
    rounds = 10 if full else 3

    for ds in datasets:
        (xtr, ytr), (xte, yte), _ = forecast_data(
            ds, lookback, T, timesteps=8000 if full else 2000)
        M = xtr.shape[-1]
        series = generate(DATASETS[ds], timesteps=8000 if full else 2000)
        tr, _ = train_test_split(series)
        clients = partition_clients(tr, 8, seed=0,
                                    channels_per_client=min(M, 3))
        cdata = client_windows(clients, lookback, T, max_windows=64)
        Mc = cdata[0][0].shape[-1]

        # FedTime
        cfg = fast_fedtime_config(horizon=T, lookback=lookback)
        res = federated_fit(cfg, cdata, rounds=rounds, batch_size=8)
        params = res.params_for_cluster(0)
        m = evaluate_forecaster(lambda q, x: fedtime.forward(q, cfg, x),
                                params, xte[..., :Mc], yte[..., :Mc])
        emit("table3", dataset=ds, horizon=T, method="fedtime",
             mse=round(m["mse"], 4), mae=round(m["mae"], 4),
             comm_mb=round(res.total_megabytes(), 2))

        # Fed-PatchTST (full-model federation)
        cfgp = patchtst.make_config(lookback=lookback, horizon=T,
                                    d_model=64, num_layers=2, num_heads=4,
                                    d_ff=128, patch_len=8, stride=4)
        pp = _federate_full_model(
            lambda k: patchtst.init(cfgp, k, num_channels=Mc),
            lambda p, b: patchtst.loss(p, cfgp, b),
            lambda p, x: patchtst.forward(p, cfgp, x),
            cdata, rounds=rounds, local_steps=4, key=jax.random.PRNGKey(1))
        from repro.core.lora import tree_nbytes
        comm_mb = 2 * tree_nbytes(pp) * len(cdata) * rounds / 1e6
        m = evaluate_forecaster(lambda q, x: patchtst.forward(q, cfgp, x),
                                pp, xte[..., :Mc], yte[..., :Mc])
        emit("table3", dataset=ds, horizon=T, method="fed-patchtst",
             mse=round(m["mse"], 4), mae=round(m["mae"], 4),
             comm_mb=round(comm_mb, 2))

        # FSLSTM (full-model federation)
        pf = _federate_full_model(
            lambda k: fslstm.init(k, channels=Mc, horizon=T, d_hidden=32),
            lambda p, b: fslstm.loss(p, b),
            lambda p, x: fslstm.forward(p, x),
            cdata, rounds=rounds, local_steps=4, key=jax.random.PRNGKey(2))
        comm_mb = 2 * tree_nbytes(pf) * len(cdata) * rounds / 1e6
        m = evaluate_forecaster(lambda q, x: fslstm.forward(q, x),
                                pf, xte[..., :Mc], yte[..., :Mc])
        emit("table3", dataset=ds, horizon=T, method="fslstm",
             mse=round(m["mse"], 4), mae=round(m["mae"], 4),
             comm_mb=round(comm_mb, 2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
