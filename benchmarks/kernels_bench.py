"""Kernel micro-benchmarks: us_per_call of the jnp oracle paths on this
host + derived TPU-projected arithmetic intensities for the Pallas kernels.

(Wall-clock on CPU measures the oracle; the Pallas kernels themselves are
dry-run artifacts — their projected VMEM working sets and FLOP/byte ratios
are the 'derived' column.)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def _time(fn, *args, iters=9):
    """Median-of-iters for every row: BENCH_kernels.json is a per-PR perf
    trajectory (and the decode A/B rows feed a >= 1.0x acceptance gate), so
    one noisy sweep must not decide a number."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return float(np.median(ts)) * 1e6


def _flash_decode_case(rows, cache_len: int, full: bool):
    """One decode token vs an int8 ring cache: naive full-dequant sdpa
    (the pre-kernel path) vs the auto-policy flash-decode pass (wide
    single-pass at 4k, blockwise scan at 32k — the policy ops.flash_decode
    actually dispatches, block_kv=0).  CPU wall-clock times the XLA forms
    of both; the Pallas kernel itself is a dry-run artifact, so its
    projected HBM traffic is the 'derived' column (int8 cache read once vs
    dequant-to-f32 materialization)."""
    from repro.kernels import ref
    from repro.kernels.flash_decode import flash_decode_xla
    from repro.models.layers.attention import _quant_kv

    B, Hk, G, D = (4, 8, 4, 128) if full else (2, 4, 4, 64)
    S = cache_len
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, 1, Hk * G, D), jnp.float32)
    kf = jax.random.normal(ks[1], (B, S, Hk, D), jnp.float32)
    vf = jax.random.normal(ks[2], (B, S, Hk, D), jnp.float32)
    kq, ksc = _quant_kv(kf)                    # the serving cache quantizer
    vq, vsc = _quant_kv(vf)
    kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    pos = jnp.asarray(S - 1, jnp.int32)

    naive = jax.jit(lambda *a: ref.flash_decode_ref(
        a[0], a[1], a[2], a[5], pos, k_scale=a[3], v_scale=a[4]))
    fused = jax.jit(lambda *a: flash_decode_xla(
        a[0], a[1], a[2], a[5], pos, k_scale=a[3], v_scale=a[4]))
    args = (q, kq, vq, ksc, vsc, kv_pos)
    us_naive = _time(naive, *args)
    us_fused = _time(fused, *args)

    cache_int8 = 2 * B * S * Hk * D            # k+v codes, 1 B each
    scales = 2 * B * S * Hk * 2                # bf16 absmax
    # naive: read codes+scales, write + re-read the f32 dequant copy
    hbm_naive = cache_int8 + scales + cache_int8 * 4 * 2
    hbm_fused = cache_int8 + scales            # single streamed pass
    flops = 4 * B * Hk * G * S * D
    rows.append(emit(
        "kernel", name=f"flash_decode_{S // 1024}k",
        us_per_call=round(us_fused, 1), us_naive_sdpa=round(us_naive, 1),
        speedup=round(us_naive / max(us_fused, 1e-9), 2),
        derived_flops=flops,
        derived_arith_intensity=round(flops / hbm_fused, 1),
        derived_hbm_bytes_naive=hbm_naive, derived_hbm_bytes=hbm_fused,
        vmem_tile_kib=round((1024 * D * 2 + 1024 * 2 + 8 * D * 4) / 1024,
                            1)))


def run(full: bool = False):
    from repro.core.quant import nf4_quantize
    from repro.kernels import ref

    rows = []
    M, K, N, r, qb = (512, 1024, 1024, 8, 64) if full else (128, 256, 256, 8, 64)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    w = jax.random.normal(ks[0], (K, N)) * 0.02
    wq, am = nf4_quantize(w, qb)
    am2 = am.reshape(K, N // qb)
    x = jax.random.normal(ks[1], (M, K))
    a = jax.random.normal(ks[2], (K, r)) * 0.1
    b = jax.random.normal(ks[3], (r, N)) * 0.1

    f = jax.jit(lambda *args: ref.qlora_matmul_ref(*args, 2.0))
    us = _time(f, x, wq, am2, a, b)
    flops = 2 * M * K * N + 2 * M * K * r + 2 * M * r * N
    hbm_bytes = M * K * 2 + K * N // 2 + (K * N // qb) * 4 + M * N * 2
    rows.append(emit(
        "kernel", name="qlora_matmul", us_per_call=round(us, 1),
        derived_flops=flops,
        derived_arith_intensity=round(flops / hbm_bytes, 1),
        vmem_tile_kib=round((128 * 128 + 128 * 256 // 2 + 128 * 256 * 4
                             + 128 * 256 * 4) / 1024, 1)))

    B, H, S, D = (4, 8, 1024, 128) if full else (2, 4, 256, 64)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
    k2 = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, H, S, D))
    f = jax.jit(lambda *args: ref.flash_attention_ref(*args))
    us = _time(f, q, k2, v)
    flops = 4 * B * H * S * S * D
    hbm = 4 * B * H * S * D * 2
    rows.append(emit(
        "kernel", name="flash_attention", us_per_call=round(us, 1),
        derived_flops=flops, derived_arith_intensity=round(flops / hbm, 1),
        vmem_tile_kib=round((128 * D * 3 + 128 * 128) * 4 / 1024, 1)))

    shape = (64, 4096) if full else (32, 512)
    x = jax.random.normal(jax.random.PRNGKey(4), shape)
    s = jnp.ones((shape[-1],))
    f = jax.jit(lambda *args: ref.rmsnorm_ref(*args))
    us = _time(f, x, s)
    n = shape[0] * shape[1]
    rows.append(emit(
        "kernel", name="rmsnorm", us_per_call=round(us, 1),
        derived_flops=3 * n, derived_arith_intensity=0.75,
        vmem_tile_kib=round(256 * shape[-1] * 4 / 1024, 1)))

    for cache_len in (4096, 32768):
        _flash_decode_case(rows, cache_len, full)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)


if __name__ == "__main__":
    main()
