"""Quickstart: train FedTime federatedly on a synthetic ETT-like benchmark
and forecast.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import fedtime
from repro.data.federated import client_windows, partition_clients
from repro.data.timeseries import DATASETS, generate, train_test_split
from repro.train.fed_trainer import federated_fit
from repro.train.trainer import evaluate_forecaster


def main():
    # 1. config (reduced LLaMA backbone; swap for get_config(...) on TPU)
    cfg = get_smoke_config("fedtime-llama2-7b")
    ft = cfg.fedtime
    print(f"backbone: {cfg.num_layers}L d={cfg.d_model}; "
          f"lookback={ft.lookback} horizon={ft.horizon} "
          f"clients={ft.num_clients} clusters={ft.num_clusters}")

    # 2. data: synthetic ETTh1 (Table 1 stats), 80/20 chronological split
    series = generate(DATASETS["etth1"], timesteps=3000)
    train, test = train_test_split(series)

    # 3. non-IID client partition + windows
    clients = partition_clients(train, ft.num_clients, seed=0,
                                channels_per_client=2)
    cdata = client_windows(clients, ft.lookback, ft.horizon, max_windows=64)

    # 4. federated fine-tuning (K-means clustering -> LoRA-only rounds)
    res = federated_fit(cfg, cdata, rounds=3, batch_size=8, progress=print)
    print(f"trainable fraction: {res.trainable_frac:.1%}  "
          f"total comm: {res.total_megabytes():.2f} MB")

    # 5. forecast with cluster-0's model
    params = res.params_for_cluster(0)
    from repro.data.timeseries import make_windows
    xte, yte = make_windows(test, ft.lookback, ft.horizon, stride=8)
    m = evaluate_forecaster(lambda p, x: fedtime.forward(p, cfg, x),
                            params, xte[..., :2], yte[..., :2])
    print(f"test MSE={m['mse']:.4f} MAE={m['mae']:.4f}")

    pred = fedtime.forward(params, cfg, jnp.asarray(xte[:1, :, :2]))
    print(f"one forecast, first 8 steps of channel 0: "
          f"{np.asarray(pred)[0, :8, 0].round(3).tolist()}")


if __name__ == "__main__":
    main()
