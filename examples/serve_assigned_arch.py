"""Serve any assigned architecture: prefill a batch of prompts + batched
decode with the production serve_step (the one the multi-pod dry-run lowers).

  PYTHONPATH=src python examples/serve_assigned_arch.py --arch zamba2-2.7b
  PYTHONPATH=src python examples/serve_assigned_arch.py --arch xlstm-350m
"""

import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


if __name__ == "__main__":
    serve.main()
