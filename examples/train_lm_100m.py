"""End-to-end LM training driver at ~100M scale.

Trains a SmolLM-family dense decoder (~110M params at the default width)
with the production train_step on Markov-structured synthetic tokens.
On a TPU slice: drop --layers/--width overrides to train the full config
with the same code path. On this CPU container the default is a short run
that still demonstrates loss descent at >100M params.

  PYTHONPATH=src python examples/train_lm_100m.py --steps 10
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import lm_batches, markov_tokens
from repro.launch.steps import make_train_step
from repro.models.registry import get_model
from repro.optim.adamw import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=6)
    args = ap.parse_args()

    # smollm-360m config, reduced depth => ~100M params (embed-dominated)
    cfg = get_config("smollm-360m").replace(
        name="smollm-100m", num_layers=args.layers,
        param_dtype="float32", compute_dtype="float32")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size})")

    toks = markov_tokens(300_000, cfg.vocab_size, seed=0)
    it = lm_batches(toks, args.batch, args.seq + 1, seed=0)
    step_fn = jax.jit(make_train_step(cfg, lr=3e-4), donate_argnums=(0, 1))
    opt = adamw_init(params)

    t0 = time.time()
    for i in range(args.steps):
        b = next(it)
        batch = {"tokens": jnp.asarray(b["tokens"][:, :args.seq]),
                 "labels": jnp.asarray(b["labels"][:, :args.seq])}
        params, opt, loss = step_fn(params, opt, batch,
                                    jnp.asarray(i, jnp.int32))
        print(f"step {i + 1}/{args.steps} loss={float(loss):.4f} "
              f"({(i + 1) * args.batch * args.seq / (time.time() - t0):.0f}"
              f" tok/s)", flush=True)


if __name__ == "__main__":
    main()
