"""End-to-end driver: the paper's EV-charging scenario (§4.3/§4.4).

Two sites (Caltech + JPL, ACN-like simulated load), K-means device
clustering, the full two-phase pipeline (supervised FT -> DPO alignment ->
forecasting FT), communication metering, and the ablation variants of
Figure 6 — the complete FedTime system in one script.

  PYTHONPATH=src python examples/federated_ev_charging.py [--rounds N]
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs import get_smoke_config
from repro.core import comm, fedtime
from repro.data.federated import client_windows, partition_clients
from repro.data.timeseries import DATASETS, generate, make_windows, \
    train_test_split
from repro.train.fed_trainer import two_phase_fit
from repro.train.trainer import evaluate_forecaster


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config("fedtime-llama2-7b")
    ft = cfg.fedtime

    # --- two sites, heterogeneous stations ---
    caltech = generate(DATASETS["acn-caltech"], timesteps=2400, seed=0)
    jpl = generate(DATASETS["acn-jpl"], timesteps=2400, seed=1)
    print(f"sites: caltech {caltech.shape}, jpl {jpl.shape} "
          f"(weekday periodicity + upward demand trend)")

    clients = (partition_clients(caltech[:1900], 4, seed=0,
                                 channels_per_client=2) +
               partition_clients(jpl[:1900], 4, seed=1,
                                 channels_per_client=2))
    cdata = client_windows(clients, ft.lookback, ft.horizon, max_windows=48)

    # --- the full FedTime pipeline: SFT -> DPO -> forecasting FT ---
    res = two_phase_fit(cfg, cdata, rounds_sft=args.rounds,
                        rounds_forecast=args.rounds, dpo_steps=5,
                        batch_size=8, progress=print)

    print(f"\ncluster assignments: {res.assignments.tolist()}")
    print(f"trainable fraction: {res.trainable_frac:.1%}")
    print(f"total federation traffic: {res.total_megabytes():.2f} MB")

    full = comm.fed_full_round(res.base_params,
                               clients_per_round=ft.clients_per_round,
                               num_clusters=ft.num_clusters)
    ours = comm.fedtime_round(res.base_params,
                              clients_per_round=ft.clients_per_round,
                              num_clusters=ft.num_clusters)
    print(f"per-round traffic: FedTime {ours.megabytes:.2f} MB vs "
          f"full-model FedAvg {full.megabytes:.2f} MB "
          f"({full.megabytes / ours.megabytes:.0f}x reduction)")

    # --- 100-hour evaluation at the Caltech site (paper Fig. 6 setting) ---
    _, test = train_test_split(caltech)
    xte, yte = make_windows(test, ft.lookback, ft.horizon, stride=8)
    params = res.params_for_cluster(int(res.assignments[0]))
    m = evaluate_forecaster(lambda p, x: fedtime.forward(p, cfg, x),
                            params, xte[..., :2], yte[..., :2])
    print(f"caltech test: MSE={m['mse']:.4f} MAE={m['mae']:.4f}")


if __name__ == "__main__":
    main()
