"""Cross-path consistency: prefill + decode must agree with the teacher-
forced forward pass for every family that serves."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer
from repro.models.registry import get_model


def _logits_from_forward(api, params, cfg, batch):
    """Teacher-forced logits at every position via the loss path's hidden."""
    if cfg.family == "moe":
        from repro.models import moe_transformer
        h, _ = moe_transformer.forward(params, cfg, batch["tokens"],
                                       remat=False)
        return transformer.logits_fn(params, cfg, h)
    if cfg.family == "ssm":
        from repro.models import xlstm_model
        h = xlstm_model.forward(params, cfg, batch["tokens"], remat=False)
        return transformer.logits_fn(params, cfg, h)
    if cfg.family == "hybrid":
        from repro.models import zamba2
        h = zamba2.forward(params, cfg, batch["tokens"], remat=False)
        return transformer.logits_fn(params, cfg, h)
    h = transformer.forward(params, cfg, batch["tokens"], remat=False)
    return transformer.logits_fn(params, cfg, h)


@pytest.mark.parametrize("arch,tol", [
    ("qwen3-0.6b", 2e-3),
    ("gemma2-27b", 2e-3),
    ("mixtral-8x7b", 5e-3),       # capacity dispatch can drop tokens
    ("zamba2-2.7b", 5e-3),
])
def test_prefill_logits_match_forward(arch, tol):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    cache, last_logits = api.prefill(params, cfg, batch)
    full_logits = _logits_from_forward(api, params, cfg, batch)
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("arch,tol", [
    ("qwen3-0.6b", 2e-3),
    ("smollm-360m", 2e-3),
    ("xlstm-350m", 5e-3),        # chunked-vs-recurrent numerics
    ("zamba2-2.7b", 5e-3),
])
def test_decode_continuation_matches_forward(arch, tol):
    """prefill(t[0:n]) then decode t[n] must equal forward(t[0:n+1])'s last
    logits."""
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 33
    # xlstm chunked prefill needs S % chunk == 0
    n = 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    cache, _ = api.prefill(params, cfg, {"tokens": tokens[:, :n]},
                           cache_len=S)
    logits_dec, _ = api.decode_step(
        params, cfg, cache,
        {"token": tokens[:, n:n + 1], "pos": jnp.asarray(n, jnp.int32)})
    full = _logits_from_forward(api, params, cfg,
                                {"tokens": tokens[:, :n + 1]})
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=tol, atol=tol)


def test_chunked_ce_equals_naive():
    from repro.models.losses import chunked_ce
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                cfg.vocab_size)
    h = transformer.forward(params, cfg, tokens, remat=False)
    l_chunk = chunked_ce(h, params, cfg, labels, chunk=16)
    logits = transformer.logits_fn(params, cfg, h).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    l_naive = (lse - gold).mean()
    np.testing.assert_allclose(float(l_chunk), float(l_naive), rtol=1e-5)


def test_chunked_ce_ignores_masked_labels():
    from repro.models.losses import chunked_ce
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, 512)
    labels = jax.random.randint(jax.random.PRNGKey(2), (1, 32), 0, 512)
    labels_masked = labels.at[:, 16:].set(-1)
    h = transformer.forward(params, cfg, tokens, remat=False)
    l1 = chunked_ce(h, params, cfg, labels_masked, chunk=8)
    # same result as computing CE on the first half only
    h_half = h[:, :16]
    l2 = chunked_ce(h_half, params, cfg, labels[:, :16], chunk=8)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
