"""int8 KV-cache quantization (§Perf iteration 11): decode parity within
quantization tolerance, cache actually stored in int8."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.layers.attention import (_dequant_kv, _quant_kv,
                                           attn_decode, attention,
                                           init_attention, init_attn_cache)


def test_quant_dequant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
    q, s = _quant_kv(x)
    assert q.dtype == jnp.int8
    xd = _dequant_kv(q, s, jnp.float32)
    rel = float(jnp.abs(xd - x).max() / jnp.abs(x).max())
    assert rel < 0.02, rel              # 7-bit mantissa per head-slot


def test_int8_decode_matches_full_precision(monkeypatch):
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_attention(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.arange(S, dtype=jnp.int32)
    full = attention(params, cfg, x, positions=pos, kind="causal")

    monkeypatch.setenv("REPRO_KV_INT8", "1")
    cache = init_attn_cache(B, S, cfg.num_kv_heads, cfg.resolved_head_dim(),
                            dtype=jnp.float32)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    for t in range(S):
        y_t, cache = attn_decode(params, cfg, x[:, t:t + 1], cache,
                                 jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=0.05, atol=0.05, err_msg=f"t={t}")


def test_int8_prefill_then_decode(monkeypatch):
    monkeypatch.setenv("REPRO_KV_INT8", "1")
    from repro.models.registry import get_model
    cfg = get_smoke_config("smollm-360m")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    B, P = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                cfg.vocab_size)
    cache, logits = api.prefill(params, cfg, {"tokens": tokens},
                                cache_len=P + 4)
    assert cache["k"].dtype == jnp.int8
    lg, cache = api.decode_step(
        params, cfg, cache,
        {"token": jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32),
         "pos": jnp.asarray(P, jnp.int32)})
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))


def test_int8_cache_is_half_size(monkeypatch):
    monkeypatch.setenv("REPRO_KV_INT8", "0")
    c_full = init_attn_cache(2, 128, 4, 64, dtype=jnp.bfloat16)
    monkeypatch.setenv("REPRO_KV_INT8", "1")
    c_int8 = init_attn_cache(2, 128, 4, 64, dtype=jnp.bfloat16)
    size = lambda c: sum(x.size * x.dtype.itemsize  # noqa: E731
                         for x in jax.tree.leaves(c))
    assert size(c_int8) < 0.6 * size(c_full)
