"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family — one forward/train step + one decode step on CPU,
asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.models.registry import (decode_batch_shapes, get_model,
                                   train_batch_shapes)
from repro.optim.adamw import adamw_init, adamw_update


def _make_batch(cfg, batch, seq, key):
    shapes = train_batch_shapes(cfg, batch, seq)
    out = {}
    for k, (shp, dt) in shapes.items():
        if dt == jnp.int32:
            out[k] = jax.random.randint(key, shp, 0, cfg.vocab_size)
        else:
            out[k] = jax.random.normal(key, shp, jnp.float32).astype(dt)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_is_published_spec(arch):
    cfg = get_config(arch)
    cfg.validate()
    assert cfg.source, f"{arch} must cite its source"
    # spot-check the assignment table
    expected = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151_936),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151_936),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256_206),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151_936),
        "gemma2-27b": (46, 4608, 32, 16, 36_864, 256_000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49_152),
        "paligemma-3b": (18, 2048, 8, 1, 16_384, 257_216),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50_304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10_240, 32_000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14_336, 32_000),
    }
    if arch in expected:
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected[arch], (arch, got, expected[arch])


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_config_is_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(cfg, key)
    B, S = 2, 64
    batch = _make_batch(cfg, B, S, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(api.loss)(params, cfg, batch)
    assert np.isfinite(float(loss)), arch
    # one optimizer step must change params and keep loss finite
    opt = adamw_init(params)
    params2, _ = adamw_update(params, grads, opt, 1, lr=1e-3)
    loss2 = api.loss(params2, cfg, batch)
    assert np.isfinite(float(loss2)), arch
    leaves1 = jax.tree.leaves(params)
    leaves2 = jax.tree.leaves(params2)
    assert any(not np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
               for a, b in zip(leaves1, leaves2))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(cfg, key)
    B = 2
    cache = api.init_cache(cfg, B, 128, force_window=0, dtype=jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = api.decode_step(
            params, cfg, cache,
            {"token": tok, "pos": jnp.asarray(pos, jnp.int32)})
        assert logits.shape == (B, 1, cfg.vocab_size), arch
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
        tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
