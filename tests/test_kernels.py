"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in repro.kernels.ref (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import nf4_quantize
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.qlora_matmul import qlora_matmul
from repro.kernels.rmsnorm import rmsnorm


@pytest.mark.parametrize("M,K,N", [(64, 128, 128), (128, 256, 256),
                                   (256, 128, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qlora_matmul_sweep(M, K, N, dtype):
    qb = 64
    k0 = jax.random.PRNGKey(M + K + N)
    ks = jax.random.split(k0, 4)
    w = jax.random.normal(ks[0], (K, N)) * 0.05
    wq, am = nf4_quantize(w, qb)
    am2 = am.reshape(K, N // qb)
    x = (jax.random.normal(ks[1], (M, K)) * 0.5).astype(dtype)
    r = 8
    a = (jax.random.normal(ks[2], (K, r)) * 0.1).astype(jnp.float32)
    b = (jax.random.normal(ks[3], (r, N)) * 0.1).astype(jnp.float32)
    y_k = qlora_matmul(x, wq, am2, a, b, 2.0, qblock=qb, bm=64,
                       bn=128, bk=128, interpret=True)
    y_r = ref.qlora_matmul_ref(x, wq, am2, a, b, 2.0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,S,D", [(1, 2, 128, 64), (2, 3, 256, 64),
                                     (1, 1, 256, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, S, D, causal):
    ks = jax.random.split(jax.random.PRNGKey(B * H + S), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    o_k = flash_attention(q, k, v, causal=causal, bq=128, bk=128,
                          interpret=True)
    o_r = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (2, 2, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 2, 128, 64)).astype(jnp.bfloat16)
    o_k = flash_attention(q, k, v, interpret=True)
    o_r = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("shape", [(16, 256), (4, 37, 512), (2, 3, 5, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    d = shape[-1]
    x = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    s = jax.random.normal(jax.random.PRNGKey(2), (d,))
    y_k = rmsnorm(x, s, interpret=True)
    y_r = ref.rmsnorm_ref(x, s)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=tol, atol=tol)


def test_qlora_matmul_matches_dense_layer():
    """Kernel result == the model's dense() dispatch on a quantized+LoRA
    site (same math end-to-end)."""
    from repro.models.layers.linear import dense
    K, N, r, qb = 256, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    w = jax.random.normal(ks[0], (K, N)) * 0.05
    wq, am = nf4_quantize(w, qb)
    p = {"w_nf4": wq, "absmax": am,
         "lora_a": jax.random.normal(ks[1], (K, r)) * 0.1,
         "lora_b": jax.random.normal(ks[2], (r, N)) * 0.1,
         "lora_scale": jnp.asarray(2.0)}
    x = jax.random.normal(ks[3], (32, K))
    y_model = dense(p, x)
    y_kernel = qlora_matmul(x, wq, am.reshape(K, N // qb), p["lora_a"],
                            p["lora_b"], 2.0, qblock=qb, bm=32, bn=128,
                            bk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               rtol=1e-4, atol=1e-4)
