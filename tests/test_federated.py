"""Federated-core invariants (paper C3/C5): aggregation properties,
clustering, communication accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import comm
from repro.core.clustering import client_features, cluster_clients, kmeans
from repro.core.lora import lora_tree, tree_nbytes
from repro.core.server import ClusterServer
from repro.optim.fedadam import fedadam_init, fedadam_update, fedavg


# ---------------------------------------------------------------------------
# FedAvg properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.lists(st.floats(0.1, 10.0), min_size=2,
                                   max_size=6))
def test_fedavg_is_convex_combination(n_clients, raw_w):
    """Aggregate must lie inside the convex hull of client values
    (component-wise between min and max), and weights must normalize."""
    n = min(n_clients, len(raw_w))
    w = np.asarray(raw_w[:n], np.float32)
    trees = [{"a": jnp.full((3,), float(i)), "b": {"c": jnp.asarray([i * 2.0])}}
             for i in range(n)]
    agg = fedavg(trees, w)
    vals = np.asarray([float(i) for i in range(n)])
    lo, hi = vals.min(), vals.max()
    assert np.all(np.asarray(agg["a"]) >= lo - 1e-5)
    assert np.all(np.asarray(agg["a"]) <= hi + 1e-5)
    expect = float((vals * w).sum() / w.sum())
    np.testing.assert_allclose(np.asarray(agg["a"]), expect, rtol=1e-5)


def test_fedavg_identity_with_equal_trees():
    t = {"x": jnp.asarray([1.0, 2.0])}
    agg = fedavg([t, t, t], jnp.asarray([1.0, 5.0, 0.5]))
    np.testing.assert_allclose(np.asarray(agg["x"]), [1.0, 2.0], rtol=1e-6)


def test_fedadam_moves_toward_clients():
    g = {"x": jnp.zeros((4,))}
    state = fedadam_init(g)
    delta = {"x": jnp.ones((4,))}
    g2, state = fedadam_update(g, delta, state, lr=0.1)
    assert np.all(np.asarray(g2["x"]) > 0), "server must move toward delta"


def test_cluster_server_round():
    ad0 = {"l": {"lora_a": jnp.zeros((4, 2)), "lora_b": jnp.zeros((2, 4))}}
    srv = ClusterServer(ad0, lr=0.5)
    ups = [jax.tree.map(lambda a: a + 1.0, ad0),
           jax.tree.map(lambda a: a + 3.0, ad0)]
    out = srv.aggregate(ups, [1.0, 1.0])
    assert srv.round == 1
    assert np.all(np.asarray(out["l"]["lora_a"]) > 0)


# ---------------------------------------------------------------------------
# K-means clustering
# ---------------------------------------------------------------------------

def test_kmeans_separates_obvious_clusters():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 0.2, (20, 3))
    b = rng.normal(5, 0.2, (20, 3))
    X = jnp.asarray(np.concatenate([a, b]), jnp.float32)
    assign, centers, inertia = kmeans(X, 2, key=jax.random.PRNGKey(0))
    assign = np.asarray(assign)
    assert len(set(assign[:20])) == 1
    assert len(set(assign[20:])) == 1
    assert assign[0] != assign[-1]


def test_client_features_shape_and_standardization():
    series = [np.random.default_rng(i).normal(i, 1 + i, (100 + 10 * i, 2))
              for i in range(5)]
    X = client_features(series)
    assert X.shape == (5, 5)
    np.testing.assert_allclose(np.asarray(X).mean(0), 0.0, atol=1e-4)


def test_cluster_clients_end_to_end():
    rng = np.random.default_rng(1)
    series = [rng.normal(0, 1, (64, 3)) for _ in range(6)] + \
             [rng.normal(50, 5, (64, 3)) for _ in range(6)]
    assign, _, _ = cluster_clients(series, 2)
    assign = np.asarray(assign)
    assert len(np.unique(assign)) == 2


# ---------------------------------------------------------------------------
# Communication accounting (C5)
# ---------------------------------------------------------------------------

def _adapted_params():
    from repro.configs import get_smoke_config
    from repro.core.lora import attach_lora
    from repro.models.registry import get_model
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    return attach_lora(api.init(cfg, jax.random.PRNGKey(0)),
                       jax.random.PRNGKey(1), rank=4, alpha=8.0)


def test_comm_bytes_equal_adapter_bytes_exactly():
    """The metered payload must be EXACTLY the LoRA pytree size — nothing
    more leaves the device (the paper's core comm claim)."""
    params = _adapted_params()
    payload = tree_nbytes(lora_tree(params))
    stats = comm.fedtime_round(params, clients_per_round=3, num_clusters=2)
    assert stats.bytes_up == payload * 3
    assert stats.bytes_down == payload * 3


def test_fedtime_vs_full_model_overhead():
    params = _adapted_params()
    ft = comm.fedtime_round(params, clients_per_round=4, num_clusters=2)
    full = comm.fed_full_round(params, clients_per_round=4, num_clusters=2)
    assert full.bytes_up > 5 * ft.bytes_up, \
        "LoRA federation must be far cheaper than full-model FedAvg"
    assert full.time_s > ft.time_s


def test_centralized_data_shipping_dwarfs_fedtime():
    params = _adapted_params()
    ft = comm.fedtime_round(params, clients_per_round=8, num_clusters=2)
    cen = comm.centralized_epoch(num_samples=10_000, lookback=512,
                                 horizon=96, channels=21, num_clients=8)
    assert cen.bytes_up > ft.bytes_up


def test_collective_bytes_ring_formula():
    params = _adapted_params()
    out = comm.collective_bytes_per_round(params, {"data": 16, "model": 16})
    payload = tree_nbytes(lora_tree(params))
    assert out["data"] == int(2 * payload * 15 / 16)
    assert out["pod"] == 0
