"""Attention unit tests: blockwise == naive, sliding window, GQA, prefix-LM,
ring-buffer decode parity with full-sequence forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.layers.attention import (attention, attn_decode,
                                           init_attention, init_attn_cache,
                                           sdpa)


def _qkv(B=2, S=64, H=4, Hk=2, D=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    pos = jnp.arange(S, dtype=jnp.int32)
    return q, k, v, pos


def test_blockwise_equals_naive_causal():
    q, k, v, pos = _qkv(S=128)
    out_naive = sdpa(q, k, v, q_pos=pos, kv_pos=pos, kind="causal")
    out_block = sdpa(q, k, v, q_pos=pos, kv_pos=pos, kind="causal",
                     block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(out_naive), np.asarray(out_block),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_equals_naive_sliding_window():
    q, k, v, pos = _qkv(S=128, seed=1)
    kw = dict(q_pos=pos, kv_pos=pos, kind="causal", window=16)
    out_naive = sdpa(q, k, v, **kw)
    out_block = sdpa(q, k, v, block_q=32, block_kv=32, **kw)
    np.testing.assert_allclose(np.asarray(out_naive), np.asarray(out_block),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_equals_naive_prefix():
    q, k, v, pos = _qkv(S=64, seed=2)
    pl = jnp.asarray([16, 32])
    kw = dict(q_pos=pos, kv_pos=pos, kind="prefix", prefix_len=pl)
    out_naive = sdpa(q, k, v, **kw)
    out_block = sdpa(q, k, v, block_q=16, block_kv=16, **kw)
    np.testing.assert_allclose(np.asarray(out_naive), np.asarray(out_block),
                               rtol=2e-5, atol=2e-5)


def test_sliding_window_masks_far_tokens():
    """A key far outside the window must not influence the output."""
    q, k, v, pos = _qkv(S=64, seed=3)
    out1 = sdpa(q, k, v, q_pos=pos, kv_pos=pos, kind="causal", window=8)
    v2 = v.at[:, 0].set(v[:, 0] + 100.0)     # perturb position 0
    out2 = sdpa(q, k, v2, q_pos=pos, kv_pos=pos, kind="causal", window=8)
    # rows >= 8 can't see position 0
    np.testing.assert_allclose(np.asarray(out1[:, 8:]),
                               np.asarray(out2[:, 8:]), rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 0]), np.asarray(out2[:, 0]))


def test_softcap_bounds_scores():
    """With softcap, extreme logits cannot saturate: output must differ
    from the uncapped result but stay finite."""
    q, k, v, pos = _qkv(S=32, seed=4)
    big_q = q * 100.0
    out_cap = sdpa(big_q, k, v, q_pos=pos, kv_pos=pos, kind="causal",
                   softcap=20.0)
    out_nocap = sdpa(big_q, k, v, q_pos=pos, kv_pos=pos, kind="causal")
    assert np.all(np.isfinite(np.asarray(out_cap)))
    assert not np.allclose(np.asarray(out_cap), np.asarray(out_nocap))


def test_decode_matches_full_forward():
    """Ring-buffer decode must reproduce the full-sequence attention,
    token by token (global cache, GQA + qk-norm + RoPE)."""
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_attention(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.arange(S, dtype=jnp.int32)
    full = attention(params, cfg, x, positions=pos, kind="causal")

    cache = init_attn_cache(B, S, cfg.num_kv_heads, cfg.resolved_head_dim(),
                            dtype=jnp.float32)
    for t in range(S):
        y_t, cache = attn_decode(params, cfg, x[:, t:t + 1], cache,
                                 jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_ring_buffer_sliding_window():
    """Decode with a ring cache of size W must match full-sequence SWA."""
    cfg = get_smoke_config("mixtral-8x7b").replace(sliding_window=8)
    params = init_attention(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    B, S, W = 1, 24, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.arange(S, dtype=jnp.int32)
    full = attention(params, cfg, x, positions=pos, kind="causal", window=W)

    cache = init_attn_cache(B, W, cfg.num_kv_heads, cfg.resolved_head_dim(),
                            dtype=jnp.float32)
    for t in range(S):
        y_t, cache = attn_decode(params, cfg, x[:, t:t + 1], cache,
                                 jnp.asarray(t, jnp.int32), window=W)
        np.testing.assert_allclose(np.asarray(y_t[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"t={t}")


def test_gqa_reduces_to_mha_when_heads_equal():
    """GQA with Hk == H must equal plain MHA math (sanity on grouping)."""
    B, S, H, D = 1, 16, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = sdpa(q, k, v, q_pos=pos, kv_pos=pos, kind="causal")
    # manual reference
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * D ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
