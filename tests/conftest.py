import os
import sys

# tests must see exactly ONE device (dry-run sets its own 512-device flag in
# a separate process); make sure nothing leaks in.
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
