"""Sampling utilities, generation loop, secure aggregation, stragglers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.registry import get_model
from repro.serve.sampling import generate, greedy, sample


def test_greedy_picks_argmax():
    logits = jnp.asarray([[0.1, 5.0, -1.0], [2.0, 0.0, 3.0]])
    np.testing.assert_array_equal(np.asarray(greedy(logits)), [1, 2])


def test_temperature_zero_is_greedy():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 100))
    t = sample(jax.random.PRNGKey(1), logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(greedy(logits)))


def test_top_k_restricts_support():
    logits = jnp.arange(50, dtype=jnp.float32)[None].repeat(2, 0)
    for seed in range(20):
        t = sample(jax.random.PRNGKey(seed), logits, temperature=1.0,
                   top_k=5)
        assert np.all(np.asarray(t) >= 45), t


def test_top_p_keeps_head_of_distribution():
    logits = jnp.asarray([[10.0, 9.0] + [0.0] * 98])
    for seed in range(20):
        t = sample(jax.random.PRNGKey(seed), logits, temperature=1.0,
                   top_p=0.9)
        assert int(t[0]) in (0, 1)


def test_generate_loop_runs_jitted():
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    B, P, G = 2, 16, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                cfg.vocab_size)
    cache, logits = api.prefill(params, cfg, {"tokens": tokens},
                                cache_len=P + G)
    first = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    out, _ = generate(api, params, cfg, cache, first, steps=G, start_pos=P,
                      temperature=0.8, top_k=20, key=jax.random.PRNGKey(2))
    assert out.shape == (B, G)
    assert np.all((np.asarray(out) >= 0) &
                  (np.asarray(out) < cfg.vocab_size))


def test_generate_greedy_matches_manual_decode():
    cfg = get_smoke_config("smollm-360m")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    B, P, G = 1, 8, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                cfg.vocab_size)
    cache, logits = api.prefill(params, cfg, {"tokens": tokens},
                                cache_len=P + G)
    first = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)

    out, _ = generate(api, params, cfg, cache, first, steps=G, start_pos=P,
                      temperature=0.0)
    # manual loop
    cache2, _ = api.prefill(params, cfg, {"tokens": tokens},
                            cache_len=P + G)
    tok = first
    manual = []
    for i in range(G):
        lg, cache2 = api.decode_step(params, cfg, cache2,
                                     {"token": tok,
                                      "pos": jnp.asarray(P + i, jnp.int32)})
        tok = jnp.argmax(lg[:, -1:, :], -1).astype(jnp.int32)
        manual.append(int(tok[0, 0]))
    np.testing.assert_array_equal(np.asarray(out[0]), manual)


# ---------------------------------------------------------------------------
# Secure aggregation
# ---------------------------------------------------------------------------

def test_pairwise_masks_cancel_exactly():
    from repro.core.secure_agg import aggregate_masked, mask_update
    updates = [{"a": jnp.full((8,), float(i)),
                "b": {"c": jnp.ones((2, 2)) * i}} for i in range(1, 5)]
    parts = [10, 11, 12, 13]
    masked = [mask_update(u, client_id=parts[i], participants=parts,
                          round_idx=3) for i, u in enumerate(updates)]
    # individual masked updates differ from the raw ones (privacy)
    assert not np.allclose(np.asarray(masked[0]["a"]),
                           np.asarray(updates[0]["a"]))
    agg = aggregate_masked(masked)
    expect = np.mean([float(i) for i in range(1, 5)])
    np.testing.assert_allclose(np.asarray(agg["a"]), expect, atol=1e-4)
    np.testing.assert_allclose(np.asarray(agg["b"]["c"]), expect, atol=1e-4)


def test_federated_fit_with_secure_aggregation_and_stragglers():
    from repro.data.federated import client_windows, partition_clients
    from repro.data.timeseries import DATASETS, generate as gen
    from repro.train.fed_trainer import federated_fit
    cfg = get_smoke_config("fedtime-llama2-7b")
    series = gen(DATASETS["etth2"], timesteps=1600, seed=5)
    clients = partition_clients(series, cfg.fedtime.num_clients, seed=0,
                                channels_per_client=2)
    cdata = client_windows(clients, cfg.fedtime.lookback,
                           cfg.fedtime.horizon, max_windows=32)
    res = federated_fit(cfg, cdata, rounds=2, batch_size=4,
                        straggler_prob=0.3, secure_aggregation=True)
    assert len(res.logs) > 0
    assert all(np.isfinite(l.train_loss) for l in res.logs)
    # model still produces finite forecasts after masked aggregation
    from repro.core import fedtime
    p = res.params_for_cluster(0)
    pred = fedtime.forward(p, cfg, jnp.asarray(cdata[0][0][:2]))
    assert np.all(np.isfinite(np.asarray(pred)))
