"""Unit tests for the sharding rule tables (pure functions of shapes —
no multi-device runtime needed)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import _spec_for_param, _div


MODEL = 16


def _spec(path, shape):
    return _spec_for_param(path, jax.ShapeDtypeStruct(shape, jnp.float32),
                           MODEL)


def test_attention_projections_shard_flat_head_dim():
    assert _spec("/layers/attn/wq/w", (28, 1024, 2048)) == P(None, None, "model")
    assert _spec("/layers/attn/wo/w", (28, 2048, 1024)) == P(None, "model", None)


def test_non_divisible_replicates():
    # 15-head smollm q proj: 960 divides, fine; a 15-dim leaf must replicate
    assert _spec("/layers/attn/wq/w", (32, 960, 960)) == P(None, None, "model")
    assert _spec("/layers/attn/wq/w", (32, 960, 15)) == P()


def test_mlp_shards_hidden():
    assert _spec("/layers/mlp/up/w", (28, 1024, 3072)) == P(None, None, "model")
    assert _spec("/layers/mlp/down/w", (28, 3072, 1024)) == P(None, "model", None)


def test_moe_experts_shard_ffn_not_expert_dim():
    # 60 experts don't divide 16; d_ff=1408 does
    assert _spec("/layers/moe/gate_proj", (24, 60, 2048, 1408)) == \
        P(None, None, None, "model")
    assert _spec("/layers/moe/down_proj", (24, 60, 1408, 2048)) == \
        P(None, None, "model", None)
    assert _spec("/layers/moe/router/w", (24, 2048, 60)) == P()


def test_lora_adapters_replicated():
    """The federated payload must be replicated — cluster aggregation is a
    pure psum (DESIGN.md §5)."""
    assert _spec("/layers/attn/wq/lora_a", (28, 1024, 8)) == P()
    assert _spec("/layers/attn/wq/lora_b", (28, 8, 2048)) == P()


def test_embed_shards_vocab():
    assert _spec("/embed/table", (151936, 1024)) == P("model", None)


def test_norms_replicated():
    assert _spec("/layers/attn_norm/scale", (28, 1024)) == P()


def test_cache_specs_seq_sharded(monkeypatch):
    from repro.dist import sharding as sh

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cache = {"k": jax.ShapeDtypeStruct((28, 128, 32768, 8, 128),
                                       jnp.bfloat16),
             "kv_pos": jax.ShapeDtypeStruct((28, 128, 32768), jnp.int32)}
    monkeypatch.setenv("REPRO_CACHE_SHARD", "seq")
    specs = sh.cache_specs(cache, FakeMesh())
    # flash-decode layout: batch -> data, seq -> model
    assert specs["k"] == P(None, "data", "model", None, None)
    monkeypatch.setenv("REPRO_CACHE_SHARD", "heads")
    specs = sh.cache_specs(cache, FakeMesh())
    # head dim 8 doesn't divide 16 -> falls through to dh=128
    assert specs["k"] == P(None, "data", None, None, "model")


def test_opt_state_specs_zero1(monkeypatch):
    from repro.dist import sharding as sh

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    params = {"mlp": {"up": {"w": jax.ShapeDtypeStruct((28, 4608, 36864),
                                                       jnp.bfloat16)}}}
    specs = sh.opt_state_specs(params, FakeMesh())
    # base spec shards dim2 over model; ZeRO widens dim1 over data
    assert specs["mlp"]["up"]["w"] == P(None, "data", "model")


def test_data_specs_batch_divisibility():
    from repro.dist import sharding as sh

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = sh.data_specs(batch, FakeMesh())
    assert specs["tokens"] == P(("pod", "data"), None)
    assert specs["pos"] == P()
    # batch=1 (long_500k) cannot shard
    one = {"token": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    assert sh.data_specs(one, FakeMesh())["token"] == P()
