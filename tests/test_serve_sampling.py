"""Sampling fixes + vectorized per-request sampling (serve/sampling.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st
from repro.serve.sampling import greedy, sample, sample_vec


def _logits(key, b, v):
    return jax.random.normal(key, (b, v), jnp.float32) * 3.0


# ---------------------------------------------------------------------------
# scalar `sample` fixes
# ---------------------------------------------------------------------------

def test_top_k_larger_than_vocab_is_clamped(key):
    logits = _logits(key, 3, 16)
    big = sample(jax.random.PRNGKey(1), logits, temperature=1.0, top_k=999)
    exact = sample(jax.random.PRNGKey(1), logits, temperature=1.0, top_k=16)
    np.testing.assert_array_equal(np.asarray(big), np.asarray(exact))


def test_top_p_one_keeps_full_distribution(key):
    logits = _logits(key, 4, 32)
    with_p1 = sample(jax.random.PRNGKey(2), logits, temperature=0.7,
                     top_p=1.0)
    without = sample(jax.random.PRNGKey(2), logits, temperature=0.7,
                     top_p=0.0)
    np.testing.assert_array_equal(np.asarray(with_p1), np.asarray(without))


def test_top_p_above_one_is_safe(key):
    logits = _logits(key, 2, 8)
    t = sample(jax.random.PRNGKey(3), logits, temperature=1.0, top_p=1.5)
    assert np.all((np.asarray(t) >= 0) & (np.asarray(t) < 8))


# ---------------------------------------------------------------------------
# sample_vec: per-row params, one signature
# ---------------------------------------------------------------------------

def _keys(b, seed=0):
    return jnp.stack([jnp.asarray(jax.random.PRNGKey(seed + i), jnp.uint32)
                      for i in range(b)])


def test_sample_vec_greedy_rows_are_argmax(key):
    logits = _logits(key, 4, 64)
    toks = sample_vec(_keys(4), logits,
                      temperature=jnp.zeros(4), top_k=jnp.zeros(4, jnp.int32),
                      top_p=jnp.zeros(4))
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(greedy(logits)))


def test_sample_vec_mixed_rows(key):
    """Greedy + top-k + nucleus rows coexist in one call."""
    logits = jnp.arange(50, dtype=jnp.float32)[None].repeat(3, 0)
    toks = sample_vec(_keys(3), logits,
                      temperature=jnp.asarray([0.0, 1.0, 1.0]),
                      top_k=jnp.asarray([0, 5, 0], jnp.int32),
                      top_p=jnp.asarray([0.0, 0.0, 0.2]))
    t = np.asarray(toks)
    assert t[0] == 49                            # greedy row
    assert t[1] >= 45                            # top-5 support
    assert t[2] >= 47                            # tight nucleus stays at head


def test_sample_vec_row_isolation(key):
    """A row's draw depends only on its own key/params — not on what else
    is in the batch (the engine's per-request isolation contract)."""
    logits = _logits(key, 2, 32)
    a = sample_vec(_keys(2), logits,
                   temperature=jnp.asarray([0.8, 0.8]),
                   top_k=jnp.asarray([10, 10], jnp.int32),
                   top_p=jnp.asarray([0.9, 0.9]))
    b = sample_vec(_keys(2), logits,
                   temperature=jnp.asarray([0.8, 0.0]),   # partner changed
                   top_k=jnp.asarray([10, 0], jnp.int32),
                   top_p=jnp.asarray([0.9, 0.0]))
    assert int(a[0]) == int(b[0])


def test_sample_vec_top_k_clamps_to_vocab(key):
    logits = _logits(key, 2, 16)
    big = sample_vec(_keys(2), logits, temperature=jnp.ones(2),
                     top_k=jnp.asarray([500, 500], jnp.int32),
                     top_p=jnp.zeros(2))
    exact = sample_vec(_keys(2), logits, temperature=jnp.ones(2),
                       top_k=jnp.asarray([16, 16], jnp.int32),
                       top_p=jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(big), np.asarray(exact))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), top_k=st.integers(0, 64),
       top_p=st.floats(0.0, 1.5), temperature=st.floats(0.0, 2.0))
def test_sampled_token_always_in_masked_support(seed, top_k, top_p,
                                                temperature):
    """Property: the drawn token survives the top-k mask — never an
    out-of-support index, for any (top_k, top_p, temperature) combo."""
    V = 32
    k = jax.random.PRNGKey(seed)
    logits = jax.random.normal(k, (1, V), jnp.float32) * 2.0
    tok = int(sample(jax.random.fold_in(k, 1), logits,
                     temperature=temperature, top_k=top_k, top_p=top_p)[0])
    assert 0 <= tok < V
    if temperature > 0.0 and top_k > 0:
        k_eff = min(top_k, V)
        kth = np.sort(np.asarray(logits[0]))[-k_eff]
        assert np.asarray(logits)[0, tok] >= kth
