"""LoRA / QLoRA unit + property tests (paper C2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.core.lora import (attach_lora, lora_mask, lora_tree,
                             materialize_lora, merge_lora, quantize_base,
                             trainable_fraction, tree_nbytes)
from repro.core.quant import nf4_dequant, nf4_quantize
from repro.models.registry import get_model


def test_nf4_roundtrip_error_bound():
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 256)) * 0.02
    q, a = nf4_quantize(w, 64)
    wd = nf4_dequant(q, a)
    rel = float(jnp.linalg.norm(wd - w) / jnp.linalg.norm(w))
    assert rel < 0.10, rel           # NF4 keeps ~3-4% rel error on gaussians
    assert q.dtype == jnp.uint8 and q.shape == (128, 128)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(1, 8))
def test_nf4_absmax_is_exact_per_block(rows, cols_x64):
    """Property: the max-magnitude element of every block survives
    round-trip exactly (NF4 codebook contains ±1)."""
    cols = 64 * cols_x64
    w = jax.random.normal(jax.random.PRNGKey(rows * cols), (rows, cols))
    q, a = nf4_quantize(w, 64)
    wd = np.asarray(nf4_dequant(q, a))
    flat = np.asarray(w).reshape(-1, 64)
    flat_d = wd.reshape(-1, 64)
    for b in range(flat.shape[0]):
        i = np.argmax(np.abs(flat[b]))
        np.testing.assert_allclose(flat_d[b, i], flat[b, i], rtol=1e-6)


def test_lora_zero_init_is_identity():
    """B=0 at init => adapted model output == base model output."""
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    l0 = api.loss(params, cfg, batch)
    adapted = attach_lora(params, jax.random.PRNGKey(1), rank=4, alpha=8.0)
    l1 = api.loss(adapted, cfg, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)


def test_materialize_lora_equivalence():
    """merge(W, A, B) x == W x + s·B(Ax) after folding."""
    from repro.models.layers.linear import dense
    k = jax.random.PRNGKey(2)
    p = {"wq": {"w": jax.random.normal(k, (64, 64)) * 0.1}}
    p = attach_lora(p, jax.random.PRNGKey(3), rank=4, alpha=8.0,
                    targets=("wq",))
    # give B nonzero values
    p["wq"]["lora_b"] = jax.random.normal(jax.random.PRNGKey(4),
                                          (4, 64)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 64))
    y_adapter = dense(p["wq"], x)
    folded = materialize_lora(p)
    assert "lora_a" not in folded["wq"]
    y_folded = dense(folded["wq"], x)
    np.testing.assert_allclose(np.asarray(y_adapter), np.asarray(y_folded),
                               rtol=1e-5, atol=1e-5)


def test_lora_tree_and_merge_roundtrip():
    cfg = get_smoke_config("smollm-360m")
    api = get_model(cfg)
    params = attach_lora(api.init(cfg, jax.random.PRNGKey(0)),
                         jax.random.PRNGKey(1), rank=4, alpha=8.0)
    ad = lora_tree(params)
    leaves = jax.tree.leaves(ad)
    assert leaves, "no adapters found"
    ad2 = jax.tree.map(lambda a: a + 1.0, ad)
    merged = merge_lora(params, ad2)
    ad3 = lora_tree(merged)
    for a, b in zip(jax.tree.leaves(ad2), jax.tree.leaves(ad3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # non-adapter leaves untouched
    np.testing.assert_array_equal(
        np.asarray(params["embed"]["table"]),
        np.asarray(merged["embed"]["table"]))


def test_quantize_base_shrinks_and_preserves_loss_ballpark():
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32)[None].repeat(2, 0) % 512,
             "labels": jnp.arange(64, dtype=jnp.int32)[None].repeat(2, 0) % 512}
    l0 = float(api.loss(params, cfg, batch))
    adapted = attach_lora(params, jax.random.PRNGKey(1), rank=4, alpha=8.0)
    q = quantize_base(adapted)
    l1 = float(api.loss(q, cfg, batch))
    assert abs(l1 - l0) / abs(l0) < 0.05, (l0, l1)
    # attn weights are now uint8-packed
    site = q["layers"]["attn"]["wq"]
    assert "w_nf4" in site and site["w_nf4"].dtype == jnp.uint8
    assert "w" not in site


def test_trainable_fraction_small():
    """Paper: ~1.2% trainable with QLoRA on the 7B backbone. The smoke
    model is tiny so the fraction is larger, but must be well under 10%."""
    cfg = get_smoke_config("fedtime-llama2-7b")
    from repro.core import fedtime
    params = fedtime.init(cfg, jax.random.PRNGKey(0), num_channels=3)
    adapted = attach_lora(params, jax.random.PRNGKey(1), rank=4, alpha=8.0)
    frac = trainable_fraction(adapted)
    assert 0 < frac < 0.10, frac


def test_lora_mask_marks_only_adapters():
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = attach_lora(api.init(cfg, jax.random.PRNGKey(0)),
                         jax.random.PRNGKey(1), rank=4, alpha=8.0)
    mask = lora_mask(params)
    flat_p = jax.tree_util.tree_leaves_with_path(params)
    flat_m = jax.tree.leaves(mask)
    for (path, _), m in zip(flat_p, flat_m):
        is_adapter = any(getattr(k, "key", None) in ("lora_a", "lora_b")
                         for k in path)
        assert m == is_adapter, path
