"""Property tests for the TS front-end (paper C1): RevIN invertibility,
patching bijection, channel independence round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.patching import (channel_merge, channel_split, make_patches,
                                 num_patches, patch_embed, init_patch_embed)
from repro.core.revin import (init_revin, instance_norm, revin_denorm,
                              revin_norm)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(8, 64), st.integers(1, 5),
       st.integers(0, 1000))
def test_revin_denorm_inverts_norm(B, L, M, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(3, 10, (B, L, M)).astype(np.float32))
    params = init_revin(M)
    # non-trivial affine
    params = {"gamma": params["gamma"] * 2.5, "beta": params["beta"] + 0.7}
    xn, stats = revin_norm(params, x)
    x_rec = revin_denorm(params, xn, stats)
    np.testing.assert_allclose(np.asarray(x_rec), np.asarray(x),
                               rtol=1e-3, atol=1e-3)


def test_instance_norm_zero_mean_unit_std():
    x = jnp.asarray(np.random.default_rng(0).normal(5, 3, (2, 100, 4))
                    .astype(np.float32))
    xn, stats = instance_norm(x)
    np.testing.assert_allclose(np.asarray(xn.mean(1)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(xn.std(1)), 1.0, atol=1e-2)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 100))
def test_channel_split_merge_roundtrip(B, M, seed):
    rng = np.random.default_rng(seed)
    L = 16
    x = jnp.asarray(rng.normal(0, 1, (B, L, M)).astype(np.float32))
    u = channel_split(x)
    assert u.shape == (B * M, L)
    # merge expects (B*M, T) — use the same L as "horizon"
    back = channel_merge(u, B, M)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([(64, 16, 8), (96, 16, 16), (32, 8, 4),
                        (128, 32, 16)]))
def test_patching_covers_series_exactly(cfg):
    L, P, S = cfg
    N = num_patches(L, P, S)
    x = jnp.arange(L, dtype=jnp.float32)[None]
    p = make_patches(x, P, S)
    assert p.shape == (1, N, P)
    # each patch is the right window
    for i in range(N):
        np.testing.assert_array_equal(np.asarray(p[0, i]),
                                      np.arange(i * S, i * S + P))
    # last patch reaches the end of the series
    assert (N - 1) * S + P == L


def test_patch_embed_matches_eq1():
    key = jax.random.PRNGKey(0)
    P, N, D = 8, 5, 16
    params = init_patch_embed(key, P, N, D)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, N, P))
    y = patch_embed(params, x)
    expected = x @ params["w_p"] + params["w_pos"][None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                               rtol=1e-6)
    assert y.shape == (3, N, D)
