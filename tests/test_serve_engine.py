"""Continuous-batching engine: per-request parity with solo decode, slot
lifecycle, ragged masking, cache-pool dtypes, scheduler budgets."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.steps import make_serve_step
from repro.models.registry import get_model
from repro.serve import ForecastEngine, Request, SamplingParams
from repro.serve.cache_pool import CachePool, cache_batch_axes
from repro.serve.sampling import sample_vec
from repro.serve.scheduler import (FIFOScheduler, SchedulerConfig,
                                   bucket_len)

CACHE_LEN = 48


@pytest.fixture(scope="module")
def dense():
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, api, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _solo_greedy(api, cfg, params, prompt, gen, cache_len=CACHE_LEN):
    """Reference: the request alone through prefill + serve_step."""
    cache, logits = api.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt[None])},
        cache_len=cache_len)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    P = len(prompt)
    for i in range(gen - 1):
        tok, cache = serve(params, cache,
                           {"token": tok,
                            "pos": jnp.asarray([P + i], jnp.int32)})
        out.append(int(tok[0, 0]))
    return out


def _run_trace(cfg, params, reqs, **ekw):
    eng = ForecastEngine(cfg, params, cache_len=CACHE_LEN, **ekw)
    for r in reqs:
        eng.submit(r)
    done = eng.run(max_steps=500)
    return eng, done


def test_staggered_admission_matches_solo(dense):
    """5 staggered requests through 2 slots (forces eviction + slot reuse)
    decode bit-identically to each request run alone — and the whole run
    compiles exactly ONE serve_step signature."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 9, 6, 11, 9])
    gens = [5, 3, 6, 4, 5]
    ref = [_solo_greedy(api, cfg, params, p, g)
           for p, g in zip(prompts, gens)]
    reqs = [Request(id=f"r{i}", prompt=p, max_new_tokens=g, arrival_step=i)
            for i, (p, g) in enumerate(zip(prompts, gens))]
    eng, done = _run_trace(cfg, params, reqs, num_slots=2)
    for i in range(len(reqs)):
        assert done[f"r{i}"].tokens.tolist() == ref[i], i
    assert eng.num_step_signatures() == 1
    # 5 requests through 2 lanes — at least one lane was recycled
    assert eng.metrics.requests_finished == 5


def test_ragged_active_mask_matches_dense_batch(dense):
    """Two same-shape requests admitted together decode exactly like a
    synchronous (scalar-pos) dense batch of 2."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [8, 8], seed=3)
    gen = 6
    # dense reference: one prefill of B=2, scalar-pos serve loop
    toks = jnp.asarray(np.stack(prompts))
    cache, logits = api.prefill(params, cfg, {"tokens": toks},
                                cache_len=CACHE_LEN)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    ref = [np.asarray(tok)[:, 0]]
    for i in range(gen - 1):
        tok, cache = serve(params, cache,
                           {"token": tok, "pos": jnp.asarray(8 + i,
                                                             jnp.int32)})
        ref.append(np.asarray(tok)[:, 0])
    ref = np.stack(ref, 1)                     # (2, gen)

    reqs = [Request(id=f"r{i}", prompt=p, max_new_tokens=gen)
            for i, p in enumerate(prompts)]
    _, done = _run_trace(cfg, params, reqs, num_slots=2)
    for i in range(2):
        assert done[f"r{i}"].tokens.tolist() == ref[i].tolist(), i


def test_prefill_bucketing_parity(dense):
    """Right-padded bucketed prefill (true_len masking) changes neither the
    first token nor the continuation."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [5, 10, 7], seed=5)
    gens = [4, 4, 4]
    ref = [_solo_greedy(api, cfg, params, p, g)
           for p, g in zip(prompts, gens)]
    reqs = [Request(id=f"r{i}", prompt=p, max_new_tokens=g)
            for i, (p, g) in enumerate(zip(prompts, gens))]
    eng, done = _run_trace(cfg, params, reqs, num_slots=3, prefill_bucket=8)
    for i in range(len(reqs)):
        assert done[f"r{i}"].tokens.tolist() == ref[i], i
    # 5, 10, 7 all bucket to {8, 16}: two prefill signatures, one serve
    assert eng.num_step_signatures() == 1


def test_int8_cache_pool_parity(dense, monkeypatch):
    """REPRO_KV_INT8 pools (quantized lanes + per-slot scales) keep the
    same engine == solo contract."""
    monkeypatch.setenv("REPRO_KV_INT8", "1")
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 9], seed=7)
    ref = [_solo_greedy(api, cfg, params, p, 4) for p in prompts]
    reqs = [Request(id=f"r{i}", prompt=p, max_new_tokens=4,
                    arrival_step=i) for i, p in enumerate(prompts)]
    eng, done = _run_trace(cfg, params, reqs, num_slots=2)
    # the pool really is int8
    leaf = jax.tree.leaves(eng.pool.cache)[0]
    assert any(l.dtype == jnp.int8 for l in jax.tree.leaves(eng.pool.cache))
    for i in range(len(reqs)):
        assert done[f"r{i}"].tokens.tolist() == ref[i], i


def test_per_request_sampling_isolation(dense):
    """A stochastic request draws the same tokens whether it decodes alone
    or co-batched with (greedy) neighbours: per-row keys + per-row params."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [7, 7, 7], seed=9)
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.9, seed=123)

    def stoch():
        return Request(id="s", prompt=prompts[0], max_new_tokens=5,
                       sampling=sp)

    _, alone = _run_trace(cfg, params, [stoch()], num_slots=2)
    neighbours = [Request(id=f"g{i}", prompt=prompts[i], max_new_tokens=6)
                  for i in (1, 2)]
    _, packed = _run_trace(cfg, params, [stoch(), *neighbours], num_slots=3)
    assert packed["s"].tokens.tolist() == alone["s"].tokens.tolist()
    # and the greedy neighbours still match their solo reference
    ref = _solo_greedy(api, cfg, params, prompts[1], 6)
    assert packed["g1"].tokens.tolist() == ref


def test_eos_stops_early(dense):
    cfg, api, params = dense
    prompt = _prompts(cfg, [6], seed=11)[0]
    ref = _solo_greedy(api, cfg, params, prompt, 8)
    eos = ref[2]                               # force a stop at token 3
    reqs = [Request(id="r0", prompt=prompt, max_new_tokens=8, eos_id=eos)]
    _, done = _run_trace(cfg, params, reqs, num_slots=1)
    assert done["r0"].tokens.tolist() == ref[:3]
    assert done["r0"].reason == "eos"


def test_engine_validation(dense):
    cfg, _, params = dense
    vlm_cfg = get_smoke_config("paligemma-3b")
    with pytest.raises(ValueError, match="not servable"):
        ForecastEngine(vlm_cfg, None)
    ssm_cfg = get_smoke_config("xlstm-350m")
    with pytest.raises(ValueError, match="prefill_bucket"):
        ForecastEngine(ssm_cfg, None, prefill_bucket=8)
    eng = ForecastEngine(cfg, params, num_slots=1, cache_len=16)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng.submit(Request(id="big", prompt=np.zeros(10, np.int32),
                           max_new_tokens=10))
    # bucketing may not pad the prompt past the ring either (the scatter
    # would silently drop the earliest real tokens)
    eng_b = ForecastEngine(cfg, params, num_slots=1, cache_len=12,
                           prefill_bucket=16)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng_b.submit(Request(id="pad", prompt=np.zeros(10, np.int32),
                             max_new_tokens=2))
    # hybrid attention rings are always global — same overflow guard
    eng_h = ForecastEngine(get_smoke_config("zamba2-2.7b"), None,
                           num_slots=1, cache_len=16)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        eng_h.submit(Request(id="h", prompt=np.zeros(12, np.int32),
                             max_new_tokens=8))
    # a request larger than max_tokens_in_flight could never admit —
    # reject at submit instead of live-looping in run()
    eng_t = ForecastEngine(cfg, params, num_slots=1, cache_len=32,
                           max_tokens_in_flight=10)
    with pytest.raises(ValueError, match="max_tokens_in_flight"):
        eng_t.submit(Request(id="t", prompt=np.zeros(8, np.int32),
                             max_new_tokens=8))


# ---------------------------------------------------------------------------
# host-side pieces (no model)
# ---------------------------------------------------------------------------

def test_scheduler_budgets():
    sched = FIFOScheduler(SchedulerConfig(max_tokens_in_flight=40,
                                          prefill_chunk=16))
    for i in range(4):
        sched.submit(Request(id=f"r{i}", prompt=np.zeros(10, np.int32),
                             max_new_tokens=10, arrival_step=0))
    # prefill chunk: 10 + 10 fits 16? no — second request would overflow
    got = sched.admit(now_step=0, free_slots=4, tokens_in_flight=0)
    assert [r.id for r in got] == ["r0"]
    # token budget: 20 in flight + 20 == 40 fits, next would exceed
    got = sched.admit(now_step=1, free_slots=4, tokens_in_flight=20)
    assert [r.id for r in got] == ["r1"]
    # FIFO: a future arrival at the head blocks later-queued requests
    sched2 = FIFOScheduler()
    sched2.submit(Request(id="late", prompt=np.zeros(4, np.int32),
                          max_new_tokens=2, arrival_step=10))
    sched2.submit(Request(id="early", prompt=np.zeros(4, np.int32),
                          max_new_tokens=2, arrival_step=0))
    assert sched2.admit(now_step=0, free_slots=2, tokens_in_flight=0) == []
    got = sched2.admit(now_step=10, free_slots=2, tokens_in_flight=0)
    assert [r.id for r in got] == ["late", "early"]


def test_bucket_len():
    assert bucket_len(5, 8) == 8
    assert bucket_len(8, 8) == 8
    assert bucket_len(9, 8) == 16
    assert bucket_len(5, 0) == 5


def test_cache_pool_slot_lifecycle(dense):
    cfg, api, _ = dense
    pool = CachePool(api, cfg, num_slots=2, cache_len=16)
    a = pool.acquire()
    b = pool.acquire()
    assert {a, b} == {0, 1} and pool.free_slots == 0
    with pytest.raises(RuntimeError):
        pool.acquire()
    pool.release(a)
    with pytest.raises(ValueError):
        pool.release(a)
    assert pool.acquire() == a


def test_sharded_ragged_decode_on_emulated_mesh():
    """Per-slot positions (including a -1 inactive lane) through the
    seq-sharded shard_map combine must match the single-shard kernel —
    ragged engine batches ride the REPRO_CACHE_SHARD=seq path unchanged.
    Subprocess: the device-count flag must precede jax init."""
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.dist.decode import sharded_flash_decode, seq_shard_mesh
from repro.kernels.flash_decode import flash_decode_xla

B, S, Hk, G, D = 4, 256, 2, 4, 64
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, 1, Hk * G, D))
k = jax.random.normal(ks[1], (B, S, Hk, D))
v = jax.random.normal(ks[2], (B, S, Hk, D))
# ragged lanes: different fill levels per row, lane 2 inactive (-1)
pos = jnp.asarray([S - 1, 40, -1, 130], jnp.int32)
kv_pos = jnp.where(jnp.arange(S)[None] <= jnp.maximum(pos, 0)[:, None],
                   jnp.arange(S, dtype=jnp.int32)[None], -1)
mesh = jax.make_mesh((2, 2), ("data", "model"))
with mesh:
    assert seq_shard_mesh(S) is not None
    out = sharded_flash_decode(q, k, v, kv_pos, pos, mesh, block_kv=64)
want = flash_decode_xla(q, k, v, kv_pos, pos, block_kv=64)
np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                           rtol=1e-5, atol=1e-5)
assert np.all(np.asarray(out)[2] == 0.0)      # inactive lane fully masked
print("RAGGED_SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_CACHE_SHARD", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "RAGGED_SHARDED_OK" in r.stdout, \
        r.stdout + "\n" + r.stderr


def test_cache_batch_axes_structural(dense):
    """The structural batch-axis finder agrees with the known dense layout
    (layers stacked outside batch: (L, B, S, Hk, dh))."""
    cfg, api, _ = dense
    axes = cache_batch_axes(api, cfg)
    assert all(ax == 1 for ax in jax.tree.leaves(axes))
    hy = get_smoke_config("zamba2-2.7b")
    axes_h = cache_batch_axes(get_model(hy), hy)
    assert set(jax.tree.leaves(axes_h)) == {1, 2}   # attn vs (nG, nM) SSM
