"""Optimizers, schedules, data pipeline, DPO, and HLO-cost parser tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data.timeseries import (DATASETS, generate, make_windows,
                                   train_test_split)
from repro.data.tokens import lm_batches, markov_tokens
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedules import cosine_warmup


def test_adamw_minimizes_quadratic():
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"x": jnp.zeros(3)}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    for i in range(300):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, i + 1, lr=5e-2)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_mask_freezes_leaves():
    params = {"a": jnp.ones(2), "b": jnp.ones(2)}
    opt = adamw_init(params)
    grads = {"a": jnp.ones(2), "b": jnp.ones(2)}
    mask = {"a": True, "b": False}
    p2, _ = adamw_update(params, grads, opt, 1, lr=0.1, mask=mask)
    assert not np.allclose(np.asarray(p2["a"]), 1.0)
    np.testing.assert_array_equal(np.asarray(p2["b"]), 1.0)


def test_cosine_warmup_shape():
    lrs = [float(cosine_warmup(s, base_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0           # warmup ascends
    assert lrs[99] < lrs[20]                # cosine descends
    assert min(lrs[10:]) >= 0.099           # min_frac floor


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(40, 200), st.integers(4, 16), st.integers(2, 8))
def test_make_windows_shapes(T, L, H):
    series = np.zeros((T + L + H, 3), np.float32)
    x, y = make_windows(series, L, H)
    assert x.shape[1:] == (L, 3) and y.shape[1:] == (H, 3)
    assert len(x) == len(y) == T + 1


def test_window_alignment():
    series = np.arange(50, dtype=np.float32)[:, None]
    x, y = make_windows(series, 8, 4)
    np.testing.assert_array_equal(x[0, :, 0], np.arange(8))
    np.testing.assert_array_equal(y[0, :, 0], np.arange(8, 12))
    np.testing.assert_array_equal(x[5, :, 0], np.arange(5, 13))


def test_train_test_split_is_chronological():
    s = np.arange(100, dtype=np.float32)[:, None]
    tr, te = train_test_split(s, 0.8)
    assert len(tr) == 80 and len(te) == 20
    assert tr[-1, 0] < te[0, 0]


def test_generated_datasets_match_table1_features():
    for name, spec in DATASETS.items():
        s = generate(spec, timesteps=500, seed=1)
        assert s.shape == (500, spec.features), name
        assert np.all(np.isfinite(s)), name


def test_markov_tokens_learnable_structure():
    toks = markov_tokens(5000, 64, seed=0, branching=4)
    assert toks.min() >= 0 and toks.max() < 64
    # the bigram distribution must be concentrated (branching=4 of 64)
    seen = {}
    for a, b in zip(toks[:-1], toks[1:]):
        seen.setdefault(int(a), set()).add(int(b))
    avg_branch = np.mean([len(v) for v in seen.values()])
    assert avg_branch <= 8


def test_lm_batches_shift_labels():
    toks = markov_tokens(500, 16, seed=1)
    b = next(lm_batches(toks, 4, 32, seed=0))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# DPO
# ---------------------------------------------------------------------------

def test_dpo_loss_prefers_better_forecast():
    from repro.configs import get_smoke_config
    from repro.core import dpo, fedtime
    cfg = get_smoke_config("fedtime-llama2-7b")
    params = fedtime.init(cfg, jax.random.PRNGKey(0), num_channels=2)
    L, T = cfg.fedtime.lookback, cfg.fedtime.horizon
    x = jax.random.normal(jax.random.PRNGKey(1), (2, L, 2))
    y = jax.random.normal(jax.random.PRNGKey(2), (2, T, 2))
    batch = dpo.make_preference_pairs(jax.random.PRNGKey(3), x, y)
    # y_w is closer to truth than y_l by construction
    assert float(jnp.mean((batch["y_w"] - y) ** 2)) < \
        float(jnp.mean((batch["y_l"] - y) ** 2))
    l = dpo.dpo_loss(params, params, cfg, batch)
    # identical policy and ref => logit 0 => loss = -log sigmoid(0) = ln 2
    np.testing.assert_allclose(float(l), np.log(2.0), rtol=1e-4)
    g = jax.grad(lambda p: dpo.dpo_loss(p, params, cfg, batch))(params)
    assert any(float(jnp.abs(x).max()) > 0 for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# HLO cost parser (roofline substrate)
# ---------------------------------------------------------------------------

def test_hlo_cost_counts_scan_trip_counts():
    from repro.launch.hlo_cost import analyze

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h.sum()

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(jax.grad(f, argnums=1)).lower(x, w).compile().as_text()
    r = analyze(txt)
    # fwd 8 matmuls + bwd dgrad/wgrad 8 each = 24 x (2*128*256*256)
    expected = 24 * 2 * 128 * 256 * 256
    assert abs(r["flops_per_device"] - expected) / expected < 0.01


def test_hlo_cost_counts_collectives_inside_loops():
    from repro.launch.hlo_cost import analyze
    # single-device: no collectives expected; just exercise the parser
    def f(x):
        def body(c, _):
            return c * 2.0, None
        c, _ = jax.lax.scan(body, x, None, length=5)
        return c
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64,), jnp.float32)).compile().as_text()
    r = analyze(txt)
    assert r["collective_total_bytes"] == 0
    assert r["bytes_per_device"] > 0
