"""Serving fault tolerance: SLO deadlines on the virtual clock, cost-aware
load shedding, poison-request quarantine, the write-ahead request journal
(in-process and via a real kill-9 subprocess), and the full serving chaos
acceptance trace."""

import os
import signal
import struct
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.fault import SERVE_FAULT_KINDS, FaultPlan, ServingFaultPlan
from repro.fault.clock import VirtualClock
from repro.launch.steps import make_serve_step
from repro.models.registry import get_model
from repro.serve import (ForecastEngine, Request, RequestJournal,
                         SamplingParams, replay_journal)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CACHE_LEN = 48


@pytest.fixture(scope="module")
def dense():
    cfg = get_smoke_config("qwen3-0.6b")
    api = get_model(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, api, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def _solo_greedy(api, cfg, params, prompt, gen, cache_len=CACHE_LEN):
    """Reference: the request alone through prefill + serve_step."""
    import jax.numpy as jnp
    cache, logits = api.prefill(
        params, cfg, {"tokens": jnp.asarray(prompt[None])},
        cache_len=cache_len)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    P = len(prompt)
    for i in range(gen - 1):
        tok, cache = serve(params, cache,
                           {"token": tok,
                            "pos": jnp.asarray([P + i], jnp.int32)})
        out.append(int(tok[0, 0]))
    return out


# ---------------------------------------------------------------------------
# SLO deadlines on the virtual clock
# ---------------------------------------------------------------------------

def test_deadline_cancels_mid_decode_on_virtual_clock(dense):
    """A deadline-busting request is cancelled MID-decode at the first
    tick past its window — partial output is a bit-identical prefix of
    the solo run, the lane's capacity is fully reclaimed, and the
    neighbour finishes untouched.  No wall-clock sleeping anywhere."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 9], seed=11)
    ref0 = _solo_greedy(api, cfg, params, prompts[0], 12)
    ref1 = _solo_greedy(api, cfg, params, prompts[1], 5)
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                         clock=VirtualClock(), step_time_s=0.1)
    assert eng.submit(Request(id="d0", prompt=prompts[0], max_new_tokens=12,
                              deadline_s=0.55)).ok
    assert eng.submit(Request(id="d1", prompt=prompts[1],
                              max_new_tokens=5)).ok
    done = eng.run(max_steps=200)

    assert done["d0"].reason == "deadline"
    got = done["d0"].tokens.tolist()
    # honored on the virtual clock: admitted at t=0, one token per 0.1s
    # tick, cancelled at the first sweep past 0.55 -> at most 7 tokens,
    # and every one bit-identical to the uninterrupted run
    assert 0 < len(got) <= 7 < 12
    assert got == ref0[:len(got)]
    assert done["d1"].reason == "length"
    assert done["d1"].tokens.tolist() == ref1
    # full reclamation: every lane and block back in the pool
    assert eng.active_requests == 0 and eng.pool.free_slots == 2
    if eng.paged:
        eng.pool.assert_partition()
    summ = eng.metrics.summary()
    assert summ["deadline_misses"] == 1 and summ["ttft_slo_misses"] == 0
    assert summ["requests_submitted"] == 2
    assert summ["deadline_miss_rate"] == pytest.approx(0.5)
    assert eng.num_step_signatures() == 1


def test_ttft_slo_cancels_queued_request(dense):
    """A request whose first token can't land inside its TTFT SLO is
    cancelled while still QUEUED — zero device work, the resident
    neighbour decodes to the bit-identical end."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 6], seed=12)
    ref0 = _solo_greedy(api, cfg, params, prompts[0], 8)
    eng = ForecastEngine(cfg, params, num_slots=1, cache_len=CACHE_LEN,
                         clock=VirtualClock(), step_time_s=0.1)
    eng.submit(Request(id="r0", prompt=prompts[0], max_new_tokens=8))
    eng.submit(Request(id="r1", prompt=prompts[1], max_new_tokens=4,
                       ttft_slo_s=0.35))
    done = eng.run(max_steps=200)
    assert done["r0"].reason == "length"
    assert done["r0"].tokens.tolist() == ref0
    assert done["r1"].reason == "ttft_slo"
    assert done["r1"].tokens.size == 0
    summ = eng.metrics.summary()
    assert summ["deadline_misses"] == 1 and summ["ttft_slo_misses"] == 1


# ---------------------------------------------------------------------------
# admission backpressure: cost-aware load shedding
# ---------------------------------------------------------------------------

def test_load_shedding_cheapest_to_retry_newest_first(dense):
    """Bounded queue: overflow sheds the cheapest-to-retry request
    (fewest total tokens, newest on ties) — sometimes the incoming one,
    sometimes a queued victim it displaces — with a deterministic
    retry_after_s hint.  Accepted survivors decode bit-identically."""
    cfg, api, params = dense
    # totals (prompt + gen): s0=10, s1=13, s2=10, s3=11, s4=10
    prompts = _prompts(cfg, [6, 9, 6, 7, 6], seed=13)
    eng = ForecastEngine(cfg, params, num_slots=1, cache_len=CACHE_LEN,
                         clock=VirtualClock(), step_time_s=0.1, max_queue=2)
    v = [eng.submit(Request(id=f"s{i}", prompt=p, max_new_tokens=4))
         for i, p in enumerate(prompts)]
    assert [x.verdict for x in v] == ["ok", "ok", "shed", "ok", "shed"]
    # s2 ties s0 on cost (10) -> newest sheds: s2 itself
    assert v[2].retry_after_s > 0 and v[2].shed_id is None
    # s3 (11) displaces the strictly cheaper queued s0 (10)
    assert v[3].shed_id == "s0"
    # s4 (10) is itself the cheapest+newest among {s4, s1, s3}
    assert v[4].verdict == "shed"
    assert set(eng.shed_log) == {"s0", "s2", "s4"}
    done = eng.run(max_steps=200)
    assert set(done) == {"s1", "s3"}
    for rid, gen in (("s1", 4), ("s3", 4)):
        i = int(rid[1:])
        assert done[rid].tokens.tolist() == \
            _solo_greedy(api, cfg, params, prompts[i], gen), rid
    summ = eng.metrics.summary()
    # shed requests never counted as accepted submits
    assert summ["shed"] == 3 and summ["requests_submitted"] == 3


def test_shedding_never_evicts_a_request_past_first_token(dense):
    """A queued RESUME (eviction/swap/journal replay — it has generated
    tokens and a paid-for TTFT) is never a shed victim: under
    backpressure the incoming fresh request sheds instead, even when it
    is cheaper."""
    cfg, _, params = dense
    prompts = _prompts(cfg, [6, 4], seed=14)
    eng = ForecastEngine(cfg, params, num_slots=1, cache_len=CACHE_LEN,
                         max_queue=1)
    resumed = Request(id="old", prompt=prompts[0], max_new_tokens=6,
                      resume={"generated": [3, 5], "prompt_len": 4})
    assert eng.submit(resumed).ok
    fresh = eng.submit(Request(id="new", prompt=prompts[1],
                               max_new_tokens=2))
    assert fresh.verdict == "shed" and fresh.shed_id is None
    assert [q.id for q in eng.scheduler.queued()] == ["old"]


# ---------------------------------------------------------------------------
# poison quarantine
# ---------------------------------------------------------------------------

def test_poison_quarantines_one_lane_neighbours_bit_identical(dense):
    """NaN-poisoned logits quarantine ONLY the offending lane: the audit
    names the reason, the pool partition invariant holds, and every
    neighbour — including one sharing the batch at the poisoned step —
    decodes bit-identically to its solo run.  The armed guard never adds
    a second serve_step signature."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [6, 9, 6, 11], seed=15)
    gens = [5, 6, 5, 4]
    refs = [_solo_greedy(api, cfg, params, p, g)
            for p, g in zip(prompts, gens)]
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        assert eng.submit(Request(id=f"r{i}", prompt=p,
                                  max_new_tokens=g)).ok
    eng.poison("r1")
    done = eng.run(max_steps=300)

    assert set(eng.quarantined) == {"r1"}
    q = eng.quarantined["r1"]
    assert q.reason == "nonfinite_logits" and q.prompt_len == 9
    assert "r1" not in done
    for i in (0, 2, 3):
        assert done[f"r{i}"].tokens.tolist() == refs[i], i
    if eng.paged:
        eng.pool.assert_partition()
    assert eng.pool.free_slots == 2
    assert eng.metrics.quarantined == {"nonfinite_logits": 1}
    assert eng.num_step_signatures() == 1


def test_malformed_prompt_quarantined_at_submit(dense):
    """Out-of-vocabulary prompt ids are screened BEFORE any device work:
    verdict "quarantined", audited, never queued."""
    cfg, _, params = dense
    plan = ServingFaultPlan({0: "malformed"}, seed=3)
    good = _prompts(cfg, [7], seed=16)[0]
    bad = plan.malform_prompt(0, good, cfg.vocab_size)
    assert bad.max() >= cfg.vocab_size and (bad != good).sum() == 1
    eng = ForecastEngine(cfg, params, num_slots=1, cache_len=CACHE_LEN)
    v = eng.submit(Request(id="m0", prompt=bad, max_new_tokens=4))
    assert v.verdict == "quarantined" and v.reason == "malformed_prompt"
    assert eng.scheduler.pending == 0
    assert eng.quarantined["m0"].reason == "malformed_prompt"
    assert eng.metrics.quarantined == {"malformed_prompt": 1}


# ---------------------------------------------------------------------------
# write-ahead request journal
# ---------------------------------------------------------------------------

def test_journal_roundtrip_resubmit_and_torn_tail(tmp_path):
    """Framing survives a torn tail: replay trusts everything before the
    tear, a re-submit under the same id (a shed retry) restarts that id's
    history, and an append-reopen truncates the tear away."""
    path = str(tmp_path / "req.jrnl")
    r0 = Request(id="a", prompt=[1, 2, 3], max_new_tokens=4,
                 deadline_s=2.0, sampling=SamplingParams(seed=7))
    r1 = Request(id="b", prompt=[4, 5], max_new_tokens=3)
    with RequestJournal(path) as j:
        j.log_submit(r0)
        j.log_token("a", 11)
        j.log_submit(r1)
        j.log_token("b", 21)
        j.commit()
        j.log_finish("b", "length")
        # shed retry: same id, fresh history
        j.log_finish("a", "shed")
        j.log_submit(r0)
        j.log_token("a", 12)

    st = replay_journal(path)
    assert not st.torn and st.unfinished_ids == ["a"]
    assert st.tokens["a"] == [12] and st.finished["b"] == "length"
    reqs = st.unfinished_requests()
    assert len(reqs) == 1 and reqs[0].id == "a"
    assert reqs[0].resume == {"generated": [12], "prompt_len": 3}
    assert reqs[0].prompt.tolist() == [1, 2, 3, 12]
    assert reqs[0].deadline_s == 2.0 and reqs[0].sampling.seed == 7

    # tear: a half-written record (header promises more than exists)
    size = os.path.getsize(path)
    with open(path, "ab") as f:
        f.write(struct.pack("<II", 100, 0) + b"xx")
    torn = replay_journal(path)
    assert torn.torn and torn.unfinished_ids == ["a"]
    assert torn.records == st.records
    # append-reopen truncates the tear so the file stays parseable
    with RequestJournal(path) as j:
        j.log_finish("a", "length")
    assert os.path.getsize(path) > size
    final = replay_journal(path)
    assert not final.torn and final.unfinished_ids == []


def test_journal_replay_resumes_bit_identical_in_process(dense, tmp_path):
    """Kill-free rehearsal of crash recovery: stop an engine mid-trace,
    replay its journal into a fresh engine, and the union of both
    generations' outputs is the fault-free run — zero lost, zero
    duplicated, bit-identical."""
    cfg, api, params = dense
    path = str(tmp_path / "req.jrnl")
    prompts = _prompts(cfg, [6, 9, 6, 11], seed=17)
    gens = [5, 3, 6, 4]
    refs = [_solo_greedy(api, cfg, params, p, g)
            for p, g in zip(prompts, gens)]

    eng1 = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                          journal=path)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        assert eng1.submit(Request(id=f"r{i}", prompt=p,
                                   max_new_tokens=g)).ok
    for _ in range(4):                       # abandon mid-trace
        eng1.step()
    eng1.journal.close()

    st = replay_journal(path)
    assert 0 < len(st.unfinished_ids) < 4    # some finished, some didn't
    eng2 = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                          journal=path)
    for r in st.unfinished_requests():
        assert eng2.submit(r).ok
    done2 = eng2.run(max_steps=300)

    # zero lost, zero duplicated
    assert set(done2) == set(st.unfinished_ids)
    assert set(st.finished) | set(done2) == {f"r{i}" for i in range(4)}
    assert not set(st.finished) & set(done2)
    for i in range(4):
        rid = f"r{i}"
        got = (done2[rid].tokens.tolist() if rid in done2
               else st.tokens[rid])
        assert got == refs[i], rid
    # the continued journal is itself coherent: nothing left unfinished
    eng2.journal.close()
    assert replay_journal(path).unfinished_ids == []


# ---------------------------------------------------------------------------
# cancellation never reorders FIFO unparking (satellite)
# ---------------------------------------------------------------------------

def test_cancellation_frees_blocks_without_reordering_fifo(dense):
    """When an SLO cancellation frees blocks mid-tick, the grant pass
    hands them out in original-submit order — NOT slot order.  With the
    seq of the slot-0 lane forced newest, the freed blocks must unpark
    the older lanes in higher slots first, and the starved lane still
    finishes bit-identically once capacity returns."""
    cfg, api, params = dense
    prompts = _prompts(cfg, [8, 8, 8, 8], seed=18)
    refs = [_solo_greedy(api, cfg, params, p, 6, cache_len=32)
            for p in prompts]
    eng = ForecastEngine(cfg, params, num_slots=4, cache_len=32,
                         paged=True, block_size=8, pool_blocks=5,
                         share_prefixes=False, swap_tier=False,
                         clock=VirtualClock(), step_time_s=0.1)
    eng.submit(Request(id="r0", prompt=prompts[0], max_new_tokens=10,
                       deadline_s=0.25))
    for i in (1, 2, 3):
        eng.submit(Request(id=f"r{i}", prompt=prompts[i], max_new_tokens=6))
    # 4 lanes x 1 prompt block + r0's write block == all 5 blocks: r0
    # decodes, r1/r2/r3 park awaiting their write block
    for _ in range(3):
        eng.step()
    slot_of = {eng.slots[i].request.id: i
               for i in range(4) if eng.slots[i] is not None}
    assert eng._pos[slot_of["r0"]] >= 0
    assert all(eng._pos[slot_of[r]] < 0 for r in ("r1", "r2", "r3"))
    # pretend r1 (slot 1) is the NEWEST request — a slot-order grant walk
    # would now differ from a submit-order walk
    eng._seq["r1"] = 99
    eng.step()          # sweep cancels r0 (t=0.3 > 0.25) -> 2 blocks free
    assert "r0" in eng.finished and eng.finished["r0"].reason == "deadline"
    # FIFO: the two freed blocks went to r2 and r3 (older seq), r1 waits
    assert eng._pos[slot_of["r2"]] >= 0 and eng._pos[slot_of["r3"]] >= 0
    assert eng._pos[slot_of["r1"]] < 0
    done = eng.run(max_steps=300)
    for i in (1, 2, 3):
        assert done[f"r{i}"].tokens.tolist() == refs[i], i
    eng.pool.assert_partition()


# ---------------------------------------------------------------------------
# chaos acceptance: staggered trace, 25% request-level faults
# ---------------------------------------------------------------------------

def test_serving_chaos_acceptance(dense, tmp_path):
    """ISSUE acceptance: a staggered 16-request trace with 25% injected
    request-level faults (malformed, NaN-poisoned, deadline-busting,
    burst) over a bounded queue with shed-retry, all on the virtual
    clock: every non-poisoned request finishes, survivors bit-identical
    to their fault-free runs, quarantines audited by reason, deadline
    windows honored, one serve_step signature, and the journal replays
    to zero unfinished requests."""
    cfg, api, params = dense
    plan = ServingFaultPlan({2: "malformed", 5: "poison",
                             9: "deadline", 12: "burst"}, seed=5)
    assert plan.fault_rate(16) == 0.25
    assert set(plan.faults.values()) <= set(SERVE_FAULT_KINDS)
    lens, gens = [6, 9, 7, 11], [5, 3, 6, 4]
    prompts = _prompts(cfg, [lens[i % 4] for i in range(16)], seed=19)
    refs = {f"c{i}": _solo_greedy(api, cfg, params, prompts[i],
                                  gens[i % 4])
            for i in range(16) if plan.kind_for(i) != "malformed"}

    path = str(tmp_path / "chaos.jrnl")
    step_s = 0.1
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=CACHE_LEN,
                         clock=VirtualClock(), step_time_s=step_s,
                         max_queue=3, journal=path)

    def build(i):
        kind = plan.kind_for(i)
        prompt = prompts[i]
        if kind == "malformed":
            prompt = plan.malform_prompt(i, prompt, cfg.vocab_size)
        return Request(
            id=f"c{i}", prompt=prompt, max_new_tokens=gens[i % 4],
            deadline_s=0.15 if kind == "deadline" else None)

    # staggered arrivals (two per tick); a "burst" request jumps to t=0
    pending = sorted(
        (0 if plan.kind_for(i) == "burst" else i // 2, i)
        for i in range(16))
    shed_events = 0
    t = 0
    while pending or eng.scheduler.pending or eng.active_requests:
        assert t < 800, "chaos trace did not drain"
        still = []
        for (due, i) in pending:
            if due > t:
                still.append((due, i))
                continue
            v = eng.submit(build(i))
            if plan.kind_for(i) == "poison" and v.ok:
                eng.poison(f"c{i}")
            if v.verdict == "shed":
                shed_events += 1
                still.append((t + int(v.retry_after_s / step_s) + 1, i))
            elif v.shed_id is not None:        # displaced victim retries
                shed_events += 1
                j = int(v.shed_id[1:])
                still.append(
                    (t + int(eng.shed_log[v.shed_id] / step_s) + 1, j))
        pending = sorted(still)
        eng.step()
        t += 1
    done = eng.finished

    # zero lost, zero duplicated: every request is exactly one of
    # finished / quarantined
    all_ids = {f"c{i}" for i in range(16)}
    assert set(done) | set(eng.quarantined) == all_ids
    assert not set(done) & set(eng.quarantined)
    # quarantines audited by reason
    assert eng.quarantined["c2"].reason == "malformed_prompt"
    assert eng.quarantined["c5"].reason == "nonfinite_logits"
    assert set(eng.quarantined) == {"c2", "c5"}
    # the deadline-busting request was cancelled, partial work intact
    assert done["c9"].reason == "deadline"
    assert done["c9"].tokens.tolist() == refs["c9"][:done["c9"].tokens.size]
    # every survivor bit-identical to its fault-free run
    survivors = all_ids - {"c2", "c5", "c9"}
    for rid in sorted(survivors):
        assert done[rid].reason in ("length", "eos"), rid
        assert done[rid].tokens.tolist() == refs[rid], rid
    # greedy-mismatch count, the bench-gated number, is therefore 0
    mism = sum(done[r].tokens.tolist() != refs[r] for r in survivors)
    assert mism == 0
    assert eng.num_step_signatures() == 1
    if eng.paged:
        eng.pool.assert_partition()
    summ = eng.metrics.summary()
    assert summ["quarantined"] == 2 and summ["deadline_misses"] >= 1
    assert summ["shed"] == shed_events
    # journal coherence after the storm: nothing left unfinished
    eng.journal.close()
    assert replay_journal(path).unfinished_ids == []


def test_random_serving_plan_deterministic():
    a = FaultPlan.random_serving(40, 0.3, seed=4)
    b = FaultPlan.random_serving(40, 0.3, seed=4)
    assert a == b and 0.05 < a.fault_rate(40) < 0.6
    assert all(k in SERVE_FAULT_KINDS[:4] for k in a.faults.values())
    assert FaultPlan.random_serving(40, 0.3, seed=9) != a


# ---------------------------------------------------------------------------
# kill -9 mid-trace: journal replay in a real subprocess
# ---------------------------------------------------------------------------

_CHILD = """
import os, signal, sys
import numpy as np, jax
sys.path.insert(0, os.path.join({repo!r}, "src"))
from repro.configs import get_smoke_config
from repro.models.registry import get_model
from repro.serve import ForecastEngine, Request, replay_journal

mode, out = sys.argv[1], sys.argv[2]
cfg = get_smoke_config("qwen3-0.6b")
api = get_model(cfg)
params = api.init(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(21)
lens, gens = [6, 9, 7, 11, 6, 8], [5, 3, 6, 4, 5, 4]
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in lens]
jrnl = os.path.join(out, "req.jrnl")

if mode == "full":
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=48)
    for i in range(6):
        eng.submit(Request(id=f"r{{i}}", prompt=prompts[i],
                           max_new_tokens=gens[i]))
    done = eng.run(max_steps=300)
    np.savez(os.path.join(out, "full.npz"),
             **{{r: done[r].tokens for r in done}})
elif mode == "crash":
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=48,
                         journal=jrnl)
    for i in range(6):
        eng.submit(Request(id=f"r{{i}}", prompt=prompts[i],
                           max_new_tokens=gens[i]))
    while eng.scheduler.pending or eng.active_requests:
        eng.step()
        if eng.step_count == 3:   # kill -9 mid-trace, journal mid-history
            os.kill(os.getpid(), signal.SIGKILL)
elif mode == "resume":
    st = replay_journal(jrnl)
    eng = ForecastEngine(cfg, params, num_slots=2, cache_len=48,
                         journal=jrnl)
    for r in st.unfinished_requests():
        assert eng.submit(r).ok
    done = eng.run(max_steps=300)
    # zero lost, zero duplicated across the crash
    assert set(done) == set(st.unfinished_ids)
    assert not set(done) & set(st.finished)
    merged = {{r: np.asarray(st.tokens[r], np.int32) for r in st.finished}}
    merged.update({{r: done[r].tokens for r in done}})
    assert len(merged) == 6
    np.savez(os.path.join(out, "resume.npz"), **merged)
"""


def test_kill9_mid_trace_journal_replay_bit_identical(tmp_path):
    """ISSUE acceptance: SIGKILL the engine process mid-trace; a fresh
    process replays the request journal and finishes every request with
    zero lost, zero duplicated, and outputs bit-identical to an
    uninterrupted run."""
    script = tmp_path / "child.py"
    script.write_text(_CHILD.format(repo=REPO))
    env = {**os.environ, "REPRO_TRACE": "0"}

    def run(mode):
        return subprocess.run([sys.executable, str(script), mode,
                               str(tmp_path)], env=env, timeout=560)

    crashed = run("crash")
    assert crashed.returncode == -signal.SIGKILL   # actually kill-9'd
    assert (tmp_path / "req.jrnl").exists()
    assert run("resume").returncode == 0
    assert run("full").returncode == 0

    a = np.load(tmp_path / "resume.npz")
    b = np.load(tmp_path / "full.npz")
    assert set(a.files) == set(b.files) == {f"r{i}" for i in range(6)}
    for k in b.files:
        assert np.array_equal(a[k], b[k]), k
