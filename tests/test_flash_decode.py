"""flash_decode validation: Pallas kernel (interpret mode) and the XLA
blockwise fallback vs the naive oracle, across GQA ratios, ring wrap-around,
sliding-window + prefix masking, int8 vs bf16 caches; split-partial combine
(the seq-sharded psum math); attn_decode routing (no full-cache dequant on
the fused path); ragged blockwise sdpa."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode, flash_decode_xla
from repro.models.layers import attention as attn_mod
from repro.models.layers.attention import (_quant_kv as _quant, attn_decode,
                                           init_attention, init_attn_cache,
                                           sdpa)


def _case(B=2, S=200, Hk=2, G=4, D=64, *, int8=False, wrap=False,
          dtype=jnp.float32, seed=0):
    """Build (q, k, v, kv_pos, pos, kwargs-for-scales).  ``wrap`` makes
    pos > cache_len so the ring has been overwritten at least once."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, Hk * G, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hk, D)).astype(dtype)
    if wrap:
        pos = S + S // 2 + 3                 # ring overwritten once
        positions = jnp.arange(pos - S + 1, pos + 1, dtype=jnp.int32)
        kv_pos = jnp.zeros((S,), jnp.int32).at[positions % S].set(positions)
    else:
        pos = S - 1
        kv_pos = jnp.arange(S, dtype=jnp.int32)
    kv_pos = jnp.broadcast_to(kv_pos[None], (B, S))
    kw = {}
    if int8:
        kq, ksc = _quant(k.astype(jnp.float32))
        vq, vsc = _quant(v.astype(jnp.float32))
        k, v = kq, vq
        kw = dict(k_scale=ksc, v_scale=vsc)
    return q, k, v, kv_pos, jnp.asarray(pos, jnp.int32), kw


def _tol(int8, dtype):
    if int8:
        return 3e-2
    return 1e-2 if dtype == jnp.bfloat16 else 1e-5


@pytest.mark.parametrize("G", [1, 4, 8])
@pytest.mark.parametrize("int8", [False, True])
def test_flash_decode_gqa_sweep(G, int8):
    q, k, v, kv_pos, pos, kw = _case(G=G, int8=int8, seed=G)
    o_r = ref.flash_decode_ref(q, k, v, kv_pos, pos, **kw)
    o_p = flash_decode(q, k, v, kv_pos, pos, block_kv=128, n_splits=2,
                       interpret=True, **kw)
    o_x = flash_decode_xla(q, k, v, kv_pos, pos, block_kv=64, **kw)
    tol = _tol(int8, jnp.float32)
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(o_x, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("int8", [False, True])
def test_flash_decode_ring_wraparound(int8):
    """pos > cache_len: slot order no longer equals position order."""
    q, k, v, kv_pos, pos, kw = _case(S=160, int8=int8, wrap=True, seed=7)
    o_r = ref.flash_decode_ref(q, k, v, kv_pos, pos, window=96, **kw)
    o_p = flash_decode(q, k, v, kv_pos, pos, window=96, block_kv=128,
                       interpret=True, **kw)
    tol = _tol(int8, jnp.float32)
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=tol, atol=tol)


def test_flash_decode_window_and_prefix():
    q, k, v, kv_pos, pos, _ = _case(B=2, S=130, seed=3)
    plen = jnp.asarray([17, 40], jnp.int32)
    for window in (0, 31):
        o_r = ref.flash_decode_ref(q, k, v, kv_pos, pos, kind="prefix",
                                   prefix_len=plen, window=window)
        o_p = flash_decode(q, k, v, kv_pos, pos, kind="prefix",
                           prefix_len=plen, window=window, block_kv=128,
                           interpret=True)
        o_x = flash_decode_xla(q, k, v, kv_pos, pos, kind="prefix",
                               prefix_len=plen, window=window, block_kv=32)
        np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(o_x), np.asarray(o_r),
                                   rtol=1e-5, atol=1e-5)


def test_flash_decode_full_kind_bf16():
    """Cross-attention shape: every valid slot attends (kind="full")."""
    q, k, v, kv_pos, _, _ = _case(S=96, dtype=jnp.bfloat16, seed=5)
    o_r = ref.flash_decode_ref(q, k, v, kv_pos, 0, kind="full")
    o_p = flash_decode(q, k, v, kv_pos, 0, kind="full", block_kv=128,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(o_p, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=1e-2, atol=1e-2)


def test_flash_decode_softcap():
    q, k, v, kv_pos, pos, _ = _case(S=64, seed=11)
    o_r = ref.flash_decode_ref(q, k, v * 0 + 1.0, kv_pos, pos, softcap=20.0)
    o_p = flash_decode(q, k, v * 0 + 1.0, kv_pos, pos, softcap=20.0,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)


def test_ops_dispatch_forced_interpret(monkeypatch):
    """REPRO_FORCE_KERNELS=1 routes ops.flash_decode through the Pallas
    kernel in interpret mode off-TPU."""
    monkeypatch.setenv("REPRO_FORCE_KERNELS", "1")
    assert ops.use_kernels()
    q, k, v, kv_pos, pos, kw = _case(S=140, int8=True, seed=13)
    o = ops.flash_decode(q, k, v, kv_pos, pos, **kw)
    o_r = ref.flash_decode_ref(q, k, v, kv_pos, pos, **kw)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_r, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_partials_combine_matches_full():
    """Two half-cache partials merged with the pmax/psum formula must equal
    the unsharded kernel — the math repro.dist.decode runs over ``model``."""
    q, k, v, kv_pos, pos, kw = _case(S=256, int8=True, seed=17)
    half = 128
    parts = []
    for sl in (slice(0, half), slice(half, 256)):
        parts.append(flash_decode_xla(
            q, k[:, sl], v[:, sl], kv_pos[:, sl], pos,
            k_scale=kw["k_scale"][:, sl], v_scale=kw["v_scale"][:, sl],
            block_kv=64, return_partials=True))
    m = jnp.stack([p[0] for p in parts])
    l = jnp.stack([p[1] for p in parts])
    acc = jnp.stack([p[2] for p in parts])
    m_g = m.max(0)
    w = jnp.exp(m - m_g)
    out = ((acc * w).sum(0) / jnp.maximum((l * w).sum(0), 1e-30))
    B, Hk, G, D = out.shape
    out = out.reshape(B, 1, Hk * G, D).astype(q.dtype)
    o_full = flash_decode_xla(q, k, v, kv_pos, pos, block_kv=64, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_full),
                               rtol=1e-5, atol=1e-5)


def test_sharded_flash_decode_on_emulated_mesh():
    """The shard_map pmax/psum combine on a real (emulated) multi-device
    mesh must match the oracle.  Runs in a subprocess: the device-count
    flag only takes effect before jax initializes (conftest pins this
    process to one device)."""
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.dist.decode import sharded_flash_decode, seq_shard_mesh
from repro.kernels import ref
from repro.models.layers.attention import _quant_kv

B, S, Hk, G, D = 2, 256, 2, 4, 64
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, 1, Hk * G, D))
kf = jax.random.normal(ks[1], (B, S, Hk, D))
vf = jax.random.normal(ks[2], (B, S, Hk, D))
kq, ksc = _quant_kv(kf)
vq, vsc = _quant_kv(vf)
kv_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
pos = jnp.asarray(S - 1, jnp.int32)
mesh = jax.make_mesh((2, 2), ("data", "model"))
with mesh:
    assert seq_shard_mesh(S) is not None
    for kw in (dict(), dict(window=70),
               dict(k_scale=ksc, v_scale=vsc, kind="prefix",
                    prefix_len=jnp.asarray([10, 60], jnp.int32))):
        k, v = (kq, vq) if "k_scale" in kw else (kf, vf)
        out = sharded_flash_decode(q, k, v, kv_pos, pos, mesh,
                                   block_kv=64, **kw)
        want = ref.flash_decode_ref(q, k, v, kv_pos, pos, **kw)
        tol = 3e-2 if "k_scale" in kw else 1e-5
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=tol, atol=tol)
print("SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_CACHE_SHARD", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "SHARDED_OK" in r.stdout, \
        r.stdout + "\n" + r.stderr


def test_attn_decode_fused_path_skips_full_dequant(monkeypatch):
    """On the fused path the int8 cache must never be dequantized whole:
    _dequant_kv (the full-cache helper) must not run during attn_decode."""
    monkeypatch.setenv("REPRO_KV_INT8", "1")
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_attention(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    B, S = 2, 24
    cache = init_attn_cache(B, S, cfg.num_kv_heads, cfg.resolved_head_dim(),
                            dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model))

    def boom(*a, **k):
        raise AssertionError("full-cache _dequant_kv on the fused path")

    monkeypatch.setattr(attn_mod, "_dequant_kv", boom)
    y, _ = attn_decode(params, cfg, x, cache, jnp.asarray(0, jnp.int32))
    assert np.all(np.isfinite(np.asarray(y, np.float32)))


@pytest.mark.parametrize("int8", [False, True])
def test_attn_decode_fused_matches_legacy(monkeypatch, int8):
    """REPRO_FLASH_DECODE=0 (dequant-then-sdpa) and the fused path must
    agree step by step."""
    monkeypatch.setenv("REPRO_KV_INT8", "1" if int8 else "0")
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_attention(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))

    outs = {}
    for flag in ("1", "0"):
        monkeypatch.setenv("REPRO_FLASH_DECODE", flag)
        cache = init_attn_cache(B, S, cfg.num_kv_heads,
                                cfg.resolved_head_dim(), dtype=jnp.float32)
        ys = []
        for t in range(S):
            y, cache = attn_decode(params, cfg, x[:, t:t + 1], cache,
                                   jnp.asarray(t, jnp.int32))
            ys.append(np.asarray(y[:, 0]))
        outs[flag] = np.stack(ys)
    np.testing.assert_allclose(outs["1"], outs["0"], rtol=2e-4, atol=2e-4)


def test_sdpa_blockwise_ragged_lengths():
    """Skv/Sq not divisible by the block sizes must pad, not crash."""
    B, Sq, Skv, H, Hk, D = 1, 50, 100, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(19), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Skv, Hk, D))
    v = jax.random.normal(ks[2], (B, Skv, Hk, D))
    qp = jnp.arange(Skv - Sq, Skv, dtype=jnp.int32)
    kp = jnp.arange(Skv, dtype=jnp.int32)
    naive = sdpa(q, k, v, q_pos=qp, kv_pos=kp, kind="causal")
    block = sdpa(q, k, v, q_pos=qp, kv_pos=kp, kind="causal",
                 block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(block), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


def test_sdpa_blockwise_int8_scales_in_scan():
    """Scales passed through: blockwise in-scan dequant == naive dequant."""
    B, S, H, Hk, D = 2, 96, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    kq, ksc = _quant(k)
    vq, vsc = _quant(v)
    qp = jnp.full((B, 1), S - 1, jnp.int32)
    kp = jnp.arange(S, dtype=jnp.int32)
    naive = sdpa(q, (kq.astype(jnp.float32) * ksc.astype(jnp.float32)),
                 (vq.astype(jnp.float32) * vsc.astype(jnp.float32)),
                 q_pos=qp, kv_pos=kp, kind="causal")
    fused = sdpa(q, kq, vq, k_scale=ksc, v_scale=vsc,
                 q_pos=qp, kv_pos=kp, kind="causal", block_kv=32)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)
