"""Federated communication fast path (repro.kernels.ring_allreduce +
repro.dist.fedcomm): psum parity, wire formats, error feedback, the
three-way byte agreement, and the ZeRO-1 scatter-update AdamW.

Multi-device cases run in subprocesses (like test_paged_pool) because the
emulated device count must be set before jax initializes; the scripts
inherit REPRO_FORCE_KERNELS so the CI interpret job drives the Pallas
fused-hop kernel, not just its jnp oracle.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm
from repro.dist import fed, fedcomm

_ENV_KEYS = ("REPRO_FED_WIRE", "REPRO_FED_QBLOCK", "REPRO_FED_RING",
             "REPRO_ZERO1_SCATTER", "REPRO_CACHE_SHARD")


def _run_sub(script: str, timeout: int = 900, **env_extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    for k in _ENV_KEYS:
        env.pop(k, None)
    env.update(env_extra)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ---------------------------------------------------------------------------
# byte accounting: one number, three ways
# ---------------------------------------------------------------------------

def test_ring_wire_plan_f32_matches_classic_formula():
    """On a divisible f32 payload the exact chunk plan reduces to the
    textbook 2·P·(n-1)/n."""
    # 1024 elems over n=4: 2n=8 chunks of 128, no padding
    P = 1024 * 4
    assert comm.ring_wire_bytes(1024, 4, "f32") == int(2 * P * 3 / 4)
    assert comm.ring_wire_bytes(1024, 1, "f32") == 0


def test_ring_wire_plan_padding_is_counted():
    """Non-divisible payloads pay their real padding — no silent float
    truncation (the old int(2·P·(n-1)/n) would round DOWN)."""
    plan = comm.ring_wire_plan(1000, 16, "f32")
    assert plan.chunk_elems == 32          # ceil(1000 / 32)
    assert plan.per_device_bytes == 60 * 32 * 4
    assert plan.per_device_bytes >= int(2 * 4000 * 15 / 16)


def test_ring_wire_plan_int8_scale_bytes():
    plan = comm.ring_wire_plan(1 << 20, 8, "int8", qblock=128)
    c = plan.chunk_elems
    assert c % 128 == 0
    assert plan.scale_bytes == 4 * (c // 128)
    assert plan.code_bytes == c
    # scale overhead keeps the int8 wire under the 0.27x acceptance bound
    f32 = comm.ring_wire_bytes(1 << 20, 8, "f32")
    assert plan.per_device_bytes / f32 <= 0.27


def test_fed_ring_allreduce_bytes_wraps_plan():
    # payload_bytes -> f32 elems -> exact plan
    assert fed.ring_allreduce_bytes(4096, 4) == \
        comm.ring_wire_bytes(1024, 4, "f32")
    assert fed.ring_allreduce_bytes(4096, 4, wire="int8") == \
        comm.ring_wire_bytes(1024, 4, "int8")
    assert fed.ring_allreduce_bytes(1000, 1) == 0


def test_wire_payload_bytes():
    assert comm.wire_payload_bytes(1000, "f32") == 4000
    assert comm.wire_payload_bytes(1000, "bf16") == 2000
    assert comm.wire_payload_bytes(1000, "int8", qblock=128) == \
        1000 + 4 * 8   # ceil(1000/128) = 8 scale blocks
    with pytest.raises(ValueError):
        comm.wire_payload_bytes(10, "fp4")


@pytest.mark.parametrize("wire", comm.WIRE_FORMATS)
def test_expected_equals_accounted_per_wire(wire):
    """fed.expected_collective_bytes == comm.collective_bytes_per_round for
    every wire format (ways one and two of the three-way agreement; the
    kernel ledger is way three, measured on the emulated mesh below)."""
    from repro.configs import get_smoke_config
    from repro.core.lora import attach_lora
    from repro.models.registry import get_model

    cfg = get_smoke_config("qwen3-0.6b")

    def build(key):
        p = get_model(cfg).init(cfg, key)
        return attach_lora(p, key, rank=cfg.fedtime.lora_rank,
                           alpha=cfg.fedtime.lora_alpha)

    params = jax.eval_shape(build, jax.ShapeDtypeStruct((2,), jnp.uint32))
    shape = {"pod": 2, "data": 16, "model": 16}
    assert fed.expected_collective_bytes(params, shape, wire=wire) == \
        comm.collective_bytes_per_round(params, shape, wire=wire)


def test_fedtime_round_int8_shrinks(monkeypatch):
    from repro.configs import get_smoke_config
    from repro.core import fedtime
    from repro.core.lora import attach_lora

    cfg = get_smoke_config("fedtime-llama2-7b")
    p = fedtime.init(cfg, jax.random.PRNGKey(0), num_channels=3)
    p = attach_lora(p, jax.random.PRNGKey(1), rank=4, alpha=8.0)
    f32 = comm.fedtime_round(p, clients_per_round=4, num_clusters=2)
    i8 = comm.fedtime_round(p, clients_per_round=4, num_clusters=2,
                            wire="int8")
    assert i8.megabytes < 0.27 * f32.megabytes
    # env-driven default
    monkeypatch.setenv("REPRO_FED_WIRE", "int8")
    assert comm.fedtime_round(p, clients_per_round=4,
                              num_clusters=2).bytes_up == i8.bytes_up


# ---------------------------------------------------------------------------
# the ring itself (emulated meshes, subprocess)
# ---------------------------------------------------------------------------

_RING_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.comm import ring_wire_plan
from repro.dist import fed, fedcomm

MESHES = [jax.make_mesh((8, 1), ("data", "model")),
          jax.make_mesh((2, 2, 2), ("pod", "data", "model"))]
rng = np.random.default_rng(0)
for mesh in MESHES:
    axes = fed.aggregation_axes(mesh)
    n = 16                                         # members (divides both)
    # E = 610 elems / member: not divisible by 2n for any fed axis size
    members = {"wq": {"lora_a": None, "lora_b": None}}
    ints = rng.integers(-8, 9, (n, 5, 61, 2)).astype(np.float32)
    members["wq"]["lora_a"] = jnp.asarray(ints)
    members["wq"]["lora_b"] = jnp.asarray(
        rng.integers(-8, 9, (n,**SHAPE_B**)).astype(np.float32))
    w_int = jnp.ones((n,), jnp.float32)            # integer-exact weights
    exact = jax.tree.map(
        lambda a: np.tensordot(np.ones(n, np.float32), np.asarray(a),
                               axes=1), members)
    with mesh:
        # f32 wire: BIT-EXACT against psum (integer payload: any summation
        # order is exact in f32, so equality is robust)
        ring = fedcomm.ring_aggregate(members, w_int, mesh, wire="f32")
        os.environ["REPRO_FED_RING"] = "0"
        psum = fed.aggregate_adapters(members, w_int, mesh)
        del os.environ["REPRO_FED_RING"]
        for k in ("lora_a", "lora_b"):
            assert np.array_equal(np.asarray(ring["wq"][k]),
                                  np.asarray(psum["wq"][k])), (mesh, k)
            assert np.array_equal(np.asarray(ring["wq"][k]),
                                  exact["wq"][k]), (mesh, k)

        # weighted float aggregation, every wire
        wf = jnp.asarray(rng.random(n).astype(np.float32))
        wf = wf / wf.sum()
        want = jax.tree.map(
            lambda a: np.tensordot(np.asarray(wf), np.asarray(a), axes=1),
            members)
        for wire, tol in (("f32", 1e-6), ("bf16", 5e-2), ("int8", 0.3)):
            ledger = []
            out = fedcomm.ring_aggregate(members, wf, mesh, wire=wire,
                                         byte_ledger=ledger)
            for k in ("lora_a", "lora_b"):
                np.testing.assert_allclose(np.asarray(out["wq"][k]),
                                           want["wq"][k], atol=tol,
                                           err_msg=f"{wire} {k}")
            # way three of the byte agreement: the ledger records the
            # actual nbytes of every ppermute'd buffer at trace time
            E = sum(l.size // n for l in jax.tree.leaves(members))
            per_axis = {}
            for ax, b in ledger:
                per_axis[ax] = per_axis.get(ax, 0) + b
            shape = dict(mesh.shape)
            expected = fed.expected_collective_bytes(
                {"wq": {k: jax.ShapeDtypeStruct((E // 2,), jnp.float32)
                        for k in ("lora_a", "lora_b")}}, mesh, wire=wire)
            for ax in axes:
                plan = ring_wire_plan(E, shape[ax], wire)
                assert per_axis[ax] == plan.per_device_bytes, (wire, ax)
                assert per_axis[ax] == expected[ax], (wire, ax)
print("RING_PARITY_OK")
"""


def test_ring_psum_parity_and_byte_ledger():
    """f32 ring == psum bit-exact; weighted aggregation on every wire; the
    kernel's measured per-hop bytes == plan == expected_collective_bytes,
    per axis, on single- and multi-axis (pod) meshes."""
    out = _run_sub(_RING_PARITY.replace("**SHAPE_B**", "2, 61, 5"))
    assert "RING_PARITY_OK" in out


_RING_EF = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.dist import fedcomm

mesh = jax.make_mesh((4, 1), ("data", "model"))
rng = np.random.default_rng(3)
n = 4
members = {"a": jnp.asarray(rng.normal(size=(n, 777)).astype(np.float32))}
w = jnp.full((n,), 1.0 / n)
exact = np.asarray(members["a"]).mean(axis=0)

with mesh:
    # one-shot (no residual): a fixed quantization bias
    one = fedcomm.ring_aggregate(members, w, mesh, wire="int8")
    bias_one = float(np.abs(np.asarray(one["a"]) - exact).mean())

    # carried error feedback: the time-average converges to the true mean
    st = fedcomm.init_state(members, mesh, wire="int8")
    acc = np.zeros_like(exact)
    R = 24
    for r in range(R):
        out, st = fedcomm.ring_aggregate(members, w, mesh, wire="int8",
                                         state=st)
        acc += np.asarray(out["a"])
bias_ef = float(np.abs(acc / R - exact).mean())
print("bias one-shot", bias_one, "bias EF", bias_ef)
assert bias_ef < 0.35 * bias_one, (bias_ef, bias_one)
print("RING_EF_OK")
"""


def test_error_feedback_debiases_ring_rounds():
    """Carried EF residual: the running average of int8-wire rounds
    converges to the exact aggregate, while one-shot quantization keeps a
    fixed bias — Algorithm 1 stays unbiased on the quantized wire."""
    out = _run_sub(_RING_EF)
    assert "RING_EF_OK" in out


def test_quantize_update_host_path():
    """The host-loop wire emulation (fed_trainer's client upload): f32 is
    the identity, int8 round-trips within absmax precision, and the carried
    residual drives the time-averaged delivery to the true delta."""
    rng = np.random.default_rng(1)
    tree = {"x": jnp.asarray(rng.normal(size=(13, 7)).astype(np.float32)),
            "y": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}

    same, res = fedcomm.quantize_update(tree, None, wire="f32")
    assert same is tree and res is None

    dq, res = fedcomm.quantize_update(tree, None, wire="int8")
    for k in tree:
        np.testing.assert_allclose(np.asarray(dq[k]), np.asarray(tree[k]),
                                   atol=0.05)
    one_bias = max(float(np.abs(np.asarray(dq[k]) -
                                np.asarray(tree[k])).mean()) for k in tree)

    acc = {k: np.zeros(tree[k].shape, np.float32) for k in tree}
    res, R = None, 16
    for _ in range(R):
        dq, res = fedcomm.quantize_update(tree, res, wire="int8")
        for k in tree:
            acc[k] += np.asarray(dq[k])
    ef_bias = max(float(np.abs(acc[k] / R - np.asarray(tree[k])).mean())
                  for k in tree)
    assert ef_bias < 0.5 * one_bias, (ef_bias, one_bias)


def test_fed_trainer_int8_wire_runs():
    """federated_fit on the int8 wire: losses stay finite, comm is metered
    at wire prices (< 0.27x the f32 meter), residuals are carried."""
    from repro.configs import get_smoke_config
    from repro.data.federated import client_windows, partition_clients
    from repro.data.timeseries import (DATASETS, generate, train_test_split)
    from repro.train.fed_trainer import federated_fit

    cfg = get_smoke_config("fedtime-llama2-7b")
    series = generate(DATASETS["etth1"], timesteps=1200, seed=0)
    train, _ = train_test_split(series)
    clients = partition_clients(train, cfg.fedtime.num_clients, seed=0,
                                channels_per_client=2)
    cdata = client_windows(clients, cfg.fedtime.lookback,
                           cfg.fedtime.horizon, max_windows=24)
    res32 = federated_fit(cfg, cdata, rounds=1, batch_size=4)
    res8 = federated_fit(cfg, cdata, rounds=1, batch_size=4, wire="int8")
    assert all(np.isfinite(l.train_loss) for l in res8.logs)
    assert res8.total_megabytes() < 0.27 * res32.total_megabytes()


# ---------------------------------------------------------------------------
# ZeRO-1 scatter-update AdamW
# ---------------------------------------------------------------------------

_ZERO1 = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.launch.hlo_cost import analyze
from repro.models.registry import get_model
from repro.dist.sharding import param_specs, opt_state_specs, to_shardings
from repro.optim.adamw import adamw_init, adamw_update, adamw_update_zero1

cfg = get_smoke_config("qwen3-0.6b")
api = get_model(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
params = api.init(cfg, key)
grads = jax.tree.map(
    lambda p: jax.random.normal(jax.random.fold_in(key, p.size % 9973),
                                p.shape, jnp.float32) * 0.01, params)
opt = adamw_init(params)
psh = to_shardings(param_specs(params, mesh), mesh)
osh = to_shardings(opt_state_specs(params, mesh), mesh)

with mesh:
    # scatter-update == gather-update, bit-exact (same f32 arithmetic on
    # the same shards)
    pg, sg = adamw_update(params, grads, opt, 3, lr=1e-3, weight_decay=0.01)
    ps, ss = adamw_update_zero1(params, grads, opt, 3, mesh=mesh, lr=1e-3,
                                weight_decay=0.01)
    for a, b in ((pg, ps), (sg["mu"], ss["mu"]), (sg["nu"], ss["nu"])):
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a, b)

    # the dryrun cost model: the scatter formulation's collective term is
    # strictly smaller (no all-to-all / collective-permute resharding of
    # the replicated grads onto the moment layout)
    totals = {}
    for name, fn in (("gather", lambda p, g, s: adamw_update(p, g, s, 3)),
                     ("scatter", lambda p, g, s: adamw_update_zero1(
                         p, g, s, 3, mesh=mesh))):
        jitted = jax.jit(fn, in_shardings=(psh, psh, {"mu": osh, "nu": osh}),
                         out_shardings=(psh, {"mu": osh, "nu": osh}))
        parsed = analyze(jitted.lower(params, grads, opt).compile().as_text())
        totals[name] = parsed["collective_total_bytes"]
print("totals", totals)
assert totals["scatter"] < totals["gather"], totals
print("ZERO1_OK")
"""


def test_zero1_scatter_parity_and_collective_term():
    """ZeRO-1 scatter-update == gather-update param/moment parity
    (bit-exact), and a strictly smaller compiled collective term, on an
    emulated (data=4, model=2) mesh."""
    out = _run_sub(_ZERO1)
    assert "ZERO1_OK" in out


def test_zero1_no_mesh_falls_back():
    from repro.optim.adamw import (adamw_init, adamw_update,
                                   adamw_update_zero1)
    p = {"w": jnp.arange(8, dtype=jnp.float32)}
    g = {"w": jnp.ones(8, jnp.float32)}
    st = adamw_init(p)
    a, _ = adamw_update(p, g, st, 1)
    b, _ = adamw_update_zero1(p, g, st, 1, mesh=None)
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))


def test_zero1_env_escape_hatch(monkeypatch):
    from repro.optim.adamw import zero1_scatter_enabled
    assert zero1_scatter_enabled()
    monkeypatch.setenv("REPRO_ZERO1_SCATTER", "0")
    assert not zero1_scatter_enabled()
